//! End-to-end driver (the repo's full-system validation): Table-1a-style
//! attribution on the MLP + synthetic-digits workload, exercising every
//! layer of the stack on a real small workload:
//!
//!  * trains the model from Rust through the HLO train-step executable
//!    (logging the loss curve),
//!  * runs the staged cache pipeline (PJRT grad workers → compressors →
//!    gradient store on disk),
//!  * builds the FIM, preconditions, attributes held-out queries,
//!  * retrains LDS subset models and reports the LDS for SJLT vs RandomMask
//!    vs GraSS.
//!
//! Run: `cargo run --release --example mnist_attribution [-- --fast]`

use anyhow::Result;
use grass::attrib::fim::accumulate_fim;
use grass::attrib::influence::{scores_query_side, DAMPING_GRID};
use grass::coordinator::{pipeline::Source, CachePipeline, CompressorBank, PipelineConfig};
use grass::data::images::SynthDigits;
use grass::eval::retrain::{TaskData, Trainer};
use grass::eval::{lds_score, sample_subsets};
use grass::runtime::Runtime;
use grass::sketch::{Compressor, MaskKind, MethodSpec};
use grass::store::StoreReader;
use grass::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let fast = args.get_bool("fast");
    let (n, m, subsets, epochs) = if fast { (200, 24, 6, 2) } else { (800, 64, 12, 4) };

    let rt = Runtime::load(Runtime::artifacts_dir())?;
    let trainer = Trainer::new(&rt, "mlp")?;
    let p = trainer.p;
    println!("== end-to-end attribution driver: MLP ({p} params), n={n}, m={m} ==");

    let train = SynthDigits::generate(n, 1);
    let test = SynthDigits::generate(m, 2);
    let train_td = TaskData::Labelled(&train);
    let test_td = TaskData::Labelled(&test);
    let all: Vec<usize> = (0..n).collect();
    let tidx: Vec<usize> = (0..m).collect();

    // ---- train with a logged loss curve ----
    let mut params = trainer.init(0)?;
    for epoch in 0..epochs {
        params = trainer.train(params, &train_td, &all, 1, 0.2, epoch as u64)?;
        let tr_loss: f32 = trainer.losses(&params, &train_td, &all)?.iter().sum::<f32>() / n as f32;
        let te_loss: f32 = trainer.losses(&params, &test_td, &tidx)?.iter().sum::<f32>() / m as f32;
        println!("epoch {epoch}: train loss {tr_loss:.4}, test loss {te_loss:.4}");
    }

    // ---- cache stage through the staged pipeline ----
    let spec = MethodSpec::Sjlt { k: 512, s: 1 };
    let seed = 42u64;
    let store_dir = std::env::temp_dir().join(format!("grass_e2e_{}", std::process::id()));
    let pipeline = CachePipeline::new(&rt, "mlp", params.clone(), PipelineConfig::default());
    let bank = CompressorBank::Flat(spec.build(p, seed));
    let meta = pipeline.run_flat(
        &Source::Labelled(&train),
        &bank,
        &store_dir,
        &spec.spec_string(),
        seed,
    )?;
    println!("cache stage: {}", pipeline.metrics.report());
    assert_eq!(meta.n, n);

    // ---- attribute stage from the on-disk store ----
    let reader = StoreReader::open(&store_dir)?;
    let ctr = reader.read_all()?;
    let k = reader.meta.k;
    let c = MethodSpec::parse(&reader.meta.method)?.build(p, reader.meta.seed);
    let g_test = trainer.grads(&params, &test_td, &tidx)?;
    let mut cte = vec![0.0f32; m * k];
    c.compress_batch(&g_test, m, &mut cte);
    let fim = accumulate_fim(&ctr, n, k);

    // ---- LDS ground truth (subset retraining) ----
    println!("retraining {subsets} LDS subset models…");
    let subs = sample_subsets(n, subsets, 0.5, 7);
    let mut subset_losses = Vec::with_capacity(subsets * m);
    for (s, subset) in subs.iter().enumerate() {
        let ps = trainer.train(trainer.init(100 + s as i32)?, &train_td, subset, epochs, 0.2, s as u64)?;
        subset_losses.extend_from_slice(&trainer.losses(&ps, &test_td, &tidx)?);
    }

    // ---- compare methods on the SAME ground truth ----
    println!("\n{:<28} {:>8} {:>10}", "method", "LDS", "damping");
    for spec in [
        MethodSpec::RandomMask { k: 512 },
        MethodSpec::Sjlt { k: 512, s: 1 },
        MethodSpec::Grass {
            k: 512,
            k_prime: 2048,
            mask: MaskKind::Random,
        },
    ] {
        let c = spec.build(p, seed);
        let g_train = trainer.grads(&params, &train_td, &all)?;
        let mut ctr = vec![0.0f32; n * 512];
        c.compress_batch(&g_train, n, &mut ctr);
        let mut cte = vec![0.0f32; m * 512];
        c.compress_batch(&g_test, m, &mut cte);
        let fim = accumulate_fim(&ctr, n, 512);
        let mut best = (0.0f64, f64::NEG_INFINITY);
        for &d in DAMPING_GRID {
            if let Ok(scores) = scores_query_side(&fim, 512, d, &ctr, n, &cte, m) {
                let (lds, _) = lds_score(&scores, n, m, &subs, &subset_losses);
                if lds > best.1 {
                    best = (d, lds);
                }
            }
        }
        println!("{:<28} {:>8.4} {:>10.0e}", c.name(), best.1, best.0);
    }

    // keep the unused first-cache artifacts honest
    let _ = (fim, cte);
    std::fs::remove_dir_all(&store_dir).ok();
    println!("\nend-to-end driver OK");
    Ok(())
}
