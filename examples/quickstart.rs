//! Quickstart: the whole three-layer stack in one page.
//!
//! 1. Load the PJRT runtime and AOT artifacts (`make artifacts` first).
//! 2. Run the L1 Pallas SJLT kernel through HLO and cross-check it against
//!    the Rust-native SJLT (same seeded tables — bitwise same projection).
//! 3. Compress a batch of per-sample MLP gradients with GraSS and compute
//!    influence scores.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use grass::attrib::influence::InfluenceEngine;
use grass::data::images::SynthDigits;
use grass::eval::retrain::{TaskData, Trainer};
use grass::runtime::{Arg, Runtime};
use grass::sketch::rng::Pcg;
use grass::sketch::{sjlt::Sjlt, Compressor, MaskKind, MethodSpec};

fn main() -> Result<()> {
    let rt = Runtime::load(Runtime::artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // --- L1: Pallas SJLT kernel vs Rust-native SJLT -----------------------
    let exe = rt.executable("kernel_sjlt")?;
    let (b, p, k) = (4usize, 8192usize, 256usize);
    let native = Sjlt::new(p, k, 1, 42);
    let (mut idx, mut sgn) = (vec![0i32; p], vec![0f32; p]);
    for j in 0..p {
        let (bucket, sign) = native.bucket_sign(j, 0);
        idx[j] = bucket as i32;
        sgn[j] = sign;
    }
    let mut rng = Pcg::new(1);
    let g: Vec<f32> = (0..b * p).map(|_| rng.next_gaussian()).collect();
    let out = exe
        .run(&[
            Arg::F32(g.clone(), vec![b, p]),
            Arg::I32(idx, vec![p]),
            Arg::F32(sgn, vec![p]),
        ])?
        .remove(0);
    let want = native.compress(&g[..p]);
    let max_err = out
        .row(0)
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("L1 Pallas SJLT vs Rust SJLT: max |Δ| = {max_err:.2e}  ✓");

    // --- L2+L3: per-sample gradients → GraSS → influence ------------------
    let trainer = Trainer::new(&rt, "mlp")?;
    let n = 128;
    let m = 8;
    let train = SynthDigits::generate(n, 7);
    let test = SynthDigits::generate(m, 8);
    let all: Vec<usize> = (0..n).collect();
    let tidx: Vec<usize> = (0..m).collect();
    println!("training MLP ({} params) on {n} synthetic digits…", trainer.p);
    let params = trainer.train(
        trainer.init(0)?,
        &TaskData::Labelled(&train),
        &all,
        4,
        0.2,
        0,
    )?;

    let g_train = trainer.grads(&params, &TaskData::Labelled(&train), &all)?;
    let g_test = trainer.grads(&params, &TaskData::Labelled(&test), &tidx)?;

    let spec = MethodSpec::Grass {
        k: 256,
        k_prime: 2048,
        mask: MaskKind::Random,
    };
    let c = spec.build(trainer.p, 42);
    println!("compressing with {} (P = {} → k = 256)…", c.name(), trainer.p);
    let mut ctr = vec![0.0f32; n * 256];
    c.compress_batch(&g_train, n, &mut ctr);
    let mut cte = vec![0.0f32; m * 256];
    c.compress_batch(&g_test, m, &mut cte);

    let engine = InfluenceEngine::new(256, 1e-3);
    let scores = engine.attribute(&ctr, n, &cte, m)?;
    for q in 0..3.min(m) {
        let srow = &scores[q * n..(q + 1) * n];
        let best = (0..n)
            .max_by(|&a, &b| srow[a].partial_cmp(&srow[b]).unwrap())
            .unwrap();
        println!(
            "query {q} (class {}): most influential train sample #{best} (class {}), τ = {:.4}",
            test.sample(q).1,
            train.sample(best).1,
            srow[best]
        );
    }
    println!("quickstart OK");
    Ok(())
}
