//! Table-2 scenario: billion-scale compression throughput on the exact
//! Llama-3.1-8B layer geometry (synthetic activations; weight values are
//! irrelevant to compression cost — DESIGN.md §5).
//!
//! Prints the same rows as the paper's Table 2: compress and cache
//! throughput (tokens/s) for LoGra vs FactGraSS at k_l ∈ {256, 1024, 4096}.
//!
//! Run: `cargo run --release --example billion_scale_throughput [-- --fast]`

use anyhow::Result;
use grass::exp::table2;
use grass::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let (kls, tokens, reps) = if args.get_bool("fast") {
        (vec![256], 64, 2)
    } else {
        (
            args.get_usize_list("ks", &[256, 1024, 4096])?,
            args.get_usize("tokens", 256)?,
            args.get_usize("reps", 4)?,
        )
    };
    let (kls, tokens, reps) = (kls, tokens, reps);
    let table = table2::run(&kls, tokens, reps, None)?;
    table.print();
    println!(
        "paper's claim to reproduce in shape: FactGraSS ≥ 1.6× LoGra on the \
         compress step (paper: 160–175% on H200)."
    );
    Ok(())
}
