//! Figure-9 scenario: qualitative LLM data attribution with FactGraSS.
//!
//! Trains the tiny LM on the themed corpus, then attributes a themed query
//! prompt ("privacy") with FactGraSS + layer-wise block-diagonal FIM
//! influence and prints the top influential documents — the synthetic
//! analogue of the paper's "To improve data privacy" → privacy-journalism
//! retrieval (Fig. 9).
//!
//! Run: `cargo run --release --example lm_influence [-- --fast]`

use anyhow::Result;
use grass::config::ExpConfig;
use grass::exp::fig9;
use grass::runtime::Runtime;
use grass::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = ExpConfig {
        n_train: 384,
        epochs: 3,
        lr: 0.3,
        ..Default::default()
    };
    if args.get_bool("fast") {
        cfg.n_train = 96;
        cfg.epochs = 1;
    }
    let kl = args.get_usize("kl", 256)?;

    let rt = Runtime::load(Runtime::artifacts_dir())?;
    let outcome = fig9::run(&rt, &cfg, kl)?;
    outcome.table.print();
    println!(
        "top-10 same-theme fraction: {:.0}% (query theme: '{}'; corpus base rate ≈ 17%)",
        outcome.top10_theme_hit * 100.0,
        outcome.query_theme
    );
    Ok(())
}
