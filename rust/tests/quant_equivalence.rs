//! Numerics gate for quantized shard stores: payload codec roundtrip
//! error pins per dtype (f16/bf16/int8), streamed-score equivalence of
//! quantized stores against f32 for every scorer in the registry, and
//! `grass quantize` output parity against a natively quantized cache.

use grass::attrib::{from_spec, AttributionSpec, Attributor, StreamOpts};
use grass::sketch::rng::Pcg;
use grass::sketch::MethodSpec;
use grass::store::{PayloadDtype, StoreMeta, StoreReader, StoreWriter};
use std::path::PathBuf;
use std::process::Command;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_quant_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn gaussian(rows: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..rows * k).map(|_| rng.next_gaussian()).collect()
}

/// Write a raw `n × k` matrix as a store under the given payload codec.
fn write_store(dir: &PathBuf, rows: &[f32], k: usize, shard_rows: usize, dtype: PayloadDtype) {
    let meta = StoreMeta {
        k,
        n: 0,
        shard_rows,
        method: "raw".to_string(),
        seed: 0,
        model: String::new(),
        input_dim: 0,
        layer_dims: vec![],
        density: 1.0,
        dtype,
    };
    let mut w = StoreWriter::create_described(dir, meta).unwrap();
    w.push_batch(rows).unwrap();
    w.finish().unwrap();
}

/// The ISSUE's per-dtype roundtrip pins: f16 within 1e-3 relative, bf16
/// within its 8-bit-mantissa envelope, int8 within 1e-2 of the row's
/// absmax (per-row scales), and all three exact at zero.
#[test]
fn roundtrip_error_pins_per_dtype() {
    let (rows, k) = (8usize, 64usize);
    let mut rng = Pcg::new(3);
    let mut data: Vec<f32> = (0..rows * k).map(|_| rng.next_gaussian() * 10.0).collect();
    for v in &mut data[2 * k..3 * k] {
        *v = 0.0; // one all-zero row: must survive every codec exactly
    }

    for (dtype, rel) in [(PayloadDtype::F16, 1e-3f32), (PayloadDtype::Bf16, 4e-3)] {
        let mut enc = Vec::new();
        for r in data.chunks(k) {
            dtype.encode_row(r, &mut enc);
        }
        assert_eq!(enc.len(), rows * dtype.row_bytes(k), "{dtype} encoded size");
        let mut dec = vec![0.0f32; rows * k];
        dtype.decode_rows(&enc, k, rows, &mut dec);
        for i in 0..rows * k {
            let err = (dec[i] - data[i]).abs();
            // + 1e-6 absolute floor: a sample landing in the codec's
            // subnormal range has bounded absolute, not relative, error.
            assert!(
                err <= rel * data[i].abs() + 1e-6,
                "{dtype} roundtrip at {i}: {} vs {} (err {err})",
                dec[i],
                data[i]
            );
        }
        assert!(
            dec[2 * k..3 * k].iter().all(|&v| v == 0.0),
            "{dtype} zero row must roundtrip exactly"
        );
    }

    let dtype = PayloadDtype::Int8;
    let mut enc = Vec::new();
    for r in data.chunks(k) {
        dtype.encode_row(r, &mut enc);
    }
    assert_eq!(enc.len(), rows * (4 + k), "int8 rows carry a 4-byte scale");
    let mut dec = vec![0.0f32; rows * k];
    dtype.decode_rows(&enc, k, rows, &mut dec);
    for (r, row) in data.chunks(k).enumerate() {
        let absmax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        for (i, &v) in row.iter().enumerate() {
            let err = (dec[r * k + i] - v).abs();
            assert!(
                err <= 1e-2 * absmax,
                "int8 roundtrip row {r} col {i}: {} vs {v} (err {err}, absmax {absmax})",
                dec[r * k + i]
            );
        }
    }
    assert!(
        dec[2 * k..3 * k].iter().all(|&v| v == 0.0),
        "int8 zero row must roundtrip exactly (scale 0)"
    );
}

/// The tentpole contract: a quantized store streamed through the
/// dequant-fused read path produces the same scores as the f32 store for
/// every scorer in the registry, within each codec's error envelope — and
/// exactly zero for a zero gradient row under every codec.
#[test]
fn quantized_streamed_scores_match_f32_for_all_five_scorers() {
    let (n, k, m) = (96usize, 32usize, 6usize);
    let zero_row = 5usize;
    let mut g1 = gaussian(n, k, 21);
    let mut g2 = gaussian(n, k, 22);
    for v in &mut g1[zero_row * k..(zero_row + 1) * k] {
        *v = 0.0;
    }
    for v in &mut g2[zero_row * k..(zero_row + 1) * k] {
        *v = 0.0;
    }
    let queries = gaussian(m, k, 23);

    let f1 = tmpdir("eq_f32_a");
    let f2 = tmpdir("eq_f32_b");
    write_store(&f1, &g1, k, 7, PayloadDtype::F32); // ragged final shard
    write_store(&f2, &g2, k, 7, PayloadDtype::F32);
    let rf1 = StoreReader::open(&f1).unwrap();
    let rf2 = StoreReader::open(&f2).unwrap();
    // A budget small enough to force many streamed blocks on every store.
    let opts = StreamOpts {
        mem_budget: 3 * 2 * k * 4 * 2,
        workers: 3,
        ..StreamOpts::default()
    };

    for (dtype, tol) in [
        (PayloadDtype::F16, 3e-2f32),
        (PayloadDtype::Bf16, 1e-1),
        (PayloadDtype::Int8, 3e-1),
    ] {
        let q1 = tmpdir(&format!("eq_{dtype}_a"));
        let q2 = tmpdir(&format!("eq_{dtype}_b"));
        write_store(&q1, &g1, k, 7, dtype);
        write_store(&q2, &g2, k, 7, dtype);
        let rq1 = StoreReader::open(&q1).unwrap();
        let rq2 = StoreReader::open(&q2).unwrap();
        assert_eq!(rq1.meta.dtype, dtype);
        assert_eq!(rq1.meta.row_bytes(), dtype.row_bytes(k));

        for scorer in ["if", "graddot", "trak", "tracin", "blockwise"] {
            let mut aspec = AttributionSpec::new(scorer, MethodSpec::RandomMask { k }, 0);
            // Heavy damping keeps the preconditioned solve well conditioned
            // so the codec's input error is not amplified by the inverse.
            aspec.damping = 0.5;
            if scorer == "blockwise" {
                aspec.layout = vec![12, 20];
            }
            let ensemble = matches!(scorer, "trak" | "tracin");

            let mut base = from_spec(&aspec).unwrap();
            base.cache_stream(&rf1, &opts).unwrap();
            if ensemble {
                base.cache_stream(&rf2, &opts).unwrap();
            }
            let mut quant = from_spec(&aspec).unwrap();
            quant.cache_stream(&rq1, &opts).unwrap();
            if ensemble {
                quant.cache_stream(&rq2, &opts).unwrap();
            }

            let sb = base.attribute(&queries, m).unwrap();
            let sq = quant.attribute(&queries, m).unwrap();
            assert_eq!((sq.m, sq.n), (sb.m, sb.n), "{dtype}/{scorer} shape");
            for i in 0..m * n {
                let (a, b) = (sq.scores[i], sb.scores[i]);
                assert!(
                    (a - b).abs() <= tol * (1.0 + b.abs()),
                    "{dtype}/{scorer} score {i}: quantized {a} vs f32 {b}"
                );
            }
            // The zero gradient row scores exactly zero under every codec.
            for q in 0..m {
                assert_eq!(
                    sq.scores[q * n + zero_row],
                    0.0,
                    "{dtype}/{scorer} zero row must score exactly 0"
                );
            }
        }
        std::fs::remove_dir_all(&q1).ok();
        std::fs::remove_dir_all(&q2).ok();
    }
    std::fs::remove_dir_all(&f1).ok();
    std::fs::remove_dir_all(&f2).ok();
}

/// `grass quantize` parity: converting an f32 cache offline produces
/// byte-identical shards to a cache run that used `--dtype` natively
/// (both encode the same exact f32 rows), and the in-place rewrite leaves
/// a store that verifies clean and still attributes.
#[test]
fn cli_quantize_matches_native_quantized_cache() {
    let exe = env!("CARGO_BIN_EXE_grass");
    let dir_f32 = tmpdir("cli_f32");
    let dir_native = tmpdir("cli_native");
    let dir_conv = tmpdir("cli_conv");
    let base_args = |store: &PathBuf, extra: &[&str]| {
        let mut v = vec![
            "cache".to_string(),
            "--model".to_string(),
            "synth".to_string(),
            "--method".to_string(),
            "rm:k=32".to_string(),
            "--n".to_string(),
            "40".to_string(),
            "--p".to_string(),
            "256".to_string(),
            "--seed".to_string(),
            "7".to_string(),
            "--shard-rows".to_string(),
            "16".to_string(),
            "--store".to_string(),
            store.to_str().unwrap().to_string(),
        ];
        v.extend(extra.iter().map(|s| s.to_string()));
        v
    };
    let run = |args: &[String]| {
        let out = Command::new(exe).args(args).output().expect("spawn grass");
        assert!(
            out.status.success(),
            "grass {:?} failed: {}{}",
            args,
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    run(&base_args(&dir_f32, &[]));
    run(&base_args(&dir_native, &["--dtype", "f16"]));
    let stdout = run(&[
        "quantize".to_string(),
        "--store".to_string(),
        dir_f32.to_str().unwrap().to_string(),
        "--dtype".to_string(),
        "f16".to_string(),
        "--out".to_string(),
        dir_conv.to_str().unwrap().to_string(),
    ]);
    assert!(stdout.contains("f32 → f16"), "{stdout}");

    // Converted shards are byte-identical to the natively quantized cache.
    for idx in 0..3 {
        let name = format!("shard_{idx:04}.bin");
        let a = std::fs::read(dir_conv.join(&name)).unwrap();
        let b = std::fs::read(dir_native.join(&name)).unwrap();
        assert_eq!(a.len(), 16 * 32 * 2, "{name} holds 16 f16 rows of k=32");
        assert_eq!(a, b, "{name} differs between quantize and native cache");
    }
    let conv_meta = std::fs::read_to_string(dir_conv.join("store.json")).unwrap();
    assert!(conv_meta.contains("f16"), "{conv_meta}");

    // In-place rewrite: the f32 source becomes an f16 store that verifies
    // clean and still attributes through the dequant-fused read path.
    run(&[
        "quantize".to_string(),
        "--store".to_string(),
        dir_f32.to_str().unwrap().to_string(),
        "--dtype".to_string(),
        "f16".to_string(),
    ]);
    let meta = std::fs::read_to_string(dir_f32.join("store.json")).unwrap();
    assert!(meta.contains("f16"), "{meta}");
    let out = Command::new(exe)
        .args(["verify", "--store", dir_f32.to_str().unwrap()])
        .output()
        .expect("spawn grass verify");
    assert_eq!(out.status.code(), Some(0), "verify after in-place quantize");
    let stdout = run(&[
        "attribute".to_string(),
        "--store".to_string(),
        dir_f32.to_str().unwrap().to_string(),
        "--queries".to_string(),
        "4".to_string(),
        "--scorer".to_string(),
        "graddot".to_string(),
    ]);
    assert!(stdout.contains("attributed 4 queries"), "{stdout}");

    // Quantizing an already-lossy store is refused with a descriptive error.
    let out = Command::new(exe)
        .args([
            "quantize",
            "--store",
            dir_f32.to_str().unwrap(),
            "--dtype",
            "int8",
        ])
        .output()
        .expect("spawn grass quantize lossy");
    assert!(!out.status.success(), "re-quantizing lossy payloads must fail");
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("f16"), "{err}");

    std::fs::remove_dir_all(&dir_f32).ok();
    std::fs::remove_dir_all(&dir_native).ok();
    std::fs::remove_dir_all(&dir_conv).ok();
}
