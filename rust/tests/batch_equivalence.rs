//! Property tests: every compressor implementing the batch API must produce
//! the same output as the per-sample path within 1e-4 relative tolerance —
//! across s > 1, sparse inputs, non-divisible batch sizes, inputs above the
//! parallel threshold, and strided factorized output bands. The CSR
//! (sparse) kernels are held to the same bound against the dense batch
//! kernels across densities {0.001, 0.01, 0.1, 1.0}, ragged rows, empty
//! rows, and the dispatch crossover.

use grass::sketch::factgrass::{FactGrass, FactMask, FactSjlt};
use grass::sketch::logra::LoGra;
use grass::sketch::rng::Pcg;
use grass::sketch::sparse::{should_dispatch_sparse, SPARSE_DISPATCH_MAX_DENSITY};
use grass::sketch::{Compressor, FactorizedCompressor, MaskKind, MethodSpec, Scratch, SparseRows};

const TOL: f32 = 1e-4;

fn close(got: f32, want: f32) -> bool {
    (got - want).abs() <= TOL * (1.0 + want.abs())
}

/// Gradient rows with a requested zero fraction (sparse-input coverage).
fn make_rows(rows: usize, p: usize, zero_frac: f64, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..rows * p)
        .map(|_| {
            if rng.next_f64() < zero_frac {
                0.0
            } else {
                rng.next_gaussian()
            }
        })
        .collect()
}

/// Shared harness: batch output row-for-row equals the per-sample path.
fn check_flat(c: &dyn Compressor, n: usize, gs: &[f32], scratch: &mut Scratch) {
    let (p, k) = (c.input_dim(), c.output_dim());
    assert_eq!(gs.len(), n * p);
    let mut batch = vec![0.0f32; n * k];
    c.compress_batch_with(gs, n, &mut batch, scratch);
    for i in 0..n {
        let single = c.compress(&gs[i * p..(i + 1) * p]);
        for j in 0..k {
            assert!(
                close(batch[i * k + j], single[j]),
                "{} n={n} row {i} col {j}: batch {} vs single {}",
                c.name(),
                batch[i * k + j],
                single[j]
            );
        }
    }
}

#[test]
fn flat_batch_matches_single_all_methods() {
    // p chosen prime-ish so it never divides the SJLT chunk, the Gauss
    // block, or the batch size; n covers 1, odd, and non-divisible sizes.
    let p = 1537;
    let specs = [
        MethodSpec::RandomMask { k: 120 },
        MethodSpec::SelectiveMask { k: 64 },
        MethodSpec::Sjlt { k: 120, s: 1 },
        MethodSpec::Sjlt { k: 120, s: 3 },
        MethodSpec::Gauss { k: 70 },
        MethodSpec::Fjlt { k: 120 },
        MethodSpec::Grass {
            k: 64,
            k_prime: 300,
            mask: MaskKind::Random,
        },
    ];
    let mut scratch = Scratch::new();
    for &n in &[1usize, 5, 17] {
        for &zero_frac in &[0.0, 0.6] {
            let gs = make_rows(n, p, zero_frac, 31 + n as u64);
            for spec in &specs {
                let c = spec.build(p, 907);
                check_flat(c.as_ref(), n, &gs, &mut scratch);
            }
        }
    }
}

#[test]
fn flat_batch_matches_single_above_parallel_threshold() {
    // p > 2^15 drives the single-sample SJLT through its parallel
    // private-accumulator reduction, so the comparison crosses two
    // different floating-point summation orders — the 1e-4 relative
    // tolerance is exactly the fp-reassociation budget.
    let p = (1 << 16) + 77;
    let n = 3;
    let gs = make_rows(n, p, 0.4, 99);
    let mut scratch = Scratch::new();
    let specs = [
        MethodSpec::Sjlt { k: 256, s: 2 },
        MethodSpec::RandomMask { k: 512 },
        MethodSpec::Grass {
            k: 128,
            k_prime: 2048,
            mask: MaskKind::Random,
        },
    ];
    for spec in &specs {
        let c = spec.build(p, 13);
        check_flat(c.as_ref(), n, &gs, &mut scratch);
    }
}

/// Shared harness for factorized compressors: batch output must match the
/// per-sample path inside a strided band and leave the rest of each row
/// untouched (the pipeline interleaves per-layer bands in one block).
fn check_factorized(c: &dyn FactorizedCompressor, n: usize, t: usize, seed: u64) {
    let (d_in, d_out, k) = (c.d_in(), c.d_out(), c.output_dim());
    let mut rng = Pcg::new(seed);
    let x: Vec<f32> = (0..n * t * d_in).map(|_| rng.next_gaussian()).collect();
    let dy: Vec<f32> = (0..n * t * d_out).map(|_| rng.next_gaussian()).collect();
    let stride = k + 7;
    let off = 3;
    let sentinel = -1234.5f32;
    let mut out = vec![sentinel; n * stride];
    let mut scratch = Scratch::new();
    c.compress_batch_with(n, t, &x, &dy, &mut out, stride, off, &mut scratch);
    for i in 0..n {
        let single = c.compress(
            t,
            &x[i * t * d_in..(i + 1) * t * d_in],
            &dy[i * t * d_out..(i + 1) * t * d_out],
        );
        for j in 0..k {
            assert!(
                close(out[i * stride + off + j], single[j]),
                "{} n={n} sample {i} col {j}: batch {} vs single {}",
                c.name(),
                out[i * stride + off + j],
                single[j]
            );
        }
        for j in 0..off {
            assert_eq!(out[i * stride + j], sentinel, "{} clobbered pre-band", c.name());
        }
        for j in off + k..stride {
            assert_eq!(out[i * stride + j], sentinel, "{} clobbered post-band", c.name());
        }
    }
}

#[test]
fn factorized_batch_matches_single_all_methods() {
    let (d_in, d_out) = (48, 36);
    for &n in &[1usize, 5] {
        for &t in &[1usize, 6] {
            check_factorized(&LoGra::new(d_in, d_out, 6, 4, 5), n, t, 41);
            check_factorized(
                &FactGrass::new(d_in, d_out, 12, 9, 24, MaskKind::Random, 5),
                n,
                t,
                42,
            );
            check_factorized(&FactMask::new(d_in, d_out, 8, 6, 5), n, t, 43);
            check_factorized(&FactSjlt::new(d_in, d_out, 8, 6, 5), n, t, 44);
        }
    }
}

/// Ragged batch at a target density: row 0 is empty, later rows ramp from
/// ~0.2× to ~2× the target, so per-row nnz varies wildly within one batch.
fn make_ragged(n: usize, p: usize, density: f64, seed: u64) -> (Vec<f32>, SparseRows) {
    let mut rng = Pcg::new(seed);
    let mut dense = vec![0.0f32; n * p];
    for i in 1..n {
        let row_density = density * (0.2 + 1.8 * (i - 1) as f64 / n.max(2) as f64);
        for v in dense[i * p..(i + 1) * p].iter_mut() {
            if rng.next_f64() < row_density {
                *v = rng.next_gaussian();
            }
        }
    }
    let sp = SparseRows::from_dense_threshold(&dense, n, p, 0.0);
    assert_eq!(sp.to_dense(), dense, "CSR roundtrip must be exact");
    assert_eq!(sp.nnz(0), 0, "row 0 stays empty");
    (dense, sp)
}

/// Shared harness: the CSR kernel must match the dense batch kernel.
fn check_flat_sparse(c: &dyn Compressor, dense: &[f32], sp: &SparseRows, scratch: &mut Scratch) {
    let (n, k) = (sp.n(), c.output_dim());
    let mut dense_out = vec![0.0f32; n * k];
    c.compress_batch_with(dense, n, &mut dense_out, scratch);
    let mut sparse_out = vec![0.0f32; n * k];
    c.compress_sparse_batch_with(sp, &mut sparse_out, scratch);
    for i in 0..n {
        for j in 0..k {
            assert!(
                close(sparse_out[i * k + j], dense_out[i * k + j]),
                "{} density={:.4} row {i} col {j}: sparse {} vs dense {}",
                c.name(),
                sp.density(),
                sparse_out[i * k + j],
                dense_out[i * k + j]
            );
        }
    }
}

#[test]
fn flat_sparse_matches_dense_all_methods_all_densities() {
    let p = 2053; // prime: never divides the SJLT chunk or the mask width
    let specs = [
        MethodSpec::RandomMask { k: 120 },
        MethodSpec::SelectiveMask { k: 64 },
        MethodSpec::Sjlt { k: 120, s: 1 },
        MethodSpec::Sjlt { k: 120, s: 3 },
        MethodSpec::Gauss { k: 48 },
        MethodSpec::Fjlt { k: 120 },
        MethodSpec::Grass {
            k: 64,
            k_prime: 300,
            mask: MaskKind::Random,
        },
        MethodSpec::Grass {
            k: 48,
            k_prime: 256,
            mask: MaskKind::Selective,
        },
    ];
    let mut scratch = Scratch::new();
    for (di, &density) in [0.001f64, 0.01, 0.1, 1.0].iter().enumerate() {
        let n = 9;
        let (dense, sp) = make_ragged(n, p, density, 0x5A17 + di as u64);
        for spec in &specs {
            let c = spec.build(p, 907);
            check_flat_sparse(c.as_ref(), &dense, &sp, &mut scratch);
        }
    }
}

#[test]
fn flat_sparse_matches_dense_at_dispatch_crossover() {
    // The pipeline flips representation exactly at the crossover: both
    // sides of the flip must agree, and the predicate must flip with them.
    let p = 1600;
    let n = 5;
    let mut scratch = Scratch::new();
    for &factor in &[0.5f64, 1.0, 1.5] {
        let density = SPARSE_DISPATCH_MAX_DENSITY * factor;
        let (dense, sp) = make_ragged(n, p, density, 77 + (factor * 10.0) as u64);
        for spec in &[
            MethodSpec::Sjlt { k: 96, s: 1 },
            MethodSpec::RandomMask { k: 96 },
            MethodSpec::Grass {
                k: 48,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ] {
            let c = spec.build(p, 13);
            check_flat_sparse(c.as_ref(), &dense, &sp, &mut scratch);
        }
    }
    // Predicate semantics at the exact boundary.
    let elems = 4096;
    let at = (SPARSE_DISPATCH_MAX_DENSITY * elems as f64) as usize;
    assert!(should_dispatch_sparse(at, elems));
    assert!(!should_dispatch_sparse(at + 1, elems));
}

#[test]
fn flat_sparse_all_empty_rows_give_zeros() {
    let p = 512;
    let n = 4;
    let mut sp = SparseRows::new(p);
    for _ in 0..n {
        sp.push_row(&[], &[]);
    }
    let mut scratch = Scratch::new();
    for spec in &[
        MethodSpec::Sjlt { k: 64, s: 2 },
        MethodSpec::RandomMask { k: 64 },
        MethodSpec::Grass {
            k: 32,
            k_prime: 128,
            mask: MaskKind::Random,
        },
    ] {
        let c = spec.build(p, 3);
        let mut out = vec![1.0f32; n * c.output_dim()];
        c.compress_sparse_batch_with(&sp, &mut out, &mut scratch);
        assert!(
            out.iter().all(|&v| v == 0.0),
            "{}: empty rows must compress to zeros",
            c.name()
        );
    }
}

/// Shared harness for the factorized CSR kernels: must match the dense
/// batch kernel inside a strided band and leave the rest untouched.
fn check_factorized_sparse(
    c: &dyn FactorizedCompressor,
    n: usize,
    t: usize,
    density: f64,
    seed: u64,
) {
    let (d_in, d_out, k) = (c.d_in(), c.d_out(), c.output_dim());
    let (x, xs) = make_ragged(n * t, d_in, density, seed);
    let (dy, dys) = make_ragged(n * t, d_out, density, seed ^ 0xFF);
    let stride = k + 5;
    let off = 2;
    let sentinel = -4321.5f32;
    let mut scratch = Scratch::new();
    let mut dense_out = vec![sentinel; n * stride];
    c.compress_batch_with(n, t, &x, &dy, &mut dense_out, stride, off, &mut scratch);
    let mut sparse_out = vec![sentinel; n * stride];
    c.compress_sparse_batch_with(n, t, &xs, &dys, &mut sparse_out, stride, off, &mut scratch);
    for i in 0..n {
        for j in 0..k {
            assert!(
                close(sparse_out[i * stride + off + j], dense_out[i * stride + off + j]),
                "{} density={density} sample {i} col {j}: sparse {} vs dense {}",
                c.name(),
                sparse_out[i * stride + off + j],
                dense_out[i * stride + off + j]
            );
        }
        for j in 0..off {
            assert_eq!(sparse_out[i * stride + j], sentinel, "{} clobbered pre-band", c.name());
        }
        for j in off + k..stride {
            assert_eq!(sparse_out[i * stride + j], sentinel, "{} clobbered post-band", c.name());
        }
    }
}

#[test]
fn factorized_sparse_matches_dense_all_methods_all_densities() {
    let (d_in, d_out) = (96, 72);
    for (di, &density) in [0.01f64, 0.1, 1.0].iter().enumerate() {
        let seed = 0xFA * (di as u64 + 1);
        for &(n, t) in &[(1usize, 4usize), (4, 3)] {
            check_factorized_sparse(&LoGra::new(d_in, d_out, 6, 4, 5), n, t, density, seed);
            check_factorized_sparse(
                &FactGrass::new(d_in, d_out, 12, 9, 24, MaskKind::Random, 5),
                n,
                t,
                density,
                seed + 1,
            );
            check_factorized_sparse(&FactMask::new(d_in, d_out, 8, 6, 5), n, t, density, seed + 2);
            check_factorized_sparse(&FactSjlt::new(d_in, d_out, 8, 6, 5), n, t, density, seed + 3);
        }
    }
}

#[test]
fn factorized_default_fallback_matches_tuned_kernel() {
    // The trait's default batch implementation (per-sample loop) and the
    // tuned kernels must agree — guards the contract both sides implement.
    struct Fallback<'a>(&'a LoGra);
    impl FactorizedCompressor for Fallback<'_> {
        fn d_in(&self) -> usize {
            self.0.d_in()
        }
        fn d_out(&self) -> usize {
            self.0.d_out()
        }
        fn output_dim(&self) -> usize {
            self.0.output_dim()
        }
        fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]) {
            self.0.compress_into(t, x, dy, out)
        }
        fn name(&self) -> String {
            format!("fallback[{}]", self.0.name())
        }
    }
    let lg = LoGra::new(32, 24, 4, 3, 9);
    let (n, t) = (4, 5);
    let mut rng = Pcg::new(7);
    let x: Vec<f32> = (0..n * t * 32).map(|_| rng.next_gaussian()).collect();
    let dy: Vec<f32> = (0..n * t * 24).map(|_| rng.next_gaussian()).collect();
    let k = lg.output_dim();
    let mut scratch = Scratch::new();
    let mut tuned = vec![0.0f32; n * k];
    lg.compress_batch_with(n, t, &x, &dy, &mut tuned, k, 0, &mut scratch);
    let mut fallback = vec![0.0f32; n * k];
    Fallback(&lg).compress_batch_with(n, t, &x, &dy, &mut fallback, k, 0, &mut scratch);
    for i in 0..n * k {
        assert!(
            close(tuned[i], fallback[i]),
            "at {i}: tuned {} vs fallback {}",
            tuned[i],
            fallback[i]
        );
    }
}
