//! Chaos soak for the serving daemon: scripted store faults (transient +
//! corrupt reads), panicking scorers, byte-dribbling and mid-request
//! disconnecting clients, oversized and unparseable frames, and concurrent
//! hot reloads — all at once, asserting liveness (every honest request is
//! answered correctly), correct shedding (every dishonest one gets a typed
//! reply or a bounded reap, never a wedge), and a clean draining shutdown.
//! A separate test drives the real binary through SIGTERM and checks the
//! drain banner, final metrics dump, and exit code 0.

use grass::data::synthgrad::SynthGrads;
use grass::models::shapes::ModelShapes;
use grass::serve::proto::{self, ScoreRequest};
use grass::serve::{spawn, ErrorKind, QueryPayload, Request, Response, ServeConfig};
use grass::sketch::{MethodSpec, Scratch};
use grass::store::{FaultKind, FaultPlan, StoreMeta, StoreWriter};
use grass::util::json::Json;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Cache a flat synthetic store the daemon can serve (model `"synth"`,
/// geometry recorded, compressed through the spec's bank).
fn write_synth_store(tag: &str, n: usize, p: usize, seed: u64, shard_rows: usize) -> PathBuf {
    let dir = tmpdir(tag);
    let spec = MethodSpec::Sjlt { k: 32, s: 1 };
    let shapes = ModelShapes::flat(p);
    let bank = spec.build_bank(&shapes, seed).unwrap();
    let c = bank.as_flat().unwrap();
    let meta = StoreMeta::describe(&spec, seed, "synth", &shapes, shard_rows).unwrap();
    let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    let rows = SynthGrads::new(p, seed).rows(0, n);
    let mut out = vec![0.0f32; n * c.output_dim()];
    let mut scratch = Scratch::new();
    c.compress_batch_with(&rows, n, &mut out, &mut scratch);
    w.push_batch(&out).unwrap();
    w.finish().unwrap();
    dir
}

fn quiet_cfg(dir: &PathBuf, scorers: &[&str]) -> ServeConfig {
    ServeConfig {
        store: dir.clone(),
        scorers: scorers.iter().map(|s| s.to_string()).collect(),
        quiet: true,
        ..ServeConfig::default()
    }
}

/// One NDJSON client connection: send a request frame, read one reply.
struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self {
            reader,
            writer: BufWriter::new(stream),
        }
    }

    fn ask(&mut self, req: &Request) -> Response {
        proto::write_frame(&mut self.writer, &req.to_line()).expect("write frame");
        let frame = proto::read_frame(&mut self.reader)
            .expect("read frame")
            .expect("daemon closed the connection without replying");
        Response::from_json(&frame).expect("parse response")
    }

    fn stats(&mut self) -> Json {
        match self.ask(&Request::Stats { id: 0 }) {
            Response::Stats { stats, .. } => stats,
            other => panic!("expected stats, got {other:?}"),
        }
    }
}

fn score_req(id: u64, scorer: &str, m: usize) -> Request {
    Request::Score(ScoreRequest {
        id,
        scorer: scorer.to_string(),
        top_k: 3,
        include_scores: false,
        self_influence: false,
        deadline_ms: None,
        queries: QueryPayload::Synth { m },
    })
}

fn stat(stats: &Json, path: &[&str]) -> f64 {
    let mut v = stats;
    for key in path {
        v = v.get(key).unwrap_or_else(|| panic!("stats missing {path:?}"));
    }
    v.as_f64().unwrap_or_else(|| panic!("stats {path:?} is not a number"))
}

fn quarantined(stats: &Json) -> Vec<usize> {
    match stats.get("breaker").and_then(|b| b.get("quarantined")) {
        Some(Json::Arr(xs)) => xs
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|f| f as usize)
            .collect(),
        _ => panic!("stats.breaker.quarantined missing"),
    }
}

fn wait_until(mut cond: impl FnMut() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// The soak: a faulty store (one recoverable shard, one breaker-tripping
/// shard, one corrupt shard), honest scoring load, panicking scorers,
/// a stalled half-frame client, mid-request disconnects, garbage and
/// oversized frames — concurrently. Every honest request succeeds with
/// correct degraded coverage; every fault is a typed reply or a counted
/// reap; the supervisor heals the worker pool; reloads clear the breaker
/// once the underlying fault is gone; the drain is clean.
#[test]
fn chaos_soak_keeps_serving_through_faults_panics_and_bad_clients() {
    let (n, p, seed) = (48usize, 64usize, 13u64);
    let shard_rows = 8usize; // 6 shards: 0..=5
    let dir = write_synth_store("soak", n, p, seed, shard_rows);

    let plan = FaultPlan::new();
    // Shard 1: one transient error — retries recover it, full coverage.
    plan.fail_read(1, FaultKind::Transient, 0, 1);
    // Shard 3: persistent transient errors — the breaker (threshold 2)
    // trips mid-retry and quarantines it for the epoch. Five firings
    // leave three for the first reload (trips again) and one for the
    // second (absorbed by a retry: the shard heals).
    plan.fail_read(3, FaultKind::Transient, 0, 5);
    // Shard 4: one corrupt read — quarantined via skip_corrupt outright.
    plan.fail_read(4, FaultKind::Corrupt, 0, 1);

    let handle = spawn(ServeConfig {
        workers: 2,
        skip_corrupt: true,
        cache_bytes: 0, // reads hit the fault hooks, not a warm cache
        retries: 4,
        retry_backoff_ms: 1,
        breaker: 2,
        idle_ms: 2_000,
        drain_ms: 2_000,
        faults: Some(plan),
        ..quiet_cfg(&dir, &["graddot"])
    })
    .unwrap();
    let addr = handle.addr();

    // The build already exercised the fault plan: shard 1 recovered,
    // shard 3 breaker-quarantined, shard 4 corrupt-quarantined.
    {
        let mut probe = Client::connect(addr);
        let stats = probe.stats();
        assert_eq!(stat(&stats, &["epoch"]), 1.0);
        assert_eq!(stat(&stats, &["breaker", "threshold"]), 2.0);
        assert_eq!(stat(&stats, &["breaker", "trips"]), 1.0);
        assert_eq!(quarantined(&stats), vec![3, 4]);
        assert!(stat(&stats, &["breaker", "failed_reads"]) >= 4.0);
        // dropped before the chaos: an idle connection would be reaped
    }

    let degraded_rows = n - 2 * shard_rows;
    std::thread::scope(|s| {
        // Honest scoring load: every reply must be Scores with the
        // degraded-but-correct coverage, pinned to epoch 1.
        for t in 0..3u64 {
            s.spawn(move || {
                let mut c = Client::connect(addr);
                for r in 0..4u64 {
                    let resp = c.ask(&score_req(t * 100 + r, "graddot", 2));
                    let Response::Scores(resp) = resp else {
                        panic!("scorer {t} request {r} failed: {resp:?}");
                    };
                    assert_eq!(resp.epoch, 1);
                    assert_eq!(resp.coverage.rows_scored, degraded_rows);
                    assert_eq!(resp.coverage.quarantined, vec![3, 4]);
                }
            });
        }
        // Liveness pinger.
        s.spawn(move || {
            let mut c = Client::connect(addr);
            for i in 0..20u64 {
                let resp = c.ask(&Request::Ping { id: i });
                assert!(matches!(resp, Response::Pong { .. }), "{resp:?}");
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        // Panicking scorer: each panic is a typed internal reply, the
        // worker dies, and the supervisor respawns it.
        s.spawn(move || {
            for i in 0..2u64 {
                let mut c = Client::connect(addr);
                let resp = c.ask(&score_req(900 + i, "__panic__", 1));
                let Response::Error { kind, message, .. } = resp else {
                    panic!("expected a typed panic reply, got {resp:?}");
                };
                assert_eq!(kind, ErrorKind::Internal);
                assert!(message.contains("panicked"), "{message}");
            }
        });
        // Byte-dribbling client: half a frame, then silence — the idle
        // reaper answers descriptively and closes the connection.
        s.spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"{\"v\":1,").unwrap();
            stream.flush().unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(15)))
                .unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            assert!(line.contains("idle connection"), "{line}");
        });
        // Mid-request disconnects: a full frame, then vanish before the
        // reply — the admission ticket must still come back.
        for t in 0..2u64 {
            s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut w = BufWriter::new(stream);
                proto::write_frame(&mut w, &score_req(800 + t, "graddot", 1).to_line())
                    .unwrap();
                // dropped here: FIN while the request is in flight
            });
        }
        // Garbage frame: typed BadRequest, counted as a parse failure.
        s.spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"this is not json\n").unwrap();
            stream.flush().unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(15)))
                .unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            assert!(line.contains("unparseable frame"), "{line}");
        });
        // Oversized frame: one unbounded line must not OOM the daemon —
        // the read is cut off at the frame bound and answered best-effort
        // (the peer may see the connection drop mid-write instead).
        s.spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let blob = vec![b'x'; proto::MAX_FRAME_BYTES + 2];
            let _ = stream.write_all(&blob);
            let _ = stream.flush();
        });
    });

    // Supervisor evidence: both panics were counted, the pool healed, and
    // no admission slot leaked across the chaos.
    let mut client = Client::connect(addr);
    wait_until(
        || {
            let s = client.stats();
            stat(&s, &["workers", "respawns"]) >= 2.0
                && stat(&s, &["admission", "queue_depth"]) == 0.0
                && stat(&s, &["requests", "bad_frames_oversized"]) >= 1.0
        },
        "worker respawns, a drained admission queue, and the oversized-frame count",
    );
    let stats = client.stats();
    assert_eq!(stat(&stats, &["workers", "panics"]), 2.0);
    assert_eq!(stat(&stats, &["requests", "bad_frames_parse"]), 1.0);
    assert!(stat(&stats, &["connections", "reaped_idle"]) >= 1.0);
    assert!(stat(&stats, &["requests", "scored"]) >= 12.0);

    // The pool still serves after every worker died at least once.
    let resp = client.ask(&score_req(2000, "graddot", 2));
    assert!(matches!(resp, Response::Scores(_)), "{resp:?}");

    // Reload #1: fresh epoch, fresh breaker — but the underlying fault
    // still fires, so shard 3 trips again; shard 4's fault is spent.
    let resp = client.ask(&Request::Reload {
        id: 3000,
        store: None,
    });
    let Response::Reloaded { epoch, .. } = resp else {
        panic!("reload failed: {resp:?}");
    };
    assert_eq!(epoch, 2);
    let stats = client.stats();
    assert_eq!(stat(&stats, &["epoch"]), 2.0);
    assert_eq!(stat(&stats, &["store", "opens"]), 2.0);
    assert_eq!(stat(&stats, &["breaker", "trips"]), 1.0);
    assert_eq!(quarantined(&stats), vec![3]);

    // Reload #2: one transient firing left — a retry absorbs it, the
    // breaker stays closed, and coverage is whole again.
    let resp = client.ask(&Request::Reload {
        id: 3001,
        store: None,
    });
    assert!(matches!(resp, Response::Reloaded { epoch: 3, .. }), "{resp:?}");
    let stats = client.stats();
    assert_eq!(stat(&stats, &["breaker", "trips"]), 0.0);
    assert!(quarantined(&stats).is_empty());
    let resp = client.ask(&score_req(4000, "graddot", 2));
    let Response::Scores(r) = resp else {
        panic!("post-reload score failed: {resp:?}");
    };
    assert_eq!(r.epoch, 3);
    assert_eq!(r.coverage.rows_scored, n);
    assert!(!r.coverage.is_degraded(), "{:?}", r.coverage);

    // Clean drain: the protocol shutdown joins everything.
    let resp = client.ask(&Request::Shutdown { id: 5000 });
    assert!(matches!(resp, Response::ShuttingDown { .. }), "{resp:?}");
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Hot reload under load: a hammering client never sees a failed request
/// while same-dir and retargeting reloads swap epochs; racing reloads are
/// single-flight (losers get a typed overloaded reply).
#[test]
fn hot_reload_swaps_epochs_without_failing_in_flight_requests() {
    let (n, p, seed, m) = (32usize, 128usize, 3u64, 2usize);
    let dir = write_synth_store("reload", n, p, seed, 8);
    let dir2 = write_synth_store("reload_grown", 2 * n, p, seed, 8);

    let handle = spawn(quiet_cfg(&dir, &["graddot"])).unwrap();
    let addr = handle.addr();

    let stop = &AtomicBool::new(false);
    std::thread::scope(|s| {
        let hammer = s.spawn(move || {
            let mut c = Client::connect(addr);
            let mut served = 0u64;
            let mut id = 0u64;
            while !stop.load(Ordering::Relaxed) {
                id += 1;
                let resp = c.ask(&score_req(id, "graddot", m));
                let Response::Scores(r) = resp else {
                    panic!("request {id} failed during a reload: {resp:?}");
                };
                // Every reply is self-consistent with the epoch it was
                // scored on: the original store or the grown one.
                match r.epoch {
                    1 | 2 => assert_eq!(r.n, n, "epoch {} row count", r.epoch),
                    _ => assert_eq!(r.n, 2 * n, "epoch {} row count", r.epoch),
                }
                served += 1;
            }
            served
        });
        let mut c = Client::connect(addr);
        std::thread::sleep(Duration::from_millis(30));
        // Same-dir reload: the epoch bumps, nothing in flight fails.
        let resp = c.ask(&Request::Reload {
            id: 9001,
            store: None,
        });
        assert!(matches!(resp, Response::Reloaded { epoch: 2, .. }), "{resp:?}");
        std::thread::sleep(Duration::from_millis(30));
        // Retargeting reload: the daemon swaps to the grown store.
        let resp = c.ask(&Request::Reload {
            id: 9002,
            store: Some(dir2.to_str().unwrap().to_string()),
        });
        let Response::Reloaded { epoch, store, .. } = resp else {
            panic!("retargeting reload failed: {resp:?}");
        };
        assert_eq!(epoch, 3);
        assert!(store.contains("reload_grown"), "{store}");
        std::thread::sleep(Duration::from_millis(30));
        stop.store(true, Ordering::Relaxed);
        let served = hammer.join().unwrap();
        assert!(served > 0, "the hammer must have scored during the reloads");

        // Racing reloads: single-flight. At least one wins; any loser gets
        // a typed overloaded reply, never a broken epoch.
        let outcomes: Vec<Response> = std::thread::scope(|s2| {
            let h1 = s2.spawn(|| {
                Client::connect(addr).ask(&Request::Reload {
                    id: 9003,
                    store: None,
                })
            });
            let h2 = s2.spawn(|| {
                Client::connect(addr).ask(&Request::Reload {
                    id: 9004,
                    store: None,
                })
            });
            vec![h1.join().unwrap(), h2.join().unwrap()]
        });
        let wins = outcomes
            .iter()
            .filter(|r| matches!(r, Response::Reloaded { .. }))
            .count();
        assert!(wins >= 1, "{outcomes:?}");
        for r in &outcomes {
            if let Response::Error { kind, message, .. } = r {
                assert_eq!(*kind, ErrorKind::Overloaded);
                assert!(message.contains("reload"), "{message}");
            }
        }
        let stats = c.stats();
        assert_eq!(stat(&stats, &["epoch"]), (3 + wins) as f64);
        assert_eq!(stat(&stats, &["store", "opens"]), (3 + wins) as f64);
        assert_eq!(stat(&stats, &["reloads"]), (2 + wins) as f64);
        // The current epoch serves the grown store.
        let resp = c.ask(&score_req(9100, "graddot", m));
        let Response::Scores(r) = resp else {
            panic!("post-reload score failed: {resp:?}");
        };
        assert_eq!(r.n, 2 * n);
        let resp = c.ask(&Request::Shutdown { id: 9999 });
        assert!(matches!(resp, Response::ShuttingDown { .. }), "{resp:?}");
    });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// A reload that would change the attribution space (different sketch
/// seed) or point at an unreadable directory is refused descriptively;
/// the current epoch keeps serving untouched.
#[test]
fn reload_refuses_an_incompatible_store_and_keeps_the_current_epoch() {
    let dir = write_synth_store("reload_ok", 32, 64, 3, 8);
    let other_seed = write_synth_store("reload_bad_seed", 32, 64, 4, 8);
    let handle = spawn(quiet_cfg(&dir, &["graddot"])).unwrap();
    let mut client = Client::connect(handle.addr());

    let resp = client.ask(&Request::Reload {
        id: 1,
        store: Some(other_seed.to_str().unwrap().to_string()),
    });
    let Response::Error { kind, message, .. } = resp else {
        panic!("incompatible reload must be refused: {resp:?}");
    };
    assert_eq!(kind, ErrorKind::BadRequest);
    assert!(message.contains("reload refused"), "{message}");
    assert!(message.contains("seed"), "{message}");

    let resp = client.ask(&Request::Reload {
        id: 2,
        store: Some("/nonexistent/grass_store".to_string()),
    });
    let Response::Error { kind, message, .. } = resp else {
        panic!("unreadable reload must be refused: {resp:?}");
    };
    assert_eq!(kind, ErrorKind::BadRequest);
    assert!(message.contains("reload refused"), "{message}");

    let stats = client.stats();
    assert_eq!(stat(&stats, &["epoch"]), 1.0);
    assert_eq!(stat(&stats, &["store", "opens"]), 1.0);
    assert_eq!(stat(&stats, &["reloads"]), 0.0);
    let resp = client.ask(&score_req(3, "graddot", 2));
    assert!(matches!(resp, Response::Scores(_)), "{resp:?}");
    client.ask(&Request::Shutdown { id: 4 });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&other_seed).ok();
}

/// Regression (admission-ticket hygiene): a client that disconnects after
/// sending a request must not leak its admission slot. With a queue bound
/// of 1, any leaked ticket wedges the daemon — every later request would
/// shed overloaded forever.
#[test]
fn mid_request_disconnects_never_leak_admission_slots() {
    let dir = write_synth_store("tickets", 32, 64, 5, 8);
    let handle = spawn(ServeConfig {
        workers: 1,
        max_in_flight: 1,
        ..quiet_cfg(&dir, &["graddot"])
    })
    .unwrap();
    let addr = handle.addr();

    // Three clients send a full score request and vanish before reading
    // the reply; each briefly held the only admission slot.
    for i in 0..3u64 {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream);
        proto::write_frame(&mut w, &score_req(i, "graddot", 1).to_line()).unwrap();
        // dropped: FIN before the reply is written
    }

    let mut client = Client::connect(addr);
    wait_until(
        || stat(&client.stats(), &["admission", "queue_depth"]) == 0.0,
        "admission slots released after mid-request disconnects",
    );
    // The freed slot admits a real request on the first try.
    let resp = client.ask(&score_req(10, "graddot", 2));
    assert!(matches!(resp, Response::Scores(_)), "slot leaked: {resp:?}");
    let stats = client.stats();
    assert_eq!(stat(&stats, &["admission", "queue_depth"]), 0.0);
    assert!(stat(&stats, &["requests", "scored"]) >= 1.0);
    client.ask(&Request::Shutdown { id: 11 });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The real binary under SIGTERM: serve a store, score once over TCP,
/// deliver the signal, and require a graceful drain — the "graceful
/// shutdown (SIGTERM)" banner, the final metrics dump (with its drain
/// report), and exit code 0.
#[test]
fn sigterm_drains_the_real_binary_and_dumps_final_metrics() {
    if !cfg!(unix) {
        return; // signal delivery via kill(1) is Unix-only
    }
    let dir = write_synth_store("sigterm", 32, 64, 5, 8);
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_grass"))
        .args([
            "serve",
            "--store",
            dir.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--scorers",
            "graddot",
            "--drain-ms",
            "2000",
            "--shard-cache",
            "0",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn the grass binary");
    let mut lines = BufReader::new(child.stdout.take().expect("piped stdout"));
    let mut banner = String::new();
    lines.read_line(&mut banner).expect("read the serve banner");
    assert!(banner.contains("serve: listening on"), "{banner}");
    let addr: SocketAddr = banner
        .split_whitespace()
        .nth(3)
        .expect("bound address in the banner")
        .parse()
        .expect("parse the bound address");

    // Liveness over real TCP, then the signal.
    let mut client = Client::connect(addr);
    let resp = client.ask(&score_req(1, "graddot", 2));
    assert!(matches!(resp, Response::Scores(_)), "{resp:?}");
    drop(client);
    let killed = std::process::Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("run kill");
    assert!(killed.success());

    let mut rest = String::new();
    lines.read_to_string(&mut rest).expect("drain the daemon's stdout");
    let status = child.wait().expect("wait for the daemon");
    assert!(
        status.success(),
        "SIGTERM must exit 0, got {status:?}; output:\n{rest}"
    );
    assert!(
        rest.contains("graceful shutdown (SIGTERM)"),
        "drain banner missing from:\n{rest}"
    );
    assert!(rest.contains("\"drain\""), "drain report missing from:\n{rest}");
    std::fs::remove_dir_all(&dir).ok();
}
