//! SIMD dispatch layer, exercised end to end through the public surface:
//! the `set_simd_enabled` escape hatch flips `active_isa()` to "scalar",
//! and every consumer of the dispatched kernels (matmul, FWHT, mask
//! gather, SJLT scatter, payload decode) produces the same numbers on
//! the vector and scalar paths — bitwise for the elementwise kernels,
//! within FMA-reassociation tolerance for the dot-product family.
//!
//! The toggle is process-global, so every toggle-sensitive assertion
//! lives in ONE `#[test]` — the harness runs tests in parallel threads,
//! and a second test flipping the switch mid-measurement would race.

use grass::linalg::fwht::fwht_inplace;
use grass::linalg::matmul::{matmul, matmul_abt};
use grass::linalg::simd;
use grass::sketch::rng::Pcg;
use grass::sketch::sjlt::Sjlt;
use grass::sketch::{Compressor, Scratch};
use grass::store::PayloadDtype;

fn gaussian(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.next_gaussian()).collect()
}

/// Run `f` twice — SIMD enabled, then pinned scalar — and return both
/// results. Always re-enables SIMD on the way out.
fn on_both_paths<R>(mut f: impl FnMut() -> R) -> (R, R) {
    simd::set_simd_enabled(true);
    let vectored = f();
    simd::set_simd_enabled(false);
    let scalar = f();
    simd::set_simd_enabled(true);
    (vectored, scalar)
}

#[test]
fn escape_hatch_pins_scalar_and_paths_agree() {
    // The hatch itself: forcing scalar is observable through the same
    // string `grass serve` stats and BENCH_*.json report, and releasing
    // it restores whatever the host detected.
    let detected = simd::active_isa();
    assert!(
        ["avx2+fma", "neon", "scalar"].contains(&detected),
        "unexpected ISA name {detected}"
    );
    simd::set_simd_enabled(false);
    assert_eq!(simd::active_isa(), "scalar");
    simd::set_simd_enabled(true);
    assert_eq!(simd::active_isa(), detected);

    // Dot-product family (FMA on AVX2): within reassociation tolerance.
    let (m, t, n) = (13, 257, 9);
    let a = gaussian(m * t, 1);
    let b = gaussian(t * n, 2);
    let (vec_c, sc_c) = on_both_paths(|| {
        let mut c = vec![0.0f32; m * n];
        matmul(&a, &b, &mut c, m, t, n);
        c
    });
    for (i, (x, y)) in vec_c.iter().zip(&sc_c).enumerate() {
        let tol = 1e-5 * (1.0 + x.abs().max(y.abs())) * (t as f32).sqrt();
        assert!((x - y).abs() <= tol, "matmul elem {i}: {x} vs {y}");
    }
    let bt = gaussian(n * t, 3);
    let (vec_g, sc_g) = on_both_paths(|| {
        let mut c = vec![0.0f32; m * n];
        matmul_abt(&a, &bt, &mut c, m, t, n);
        c
    });
    for (i, (x, y)) in vec_g.iter().zip(&sc_g).enumerate() {
        let tol = 1e-5 * (1.0 + x.abs().max(y.abs())) * (t as f32).sqrt();
        assert!((x - y).abs() <= tol, "matmul_abt elem {i}: {x} vs {y}");
    }

    // FWHT: butterflies and the 1/√n scale are single-op elementwise
    // kernels on every path — bitwise identical.
    let x0 = gaussian(256, 4);
    let (vec_h, sc_h) = on_both_paths(|| {
        let mut x = x0.clone();
        fwht_inplace(&mut x);
        x
    });
    assert_eq!(vec_h, sc_h, "FWHT diverges between ISA paths");

    // SJLT batch (dense scatter + 1/√s scale): the vector path preserves
    // the scalar ascending-j accumulation order — bitwise identical.
    let (p, k, rows) = (700, 64, 5);
    let sj = Sjlt::new(p, k, 3, 42);
    let gs: Vec<f32> = {
        let mut rng = Pcg::new(5);
        (0..rows * p)
            .map(|_| {
                if rng.next_f32() < 0.4 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect()
    };
    let (vec_s, sc_s) = on_both_paths(|| {
        let mut scratch = Scratch::new();
        let mut out = vec![0.0f32; rows * k];
        sj.compress_batch_with(&gs, rows, &mut out, &mut scratch);
        out
    });
    assert_eq!(vec_s, sc_s, "SJLT batch diverges between ISA paths");

    // Mask gather through the single-row entry point (vgatherdps on
    // AVX2): one multiply per element — bitwise identical.
    let mask = grass::sketch::mask::RandomMask::new(p, 96, 7);
    let (vec_m, sc_m) = on_both_paths(|| mask.compress(&gs[..p]));
    assert_eq!(vec_m, sc_m, "mask gather diverges between ISA paths");

    // Payload decoders (f16 / bf16 / int8): exact converts on every path.
    let vals = gaussian(6 * 50, 8);
    for dt in [PayloadDtype::F16, PayloadDtype::Bf16, PayloadDtype::Int8] {
        let mut enc = Vec::new();
        for row in vals.chunks(50) {
            dt.encode_row(row, &mut enc);
        }
        let (vec_d, sc_d) = on_both_paths(|| {
            let mut out = vec![0.0f32; vals.len()];
            dt.decode_rows(&enc, 50, 6, &mut out);
            out
        });
        assert_eq!(vec_d, sc_d, "{dt} decode diverges between ISA paths");
    }
}
