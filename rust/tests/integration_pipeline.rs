//! Integration: the staged cache pipeline (batcher → PJRT grad workers →
//! compress workers → reordering store writer) against real artifacts.

use grass::coordinator::{pipeline::Source, CachePipeline, CompressorBank, PipelineConfig};
use grass::data::corpus::MusicEvents;
use grass::data::images::SynthDigits;
use grass::runtime::{Arg, Runtime};
use grass::sketch::{Compressor, MaskKind, MethodSpec};
use grass::store::StoreReader;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime load"))
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("grass_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn flat_pipeline_writes_ordered_store_matching_direct_path() {
    let Some(rt) = runtime() else { return };
    let model = "mlp";
    let p = rt.manifest.model(model).unwrap().p;
    let n = 70; // not a multiple of the batch size: exercises padding
    let data = SynthDigits::generate(n, 9);
    let spec = MethodSpec::Sjlt { k: 128, s: 1 };
    let seed = 31;

    let params = rt
        .executable("mlp_init")
        .unwrap()
        .run(&[Arg::ScalarI32(3)])
        .unwrap()
        .remove(0)
        .data;

    let dir = tmpdir("flat");
    let pipeline = CachePipeline::new(
        &rt,
        model,
        params.clone(),
        PipelineConfig {
            grad_workers: 2,
            compress_workers: 2,
            queue_depth: 2,
            shard_rows: 32, // force multiple shards
            ..PipelineConfig::default()
        },
    );
    let bank = CompressorBank::Flat(spec.build(p, seed));
    let meta = pipeline
        .run_flat(
            &Source::Labelled(&data),
            &bank,
            &dir,
            &spec.spec_string(),
            seed,
        )
        .unwrap();
    assert_eq!(meta.n, n);
    assert_eq!(meta.k, 128);

    // Cross-check rows against the sequential (no-pipeline) path.
    let reader = StoreReader::open(&dir).unwrap();
    assert_eq!(reader.meta.method, spec.spec_string());
    assert!(reader.num_shards() >= 2);
    let all = reader.read_all().unwrap();

    let trainer = grass::eval::retrain::Trainer::new(&rt, model).unwrap();
    let idx: Vec<usize> = (0..n).collect();
    let grads = trainer
        .grads(
            &params,
            &grass::eval::retrain::TaskData::Labelled(&data),
            &idx,
        )
        .unwrap();
    let c = spec.build(p, seed);
    for i in 0..n {
        let want = c.compress(&grads[i * p..(i + 1) * p]);
        let got = &all[i * 128..(i + 1) * 128];
        for j in 0..128 {
            assert!(
                (want[j] - got[j]).abs() < 1e-4 * (1.0 + want[j].abs()),
                "row {i} col {j}: {} vs {}",
                want[j],
                got[j]
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    let report = pipeline.metrics.report();
    assert!(report.contains(&format!("rows_written={n}")), "{report}");
}

#[test]
fn factored_pipeline_runs_music_hooks() {
    let Some(rt) = runtime() else { return };
    let model = "music";
    let meta = rt.manifest.model(model).unwrap().clone();
    let seq = meta.seq.unwrap();
    let n = 20;
    let data = MusicEvents::generate(n, seq, 5);
    let params = rt
        .executable("music_init")
        .unwrap()
        .run(&[Arg::ScalarI32(1)])
        .unwrap()
        .remove(0)
        .data;

    let kl = 16usize;
    let spec = MethodSpec::FactGrass {
        k: kl,
        k_in: 8,
        k_out: 8,
        mask: MaskKind::Random,
    };
    let bank = spec.build_bank(&meta.shapes(), 0).unwrap();
    let total_k = bank.output_dim();

    let dir = tmpdir("fact");
    let pipeline = CachePipeline::new(&rt, model, params, PipelineConfig::default());
    let meta_store = pipeline
        .run(
            &Source::Sequences(&data),
            &bank,
            &dir,
            &spec.spec_string(),
            0,
        )
        .unwrap();
    assert_eq!(meta_store.n, n);
    assert_eq!(meta_store.k, total_k);
    // The store is self-describing: a matching spec opens, a mismatched
    // seed is rejected.
    let reader = StoreReader::open_checked(&dir, &spec, 0).unwrap();
    assert!(StoreReader::open_checked(&dir, &spec, 1).is_err());
    let all = reader.read_all().unwrap();
    assert_eq!(all.len(), n * total_k);
    // compressed grads must be non-degenerate
    let energy: f32 = all.iter().map(|v| v * v).sum();
    assert!(energy > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}
