//! Integration: the redesigned spec-driven API end to end, with no PJRT
//! runtime anywhere — synthetic gradients → `MethodSpec::build_bank` →
//! store → `StoreReader::open_checked` → `attrib::from_spec` →
//! cache/attribute/self-influence, plus the `grass cache`/`grass
//! attribute` CLI smoke on the same path.

use grass::attrib::{from_spec, AttributionSpec, Attributor};
use grass::data::synthgrad::{SYNTH_CLASSES, SYNTH_SEQ, SynthGrads, SynthHooks};
use grass::models::shapes::ModelShapes;
use grass::sketch::{MaskKind, MethodSpec, Scratch};
use grass::store::{StoreMeta, StoreReader, StoreWriter, DEFAULT_SHARD_ROWS};
use std::path::PathBuf;
use std::process::Command;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_attr_api_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Cache a flat synthetic store; returns (dir, spec, seed, n, p).
fn write_flat_store(tag: &str, n: usize, p: usize, seed: u64) -> (PathBuf, MethodSpec) {
    let dir = tmpdir(tag);
    let spec = MethodSpec::Sjlt { k: 64, s: 1 };
    let shapes = ModelShapes::flat(p);
    let bank = spec.build_bank(&shapes, seed).unwrap();
    let c = bank.as_flat().unwrap();
    let meta = StoreMeta::describe(&spec, seed, "synth", &shapes, DEFAULT_SHARD_ROWS).unwrap();
    let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    let src = SynthGrads::new(p, seed);
    let rows = src.rows(0, n);
    let mut out = vec![0.0f32; n * c.output_dim()];
    let mut scratch = Scratch::new();
    c.compress_batch_with(&rows, n, &mut out, &mut scratch);
    w.push_batch(&out).unwrap();
    w.finish().unwrap();
    (dir, spec)
}

#[test]
fn spec_store_attributor_end_to_end_with_class_signal() {
    let (n, p, seed) = (64usize, 512usize, 9u64);
    let (dir, spec) = write_flat_store("flat", n, p, seed);

    // Validated open + bank reconstruction purely from store metadata.
    let reader = StoreReader::open_checked(&dir, &spec, seed).unwrap();
    assert_eq!(reader.meta.spec().unwrap(), spec);
    let bank = spec.build_bank(&reader.meta.shapes(), reader.meta.seed).unwrap();
    assert_eq!(bank.output_dim(), reader.meta.k);

    // Wrong spec or seed never reaches scoring.
    assert!(StoreReader::open_checked(&dir, &MethodSpec::Gauss { k: 64 }, seed).is_err());
    assert!(StoreReader::open_checked(&dir, &spec, seed + 1).is_err());

    // Registry-built influence scorer over the store. Generous damping so
    // the preconditioner does not whiten away the planted class structure
    // this test asserts on (λ → ∞ recovers GradDot direction).
    let mut aspec = AttributionSpec::new("if", spec.clone(), seed);
    aspec.damping = 10.0;
    let mut attributor: Box<dyn Attributor> = from_spec(&aspec).unwrap();
    let meta = attributor.cache_store(&reader).unwrap();
    assert_eq!(meta.n, n);

    // Compress fresh synthetic queries with the reconstructed bank.
    let src = SynthGrads::new(p, seed);
    let m = 8;
    let (raw, classes) = src.queries(m);
    let c = bank.as_flat().unwrap();
    let mut q = vec![0.0f32; m * c.output_dim()];
    c.compress_batch(&raw, m, &mut q);
    let scores = attributor.attribute(&q, m).unwrap();
    assert_eq!((scores.m, scores.n), (m, n));

    // The planted class structure must survive compression + scoring:
    // top-4 rows per query are enriched in the query's class.
    let mut hits = 0usize;
    for (qi, &class) in classes.iter().enumerate() {
        hits += scores
            .top_k(qi, 4)
            .iter()
            .filter(|(i, _)| i % SYNTH_CLASSES == class)
            .count();
    }
    let frac = hits as f64 / (m * 4) as f64;
    assert!(
        frac > 0.5,
        "class enrichment too weak: {frac:.2} (chance = {:.2})",
        1.0 / SYNTH_CLASSES as f64
    );

    // Self-influence is defined and positive under the PD preconditioner.
    let si = attributor.self_influence().unwrap();
    assert_eq!(si.len(), n);
    assert!(si.iter().all(|&v| v > 0.0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn factorized_store_blockwise_scorer_end_to_end() {
    let dir = tmpdir("fact");
    let (n, seed) = (40usize, 4u64);
    let spec = MethodSpec::FactGrass {
        k: 16,
        k_in: 12,
        k_out: 12,
        mask: MaskKind::Random,
    };
    let layers = vec![(48usize, 32usize), (32usize, 48usize)];
    let shapes = ModelShapes::factored(layers.clone());
    let bank = spec.build_bank(&shapes, seed).unwrap();
    let cs = bank.as_factored().unwrap();
    let k = bank.output_dim();
    assert_eq!(k, 32); // 2 layers × k_l

    let meta = StoreMeta::describe(&spec, seed, "synth", &shapes, DEFAULT_SHARD_ROWS).unwrap();
    let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    let hooks = SynthHooks::new(layers, seed);
    let mut scratch = Scratch::new();
    let mut row = vec![0.0f32; k];
    for i in 0..n {
        let sample = hooks.sample(i);
        let mut off = 0;
        for (li, c) in cs.iter().enumerate() {
            let (x, dy) = &sample[li];
            c.compress_batch_with(1, SYNTH_SEQ, x, dy, &mut row, k, off, &mut scratch);
            off += c.output_dim();
        }
        w.push(&row).unwrap();
    }
    w.finish().unwrap();

    // Reopen through validation, rebuild the bank, score blockwise.
    let reader = StoreReader::open_checked(&dir, &spec, seed).unwrap();
    assert_eq!(reader.meta.shapes(), shapes);
    let bank2 = spec.build_bank(&reader.meta.shapes(), seed).unwrap();
    let mut aspec = AttributionSpec::new("blockwise", spec.clone(), seed);
    aspec.damping = 0.1;
    aspec.layout = bank2.layer_dims();
    assert_eq!(aspec.total_dim(), k);
    let mut attributor: Box<dyn Attributor> = from_spec(&aspec).unwrap();
    attributor.cache_store(&reader).unwrap();

    let m = 4;
    let cs2 = bank2.as_factored().unwrap();
    let mut q = vec![0.0f32; m * k];
    for qi in 0..m {
        let (sample, _) = hooks.query(qi);
        let mut off = 0;
        for (li, c) in cs2.iter().enumerate() {
            let (x, dy) = &sample[li];
            c.compress_batch_with(
                1,
                SYNTH_SEQ,
                x,
                dy,
                &mut q[qi * k..(qi + 1) * k],
                k,
                off,
                &mut scratch,
            );
            off += c.output_dim();
        }
    }
    let scores = attributor.attribute(&q, m).unwrap();
    assert_eq!((scores.m, scores.n), (m, n));
    assert!(scores.scores.iter().any(|&v| v != 0.0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_cache_then_attribute_smoke() {
    let dir = tmpdir("cli");
    let dir_s = dir.to_str().unwrap();
    let exe = env!("CARGO_BIN_EXE_grass");

    // cache → a factorized synthetic store, entirely runtime-free.
    let out = Command::new(exe)
        .args([
            "cache",
            "--model",
            "synth",
            "--method",
            "factgrass:kin=8,kout=8,kl=16",
            "--n",
            "48",
            "--seed",
            "5",
            "--store",
            dir_s,
        ])
        .output()
        .expect("spawn grass cache");
    assert!(
        out.status.success(),
        "cache failed: {}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // attribute with the influence scorer, rebuilt from store metadata.
    let out = Command::new(exe)
        .args([
            "attribute", "--store", dir_s, "--queries", "4", "--scorer", "if",
            "--self-influence",
        ])
        .output()
        .expect("spawn grass attribute");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "attribute failed: {stdout}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("attributed 4 queries"), "{stdout}");
    assert!(stdout.contains("self-influence"), "{stdout}");

    // A mismatched --method request is rejected, not silently scored.
    let out = Command::new(exe)
        .args([
            "attribute",
            "--store",
            dir_s,
            "--queries",
            "2",
            "--method",
            "logra:kin=4,kout=4",
        ])
        .output()
        .expect("spawn grass attribute mismatch");
    assert!(
        !out.status.success(),
        "mismatched method must fail: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("factgrass"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
