//! Integration: the redesigned spec-driven API end to end, with no PJRT
//! runtime anywhere — synthetic gradients → `MethodSpec::build_bank` →
//! store → `StoreReader::open_checked` → `attrib::from_spec` →
//! cache/attribute/self-influence, plus the `grass cache`/`grass
//! attribute` CLI smoke on the same path.

use grass::attrib::{from_spec, AttributionSpec, Attributor, InfluenceEngine, StreamOpts};
use grass::data::queries::synth_queries;
use grass::data::synthgrad::{SYNTH_CLASSES, SYNTH_SEQ, SynthGrads, SynthHooks};
use grass::models::shapes::ModelShapes;
use grass::sketch::rng::Pcg;
use grass::sketch::{MaskKind, MethodSpec, Scratch};
use grass::store::{RowGroups, StoreMeta, StoreReader, StoreWriter, DEFAULT_SHARD_ROWS};
use std::path::PathBuf;
use std::process::Command;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_attr_api_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Cache a flat synthetic store; returns (dir, spec, seed, n, p).
fn write_flat_store(tag: &str, n: usize, p: usize, seed: u64) -> (PathBuf, MethodSpec) {
    let dir = tmpdir(tag);
    let spec = MethodSpec::Sjlt { k: 64, s: 1 };
    let shapes = ModelShapes::flat(p);
    let bank = spec.build_bank(&shapes, seed).unwrap();
    let c = bank.as_flat().unwrap();
    let meta = StoreMeta::describe(&spec, seed, "synth", &shapes, DEFAULT_SHARD_ROWS).unwrap();
    let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    let src = SynthGrads::new(p, seed);
    let rows = src.rows(0, n);
    let mut out = vec![0.0f32; n * c.output_dim()];
    let mut scratch = Scratch::new();
    c.compress_batch_with(&rows, n, &mut out, &mut scratch);
    w.push_batch(&out).unwrap();
    w.finish().unwrap();
    (dir, spec)
}

#[test]
fn spec_store_attributor_end_to_end_with_class_signal() {
    let (n, p, seed) = (64usize, 512usize, 9u64);
    let (dir, spec) = write_flat_store("flat", n, p, seed);

    // Validated open + bank reconstruction purely from store metadata.
    let reader = StoreReader::open_checked(&dir, &spec, seed).unwrap();
    assert_eq!(reader.meta.spec().unwrap(), spec);
    let bank = spec.build_bank(&reader.meta.shapes(), reader.meta.seed).unwrap();
    assert_eq!(bank.output_dim(), reader.meta.k);

    // Wrong spec or seed never reaches scoring.
    assert!(StoreReader::open_checked(&dir, &MethodSpec::Gauss { k: 64 }, seed).is_err());
    assert!(StoreReader::open_checked(&dir, &spec, seed + 1).is_err());

    // Registry-built influence scorer over the store. Generous damping so
    // the preconditioner does not whiten away the planted class structure
    // this test asserts on (λ → ∞ recovers GradDot direction).
    let mut aspec = AttributionSpec::new("if", spec.clone(), seed);
    aspec.damping = 10.0;
    let mut attributor: Box<dyn Attributor> = from_spec(&aspec).unwrap();
    let meta = attributor.cache_store(&reader).unwrap();
    assert_eq!(meta.n, n);

    // Compress fresh synthetic queries with the reconstructed bank via the
    // shared helper — the same path `grass attribute`, `grass query`, and
    // the serving daemon use, so parity tests compare identical sketches.
    let m = 8;
    let (q, classes) = synth_queries(&reader.meta, &bank, m).unwrap();
    let scores = attributor.attribute(&q, m).unwrap();
    assert_eq!((scores.m, scores.n), (m, n));

    // The planted class structure must survive compression + scoring:
    // top-4 rows per query are enriched in the query's class.
    let mut hits = 0usize;
    for (qi, &class) in classes.iter().enumerate() {
        hits += scores
            .top_k(qi, 4)
            .iter()
            .filter(|(i, _)| i % SYNTH_CLASSES == class)
            .count();
    }
    let frac = hits as f64 / (m * 4) as f64;
    assert!(
        frac > 0.5,
        "class enrichment too weak: {frac:.2} (chance = {:.2})",
        1.0 / SYNTH_CLASSES as f64
    );

    // Self-influence is defined and positive under the PD preconditioner.
    let si = attributor.self_influence().unwrap();
    assert_eq!(si.len(), n);
    assert!(si.iter().all(|&v| v > 0.0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn factorized_store_blockwise_scorer_end_to_end() {
    let dir = tmpdir("fact");
    let (n, seed) = (40usize, 4u64);
    let spec = MethodSpec::FactGrass {
        k: 16,
        k_in: 12,
        k_out: 12,
        mask: MaskKind::Random,
    };
    let layers = vec![(48usize, 32usize), (32usize, 48usize)];
    let shapes = ModelShapes::factored(layers.clone());
    let bank = spec.build_bank(&shapes, seed).unwrap();
    let cs = bank.as_factored().unwrap();
    let k = bank.output_dim();
    assert_eq!(k, 32); // 2 layers × k_l

    let meta = StoreMeta::describe(&spec, seed, "synth", &shapes, DEFAULT_SHARD_ROWS).unwrap();
    let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    let hooks = SynthHooks::new(layers, seed);
    let mut scratch = Scratch::new();
    let mut row = vec![0.0f32; k];
    for i in 0..n {
        let sample = hooks.sample(i);
        let mut off = 0;
        for (li, c) in cs.iter().enumerate() {
            let (x, dy) = &sample[li];
            c.compress_batch_with(1, SYNTH_SEQ, x, dy, &mut row, k, off, &mut scratch);
            off += c.output_dim();
        }
        w.push(&row).unwrap();
    }
    w.finish().unwrap();

    // Reopen through validation, rebuild the bank, score blockwise.
    let reader = StoreReader::open_checked(&dir, &spec, seed).unwrap();
    assert_eq!(reader.meta.shapes(), shapes);
    let bank2 = spec.build_bank(&reader.meta.shapes(), seed).unwrap();
    let mut aspec = AttributionSpec::new("blockwise", spec.clone(), seed);
    aspec.damping = 0.1;
    aspec.layout = bank2.layer_dims();
    assert_eq!(aspec.total_dim(), k);
    let mut attributor: Box<dyn Attributor> = from_spec(&aspec).unwrap();
    attributor.cache_store(&reader).unwrap();

    // Factored query sketches through the same shared helper the CLI and
    // daemon use (SynthHooks regenerated from store-recorded layer dims).
    let m = 4;
    let (q, _classes) = synth_queries(&reader.meta, &bank2, m).unwrap();
    assert_eq!(q.len(), m * k);
    let scores = attributor.attribute(&q, m).unwrap();
    assert_eq!((scores.m, scores.n), (m, n));
    assert!(scores.scores.iter().any(|&v| v != 0.0));
    std::fs::remove_dir_all(&dir).ok();
}

fn gaussian(rows: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..rows * k).map(|_| rng.next_gaussian()).collect()
}

/// Write a raw `n × k` matrix as a store with deliberately ragged shards.
fn write_raw_store(dir: &PathBuf, rows: &[f32], k: usize, shard_rows: usize) {
    let mut w = StoreWriter::create(dir, k, "raw", 0, shard_rows).unwrap();
    w.push_batch(rows).unwrap();
    w.finish().unwrap();
}

/// The tentpole contract: out-of-core streamed ingest + scoring produces
/// the same scores as the in-memory path for every scorer in the registry,
/// to ≤ 1e-5 relative tolerance, even with a budget so small that every
/// block is two rows and three workers interleave.
#[test]
fn streamed_attribution_matches_in_memory_for_all_five_scorers() {
    let (n, k, m) = (96usize, 32usize, 6usize);
    let dir1 = tmpdir("stream_eq_ck1");
    let dir2 = tmpdir("stream_eq_ck2");
    let g1 = gaussian(n, k, 21);
    let g2 = gaussian(n, k, 22);
    write_raw_store(&dir1, &g1, k, 7); // 7-row shards: ragged final shard
    write_raw_store(&dir2, &g2, k, 7);
    let r1 = StoreReader::open(&dir1).unwrap();
    let r2 = StoreReader::open(&dir2).unwrap();
    let queries = gaussian(m, k, 23);
    // 3 workers × 2-row chunks × k × 4 B × 2 buffers — far below the
    // store's n·k·4 footprint, forcing dozens of streamed blocks.
    let opts = StreamOpts {
        mem_budget: 3 * 2 * k * 4 * 2,
        workers: 3,
        ..StreamOpts::default()
    };
    assert_eq!(opts.chunk_rows(k), 2);
    assert!(opts.resident_bytes(k) < n * k * 4);

    for scorer in ["if", "graddot", "trak", "tracin", "blockwise"] {
        let mut aspec = AttributionSpec::new(scorer, MethodSpec::RandomMask { k }, 0);
        aspec.damping = 0.05;
        if scorer == "blockwise" {
            aspec.layout = vec![12, 20]; // two uneven FIM blocks
        }
        let ensemble = matches!(scorer, "trak" | "tracin");

        let mut mem = from_spec(&aspec).unwrap();
        mem.cache(&g1, n).unwrap();
        if ensemble {
            mem.cache(&g2, n).unwrap();
        }

        let mut streamed = from_spec(&aspec).unwrap();
        streamed.cache_stream(&r1, &opts).unwrap();
        if ensemble {
            streamed.cache_stream(&r2, &opts).unwrap();
        }

        let sm = mem.attribute(&queries, m).unwrap();
        let ss = streamed.attribute(&queries, m).unwrap();
        assert_eq!((ss.m, ss.n), (sm.m, sm.n), "{scorer} shape");
        for i in 0..m * n {
            let (a, b) = (ss.scores[i], sm.scores[i]);
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "{scorer} score {i}: streamed {a} vs in-memory {b}"
            );
        }
        let si_s = streamed.self_influence().unwrap();
        let si_m = mem.self_influence().unwrap();
        assert_eq!(si_s.len(), si_m.len(), "{scorer} self-influence len");
        for i in 0..n {
            assert!(
                (si_s[i] - si_m[i]).abs() <= 1e-5 * (1.0 + si_m[i].abs()),
                "{scorer} self-influence {i}: {} vs {}",
                si_s[i],
                si_m[i]
            );
        }
    }
    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// GGDA-style grouped scoring: group columns equal the sum of their member
/// rows' scores, for both the raw-GradDot family and the preconditioned
/// influence family (whose FIM is fit on the selected rows only).
#[test]
fn grouped_streaming_aggregates_member_rows() {
    let (n, k, m) = (40usize, 16usize, 3usize);
    let dir = tmpdir("stream_groups");
    let g = gaussian(n, k, 31);
    write_raw_store(&dir, &g, k, 7);
    let reader = StoreReader::open(&dir).unwrap();
    let queries = gaussian(m, k, 32);
    // Three groups with a deliberate gap: rows 25..30 are excluded.
    let groups = RowGroups::parse("0..10,10..25,30..40").unwrap();
    let n_groups = groups.len();
    let opts = StreamOpts {
        mem_budget: 2 * 3 * k * 4 * 2,
        workers: 2,
        groups: Some(groups.clone()),
        ..StreamOpts::default()
    };

    // GradDot: group score is the sum of member dot products.
    let mut gd = from_spec(&AttributionSpec::new(
        "graddot",
        MethodSpec::RandomMask { k },
        0,
    ))
    .unwrap();
    gd.cache_stream(&reader, &opts).unwrap();
    let s = gd.attribute(&queries, m).unwrap();
    assert_eq!((s.m, s.n), (m, n_groups));
    for (qi, q) in queries.chunks(k).enumerate() {
        for (gi, r) in groups.ranges.iter().enumerate() {
            let want: f32 = r
                .clone()
                .map(|i| {
                    q.iter()
                        .zip(&g[i * k..(i + 1) * k])
                        .map(|(a, b)| a * b)
                        .sum::<f32>()
                })
                .sum();
            let got = s.scores[qi * n_groups + gi];
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "graddot group ({qi},{gi}): {got} vs {want}"
            );
        }
    }
    // Grouped self-influence sums the members' norms.
    let si = gd.self_influence().unwrap();
    assert_eq!(si.len(), n_groups);
    for (gi, r) in groups.ranges.iter().enumerate() {
        let want: f32 = r
            .clone()
            .map(|i| g[i * k..(i + 1) * k].iter().map(|v| v * v).sum::<f32>())
            .sum();
        assert!((si[gi] - want).abs() <= 1e-4 * (1.0 + want.abs()), "group {gi}");
    }

    // Influence: equivalent to the in-memory engine cached on the selected
    // rows (in selection order), with per-group column sums.
    let sel: Vec<f32> = groups
        .ranges
        .iter()
        .flat_map(|r| r.clone())
        .flat_map(|i| g[i * k..(i + 1) * k].to_vec())
        .collect();
    let n_sel = groups.total_rows();
    let want_rows = InfluenceEngine::new(k, 0.1)
        .attribute(&sel, n_sel, &queries, m)
        .unwrap();
    let mut st = InfluenceEngine::new(k, 0.1);
    st.cache_stream(&reader, &opts).unwrap();
    let got = Attributor::attribute(&st, &queries, m).unwrap();
    assert_eq!((got.m, got.n), (m, n_groups));
    for qi in 0..m {
        let mut off = 0usize;
        for (gi, r) in groups.ranges.iter().enumerate() {
            let len = r.end - r.start;
            let want: f32 = want_rows[qi * n_sel + off..qi * n_sel + off + len]
                .iter()
                .sum();
            off += len;
            let v = got.scores[qi * n_groups + gi];
            assert!(
                (v - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "influence group ({qi},{gi}): {v} vs {want}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_cache_then_attribute_smoke() {
    let dir = tmpdir("cli");
    let dir_s = dir.to_str().unwrap();
    let exe = env!("CARGO_BIN_EXE_grass");

    // cache → a factorized synthetic store, entirely runtime-free.
    let out = Command::new(exe)
        .args([
            "cache",
            "--model",
            "synth",
            "--method",
            "factgrass:kin=8,kout=8,kl=16",
            "--n",
            "48",
            "--seed",
            "5",
            "--store",
            dir_s,
        ])
        .output()
        .expect("spawn grass cache");
    assert!(
        out.status.success(),
        "cache failed: {}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // attribute with the influence scorer, rebuilt from store metadata.
    let out = Command::new(exe)
        .args([
            "attribute", "--store", dir_s, "--queries", "4", "--scorer", "if",
            "--self-influence",
        ])
        .output()
        .expect("spawn grass attribute");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "attribute failed: {stdout}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("attributed 4 queries"), "{stdout}");
    assert!(stdout.contains("self-influence"), "{stdout}");

    // Streaming knobs: a deliberately tiny budget, pinned workers, and
    // block row-grouping still attribute (48 rows → 3 groups of 16).
    let out = Command::new(exe)
        .args([
            "attribute",
            "--store",
            dir_s,
            "--queries",
            "2",
            "--scorer",
            "graddot",
            "--mem-budget",
            "4K",
            "--workers",
            "2",
            "--row-groups",
            "block=16",
        ])
        .output()
        .expect("spawn grass attribute streamed");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "streamed attribute failed: {stdout}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("3 score columns"), "{stdout}");

    // An out-of-range row-group list is a descriptive error.
    let out = Command::new(exe)
        .args([
            "attribute", "--store", dir_s, "--queries", "2", "--row-groups", "0..999",
        ])
        .output()
        .expect("spawn grass attribute bad groups");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("48"), "{err}");

    // A mismatched --method request is rejected, not silently scored.
    let out = Command::new(exe)
        .args([
            "attribute",
            "--store",
            dir_s,
            "--queries",
            "2",
            "--method",
            "logra:kin=4,kout=4",
        ])
        .output()
        .expect("spawn grass attribute mismatch");
    assert!(
        !out.status.success(),
        "mismatched method must fail: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("factgrass"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
