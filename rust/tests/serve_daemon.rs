//! Integration: the serving daemon end to end over real TCP — served
//! scores match the batch `Attributor` path bit-for-bit (modulo JSON f64
//! round-trip, which is exact), concurrent clients each get correct
//! replies, admission control and deadlines shed with typed errors while
//! the daemon keeps serving, a corrupt shard degrades one response's
//! coverage instead of killing the process, and the `stats` request proves
//! hot-state reuse (`store.opens == 1`, constant `fim_rows`).

use grass::attrib::{from_spec, AttributionSpec, Attributor, PrecondArtifact, PrecondSpec, StreamOpts};
use grass::data::queries::synth_queries;
use grass::data::synthgrad::SynthGrads;
use grass::models::shapes::ModelShapes;
use grass::serve::proto::{self, ScoreRequest};
use grass::serve::{spawn, ErrorKind, QueryPayload, Request, Response, ServeConfig};
use grass::sketch::{MethodSpec, Scratch};
use grass::store::{PayloadDtype, StoreMeta, StoreReader, StoreWriter};
use grass::util::json::Json;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Cache a flat synthetic store the daemon can serve (model `"synth"`,
/// geometry recorded, compressed through the spec's bank).
fn write_synth_store(tag: &str, n: usize, p: usize, seed: u64, shard_rows: usize) -> PathBuf {
    write_synth_store_dtype(tag, n, p, seed, shard_rows, PayloadDtype::F32)
}

/// Same store, but committed under an explicit payload codec.
fn write_synth_store_dtype(
    tag: &str,
    n: usize,
    p: usize,
    seed: u64,
    shard_rows: usize,
    dtype: PayloadDtype,
) -> PathBuf {
    let dir = tmpdir(tag);
    let spec = MethodSpec::Sjlt { k: 32, s: 1 };
    let shapes = ModelShapes::flat(p);
    let bank = spec.build_bank(&shapes, seed).unwrap();
    let c = bank.as_flat().unwrap();
    let mut meta = StoreMeta::describe(&spec, seed, "synth", &shapes, shard_rows).unwrap();
    meta.dtype = dtype;
    let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    let rows = SynthGrads::new(p, seed).rows(0, n);
    let mut out = vec![0.0f32; n * c.output_dim()];
    let mut scratch = Scratch::new();
    c.compress_batch_with(&rows, n, &mut out, &mut scratch);
    w.push_batch(&out).unwrap();
    w.finish().unwrap();
    dir
}

fn quiet_cfg(dir: &PathBuf, scorers: &[&str]) -> ServeConfig {
    ServeConfig {
        store: dir.clone(),
        scorers: scorers.iter().map(|s| s.to_string()).collect(),
        quiet: true,
        ..ServeConfig::default()
    }
}

/// One NDJSON client connection: send a request frame, read one reply.
struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self {
            reader,
            writer: BufWriter::new(stream),
        }
    }

    fn ask(&mut self, req: &Request) -> Response {
        proto::write_frame(&mut self.writer, &req.to_line()).expect("write frame");
        let frame = proto::read_frame(&mut self.reader)
            .expect("read frame")
            .expect("daemon closed the connection without replying");
        Response::from_json(&frame).expect("parse response")
    }
}

fn score_req(id: u64, scorer: &str, m: usize) -> Request {
    Request::Score(ScoreRequest {
        id,
        scorer: scorer.to_string(),
        top_k: 3,
        include_scores: true,
        self_influence: true,
        deadline_ms: None,
        queries: QueryPayload::Synth { m },
    })
}

fn stat(stats: &Json, path: &[&str]) -> f64 {
    let mut v = stats;
    for key in path {
        v = v.get(key).unwrap_or_else(|| panic!("stats missing {path:?}"));
    }
    v.as_f64().unwrap_or_else(|| panic!("stats {path:?} is not a number"))
}

/// The parity gate: for `if` (with a persisted solver artifact) and
/// `graddot`, the daemon's served scores, self-influence, and top-k match
/// a batch engine built the same way, to ≤ 1e-6 — and the `stats` request
/// proves repeat queries reuse the hot state.
#[test]
fn served_scores_match_batch_attribution_and_reuse_hot_state() {
    let (n, p, seed, m) = (48usize, 256usize, 9u64, 4usize);
    let dir = write_synth_store("parity", n, p, seed, 16);

    // Fit + persist the solver artifact the daemon consumes at startup.
    {
        let reader = StoreReader::open(&dir).unwrap();
        let pspec = PrecondSpec::default_for_scorer("if", 1e-3);
        assert!(pspec.needs_fim());
        let layout = pspec.layout_for(reader.meta.k, &[]);
        let artifact = PrecondArtifact::fit(&reader, &StreamOpts::default(), &layout).unwrap();
        artifact.save(&dir).unwrap();
    }

    let reader = StoreReader::open(&dir).unwrap();
    let spec = reader.meta.spec().unwrap();
    let bank = spec.build_bank(&reader.meta.shapes(), seed).unwrap();
    let artifact = PrecondArtifact::load_if_present(&dir).unwrap().map(Arc::new);
    assert!(artifact.is_some(), "fitted artifact must load back");
    let (q, classes) = synth_queries(&reader.meta, &bank, m).unwrap();

    let handle = spawn(quiet_cfg(&dir, &["if", "graddot"])).unwrap();
    let mut client = Client::connect(handle.addr());

    for (ri, scorer) in ["if", "graddot"].iter().enumerate() {
        // Batch reference: the same construction the daemon performs —
        // same spec, damping, preconditioner default, artifact, workers.
        let pspec = PrecondSpec::default_for_scorer(scorer, 1e-3);
        let mut opts = StreamOpts {
            workers: 2,
            ..StreamOpts::default()
        };
        if pspec.needs_fim() {
            opts.artifact = artifact.clone();
        }
        let mut aspec = AttributionSpec::new(scorer, spec.clone(), seed);
        aspec.layout = bank.layer_dims();
        aspec.precond = Some(pspec);
        let mut engine = from_spec(&aspec).unwrap();
        engine.cache_stream(&reader, &opts).unwrap();
        let want = engine.attribute(&q, m).unwrap();
        let want_si = engine.self_influence().unwrap();

        let resp = client.ask(&score_req(10 + ri as u64, scorer, m));
        let Response::Scores(r) = resp else {
            panic!("{scorer}: expected scores, got {resp:?}");
        };
        assert_eq!((r.m, r.n), (m, n), "{scorer} shape");
        assert_eq!(r.scorer, *scorer);
        assert_eq!(r.classes.as_ref(), Some(&classes), "{scorer} classes");
        assert!(!r.coverage.is_degraded(), "{scorer}: {:?}", r.coverage);
        assert_eq!(r.coverage.rows_scored, n);

        let got = r.scores.as_ref().expect("include_scores was set");
        assert_eq!(got.len(), m * n);
        for i in 0..m * n {
            let (a, b) = (got[i], want.scores[i]);
            assert!(
                (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
                "{scorer} score {i}: served {a} vs batch {b}"
            );
        }
        let got_si = r.self_influence.as_ref().expect("self_influence was set");
        assert_eq!(got_si.len(), n);
        for i in 0..n {
            assert!(
                (got_si[i] - want_si[i]).abs() <= 1e-6 * (1.0 + want_si[i].abs()),
                "{scorer} self-influence {i}: served {} vs batch {}",
                got_si[i],
                want_si[i]
            );
        }
        assert_eq!(r.top.len(), m);
        for (qi, top) in r.top.iter().enumerate() {
            let want_top = want.top_k(qi, 3);
            assert_eq!(top.len(), want_top.len(), "{scorer} query {qi} top len");
            for ((gi, gs), (wi, ws)) in top.iter().zip(&want_top) {
                assert_eq!(gi, wi, "{scorer} query {qi} top index");
                assert!((gs - ws).abs() <= 1e-6 * (1.0 + ws.abs()));
            }
        }
    }

    // Hot-state evidence: one store open, artifact consumed — the `if`
    // engine streamed 0 FIM rows because the persisted artifact made the
    // refit unnecessary.
    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 20 }) else {
        panic!("expected stats reply");
    };
    assert_eq!(stat(&stats, &["store", "opens"]), 1.0);
    assert_eq!(stats.get("artifact_loaded").and_then(|x| x.as_bool()), Some(true));
    // The daemon reports which kernel path its scorers dispatch to —
    // the same string `linalg::simd::active_isa()` returns in-process.
    assert_eq!(
        stats.get("simd_isa").and_then(|x| x.as_str()),
        Some(grass::linalg::simd::active_isa()),
        "stats must carry the active SIMD ISA"
    );
    let fim_rows = stat(&stats, &["engines", "if", "fim_rows"]);
    assert_eq!(fim_rows, 0.0, "artifact reuse must skip the FIM ingest pass");
    let scored = stat(&stats, &["requests", "scored"]);
    assert_eq!(scored, 2.0);

    // Repeat queries never re-open the store or refit the FIM.
    let resp = client.ask(&score_req(21, "if", m));
    assert!(matches!(resp, Response::Scores(_)), "{resp:?}");
    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 22 }) else {
        panic!("expected stats reply");
    };
    assert_eq!(stat(&stats, &["store", "opens"]), 1.0);
    assert_eq!(stat(&stats, &["engines", "if", "fim_rows"]), fim_rows);
    assert_eq!(stat(&stats, &["requests", "scored"]), scored + 1.0);

    let resp = client.ask(&Request::Shutdown { id: 30 });
    assert!(matches!(resp, Response::ShuttingDown { id: 30 }));
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// N concurrent clients, each sending several requests over its own
/// connection, all receive the exact batch-path scores.
#[test]
fn concurrent_clients_each_get_correct_scores() {
    let (n, p, seed, m) = (32usize, 128usize, 3u64, 3usize);
    let dir = write_synth_store("concurrent", n, p, seed, 8);

    // Expected scores from the batch path (graddot: no FIM involved).
    let reader = StoreReader::open(&dir).unwrap();
    let spec = reader.meta.spec().unwrap();
    let bank = spec.build_bank(&reader.meta.shapes(), seed).unwrap();
    let mut aspec = AttributionSpec::new("graddot", spec.clone(), seed);
    aspec.layout = bank.layer_dims();
    aspec.precond = Some(PrecondSpec::default_for_scorer("graddot", 1e-3));
    let mut engine = from_spec(&aspec).unwrap();
    engine
        .cache_stream(
            &reader,
            &StreamOpts {
                workers: 2,
                ..StreamOpts::default()
            },
        )
        .unwrap();
    let (q, _classes) = synth_queries(&reader.meta, &bank, m).unwrap();
    let want = engine.attribute(&q, m).unwrap();
    let want = &want;

    let handle = spawn(quiet_cfg(&dir, &["graddot"])).unwrap();
    let addr = handle.addr();

    std::thread::scope(|s| {
        for t in 0..4u64 {
            s.spawn(move || {
                let mut client = Client::connect(addr);
                for r in 0..3u64 {
                    let resp = client.ask(&score_req(t * 10 + r, "graddot", m));
                    let Response::Scores(resp) = resp else {
                        panic!("client {t} request {r}: unexpected reply {resp:?}");
                    };
                    assert_eq!((resp.m, resp.n), (m, n), "client {t}");
                    let got = resp.scores.as_ref().expect("include_scores");
                    for i in 0..m * n {
                        let (a, b) = (got[i], want.scores[i]);
                        assert!(
                            (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
                            "client {t} score {i}: served {a} vs batch {b}"
                        );
                    }
                }
            });
        }
    });

    let mut client = Client::connect(addr);
    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 99 }) else {
        panic!("expected stats reply");
    };
    assert_eq!(stat(&stats, &["requests", "scored"]), 12.0);
    assert_eq!(stat(&stats, &["store", "opens"]), 1.0);
    assert!(stat(&stats, &["latency", "count"]) >= 12.0);
    client.ask(&Request::Shutdown { id: 100 });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Overload and deadline sheds are typed replies on a live connection —
/// the daemon never drops the socket, and keeps scoring afterwards.
#[test]
fn admission_and_deadlines_shed_typed_replies_while_serving() {
    let (n, p, seed) = (24usize, 64usize, 5u64);
    let dir = write_synth_store("shed", n, p, seed, 8);

    // Queue bound 0: every score request sheds, liveness stays up.
    let handle = spawn(ServeConfig {
        max_in_flight: 0,
        ..quiet_cfg(&dir, &["graddot"])
    })
    .unwrap();
    let mut client = Client::connect(handle.addr());
    let resp = client.ask(&score_req(1, "graddot", 2));
    let Response::Error { kind, message, .. } = resp else {
        panic!("expected overload shed, got {resp:?}");
    };
    assert_eq!(kind, ErrorKind::Overloaded);
    assert!(kind.is_shed());
    assert!(message.contains("queue full"), "{message}");
    assert!(matches!(client.ask(&Request::Ping { id: 2 }), Response::Pong { id: 2 }));
    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 3 }) else {
        panic!("expected stats reply");
    };
    assert_eq!(stat(&stats, &["requests", "overloaded"]), 1.0);
    assert_eq!(stat(&stats, &["requests", "scored"]), 0.0);
    client.ask(&Request::Shutdown { id: 4 });
    handle.join().unwrap();

    // Fresh daemon with capacity: an already-expired per-request deadline
    // sheds typed, and the same connection's next request still scores.
    let handle = spawn(quiet_cfg(&dir, &["graddot"])).unwrap();
    let mut client = Client::connect(handle.addr());
    let mut req = ScoreRequest {
        id: 5,
        scorer: "graddot".to_string(),
        top_k: 2,
        include_scores: false,
        self_influence: false,
        deadline_ms: Some(0),
        queries: QueryPayload::Synth { m: 2 },
    };
    let resp = client.ask(&Request::Score(req.clone()));
    let Response::Error { kind, .. } = resp else {
        panic!("expected deadline shed, got {resp:?}");
    };
    assert_eq!(kind, ErrorKind::DeadlineExceeded);
    assert!(kind.is_shed());
    req.id = 6;
    req.deadline_ms = None;
    let resp = client.ask(&Request::Score(req));
    assert!(
        matches!(resp, Response::Scores(_)),
        "daemon must keep serving after a shed: {resp:?}"
    );

    // A scorer that isn't loaded is a typed BadRequest, not a hangup.
    let resp = client.ask(&score_req(7, "trak", 2));
    let Response::Error { kind, message, .. } = resp else {
        panic!("expected bad request, got {resp:?}");
    };
    assert_eq!(kind, ErrorKind::BadRequest);
    assert!(!kind.is_shed());
    assert!(message.contains("not loaded"), "{message}");

    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 8 }) else {
        panic!("expected stats reply");
    };
    assert_eq!(stat(&stats, &["requests", "deadline_exceeded"]), 1.0);
    assert_eq!(stat(&stats, &["requests", "scored"]), 1.0);
    client.ask(&Request::Shutdown { id: 9 });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Serving an f16 store: served scores match the batch path on the same
/// store to ≤ 1e-6 (both sides decode the identical encoded rows), and
/// `stats` reports the payload dtype, the encoded bytes-per-row, and a
/// shard-cache residency that reflects encoded — not dequantized — bytes.
#[test]
fn served_f16_store_matches_batch_and_reports_encoded_residency() {
    let (n, p, seed, m) = (48usize, 256usize, 11u64, 4usize);
    let dir = write_synth_store_dtype("f16", n, p, seed, 16, PayloadDtype::F16);

    let reader = StoreReader::open(&dir).unwrap();
    assert_eq!(reader.meta.dtype, PayloadDtype::F16);
    let k = reader.meta.k;
    let spec = reader.meta.spec().unwrap();
    let bank = spec.build_bank(&reader.meta.shapes(), seed).unwrap();
    let mut aspec = AttributionSpec::new("graddot", spec.clone(), seed);
    aspec.layout = bank.layer_dims();
    aspec.precond = Some(PrecondSpec::default_for_scorer("graddot", 1e-3));
    let mut engine = from_spec(&aspec).unwrap();
    engine
        .cache_stream(
            &reader,
            &StreamOpts {
                workers: 2,
                ..StreamOpts::default()
            },
        )
        .unwrap();
    let (q, _classes) = synth_queries(&reader.meta, &bank, m).unwrap();
    let want = engine.attribute(&q, m).unwrap();

    let handle = spawn(quiet_cfg(&dir, &["graddot"])).unwrap();
    let mut client = Client::connect(handle.addr());
    let resp = client.ask(&score_req(1, "graddot", m));
    let Response::Scores(r) = resp else {
        panic!("expected scores, got {resp:?}");
    };
    assert_eq!((r.m, r.n), (m, n));
    assert!(!r.coverage.is_degraded(), "{:?}", r.coverage);
    let got = r.scores.as_ref().expect("include_scores was set");
    for i in 0..m * n {
        let (a, b) = (got[i], want.scores[i]);
        assert!(
            (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
            "f16 served score {i}: {a} vs batch {b}"
        );
    }

    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 2 }) else {
        panic!("expected stats reply");
    };
    let dtype = stats
        .get("store")
        .and_then(|s| s.get("dtype"))
        .and_then(|d| d.as_str())
        .expect("stats.store.dtype");
    assert_eq!(dtype, "f16");
    assert_eq!(
        stat(&stats, &["store", "bytes_per_row"]),
        (k * 2) as f64,
        "f16 rows are 2 bytes per element"
    );
    // The resident cache holds encoded shard bytes: at most the f16
    // payload footprint, strictly below what dequantized f32 would cost.
    let resident = stat(&stats, &["shard_cache", "resident_bytes"]);
    assert!(resident > 0.0, "ingest must have warmed the shard cache");
    assert!(
        resident <= (n * k * 2) as f64,
        "resident {resident} exceeds the encoded f16 footprint {}",
        n * k * 2
    );

    client.ask(&Request::Shutdown { id: 3 });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// A truncated shard under `skip_corrupt` degrades the response's
/// coverage (quarantined shard listed, fewer rows scored) but the daemon
/// keeps answering; without `skip_corrupt` the daemon refuses to start.
#[test]
fn corrupt_shard_degrades_coverage_but_daemon_keeps_serving() {
    let (n, p, seed, m) = (48usize, 64usize, 7u64, 2usize);
    let shard_rows = 16usize; // 3 shards of 16
    let dir = write_synth_store("degraded", n, p, seed, shard_rows);
    let shard1 = dir.join("shard_0001.bin");
    let len = std::fs::metadata(&shard1).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&shard1).unwrap();
    f.set_len(len - 8).unwrap();
    drop(f);

    // Strict mode: ingest hits the corrupt shard and spawn fails cleanly.
    assert!(
        spawn(quiet_cfg(&dir, &["graddot"])).is_err(),
        "corrupt shard without skip_corrupt must refuse to serve"
    );

    let handle = spawn(ServeConfig {
        skip_corrupt: true,
        cache_bytes: 0,
        ..quiet_cfg(&dir, &["graddot"])
    })
    .unwrap();
    let mut client = Client::connect(handle.addr());
    let resp = client.ask(&score_req(1, "graddot", m));
    let Response::Scores(r) = resp else {
        panic!("degraded store must still score: {resp:?}");
    };
    assert!(r.coverage.is_degraded(), "{:?}", r.coverage);
    assert_eq!(r.coverage.quarantined, vec![1]);
    assert_eq!(r.coverage.rows_total, n);
    assert_eq!(r.coverage.rows_scored, n - shard_rows);

    // One bad shard costs coverage in that response, not the daemon.
    let resp = client.ask(&score_req(2, "graddot", m));
    assert!(matches!(resp, Response::Scores(_)), "{resp:?}");
    let Response::Stats { stats, .. } = client.ask(&Request::Stats { id: 3 }) else {
        panic!("expected stats reply");
    };
    assert_eq!(stat(&stats, &["requests", "degraded"]), 2.0);
    assert_eq!(stat(&stats, &["requests", "scored"]), 2.0);
    client.ask(&Request::Shutdown { id: 4 });
    handle.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
