//! Integration: the Rust PJRT runtime executing AOT artifacts end-to-end.
//! These tests are skipped (with a notice) until `make artifacts` has run.

use grass::runtime::{Arg, Runtime};
use grass::sketch::rng::Pcg;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime load"))
}

#[test]
fn mlp_init_train_loss_roundtrip() {
    let Some(rt) = runtime() else { return };
    let p = rt.manifest.model("mlp").unwrap().p;

    // init: deterministic in the seed
    let init = rt.executable("mlp_init").unwrap();
    let params = init.run(&[Arg::ScalarI32(7)]).unwrap().remove(0);
    assert_eq!(params.data.len(), p);
    let params2 = init.run(&[Arg::ScalarI32(7)]).unwrap().remove(0);
    assert_eq!(params.data, params2.data);
    let params3 = init.run(&[Arg::ScalarI32(8)]).unwrap().remove(0);
    assert_ne!(params.data, params3.data);

    // synthetic batch
    let tb = rt.manifest.batch_size("train", "mlp").unwrap();
    let mut rng = Pcg::new(3);
    let x: Vec<f32> = (0..tb * 196).map(|_| rng.next_gaussian()).collect();
    let y: Vec<i32> = (0..tb).map(|_| rng.next_below(10) as i32).collect();

    // loss before
    let lb = rt.manifest.batch_size("loss", "mlp").unwrap();
    assert_eq!(lb, tb, "test assumes shared batch size");
    let loss_exe = rt.executable("mlp_loss").unwrap();
    let loss0 = loss_exe
        .run(&[
            Arg::F32(params.data.clone(), vec![p]),
            Arg::F32(x.clone(), vec![tb, 196]),
            Arg::I32(y.clone(), vec![tb]),
        ])
        .unwrap()
        .remove(0);
    assert_eq!(loss0.data.len(), tb);
    assert!(loss0.data.iter().all(|l| l.is_finite() && *l > 0.0));

    // 20 SGD steps reduce mean loss on the same batch
    let step = rt.executable("mlp_train_step").unwrap();
    let mut cur = params.data.clone();
    for _ in 0..20 {
        cur = step
            .run(&[
                Arg::F32(cur, vec![p]),
                Arg::F32(x.clone(), vec![tb, 196]),
                Arg::I32(y.clone(), vec![tb]),
                Arg::ScalarF32(0.1),
            ])
            .unwrap()
            .remove(0)
            .data;
    }
    let loss1 = loss_exe
        .run(&[
            Arg::F32(cur, vec![p]),
            Arg::F32(x.clone(), vec![tb, 196]),
            Arg::I32(y.clone(), vec![tb]),
        ])
        .unwrap()
        .remove(0);
    let m0: f32 = loss0.data.iter().sum::<f32>() / tb as f32;
    let m1: f32 = loss1.data.iter().sum::<f32>() / tb as f32;
    assert!(m1 < m0, "training did not reduce loss: {m0} -> {m1}");
}

#[test]
fn mlp_per_sample_grads_shape_and_sparsity() {
    let Some(rt) = runtime() else { return };
    let p = rt.manifest.model("mlp").unwrap().p;
    let gb = rt.manifest.batch_size("grads", "mlp").unwrap();
    let init = rt.executable("mlp_init").unwrap();
    let params = init.run(&[Arg::ScalarI32(1)]).unwrap().remove(0);
    let mut rng = Pcg::new(5);
    let x: Vec<f32> = (0..gb * 196).map(|_| rng.next_gaussian()).collect();
    let y: Vec<i32> = (0..gb).map(|_| rng.next_below(10) as i32).collect();
    let grads = rt
        .executable("mlp_grads")
        .unwrap()
        .run(&[
            Arg::F32(params.data, vec![p]),
            Arg::F32(x, vec![gb, 196]),
            Arg::I32(y, vec![gb]),
        ])
        .unwrap()
        .remove(0);
    assert_eq!(grads.shape, vec![gb, p]);
    // paper §3.1: ReLU induces per-sample gradient sparsity
    let zeros = grads.data.iter().filter(|&&v| v == 0.0).count();
    let frac = zeros as f64 / grads.data.len() as f64;
    assert!(frac > 0.2, "expected sparse per-sample grads, got {frac:.3}");
}

#[test]
fn kernel_sjlt_matches_rust_native() {
    // The L1↔L3 cross-check: the Pallas SJLT (via HLO) and the Rust
    // counter-based SJLT agree when driven with the same tables.
    let Some(rt) = runtime() else { return };
    use grass::sketch::{sjlt::Sjlt, Compressor};
    let exe = rt.executable("kernel_sjlt").unwrap();
    let (b, p, k) = (4usize, 8192usize, 256usize);

    let t = Sjlt::new(p, k, 1, 42);
    // Export the Rust SJLT's bucket/sign tables as kernel inputs.
    let mut idx = vec![0i32; p];
    let mut sgn = vec![0f32; p];
    for j in 0..p {
        let (bucket, sign) = t.bucket_sign(j, 0);
        idx[j] = bucket as i32;
        sgn[j] = sign;
    }
    let mut rng = Pcg::new(11);
    let g: Vec<f32> = (0..b * p).map(|_| rng.next_gaussian()).collect();
    let out = exe
        .run(&[
            Arg::F32(g.clone(), vec![b, p]),
            Arg::I32(idx, vec![p]),
            Arg::F32(sgn, vec![p]),
        ])
        .unwrap()
        .remove(0);
    assert_eq!(out.shape, vec![b, k]);
    for i in 0..b {
        let native = t.compress(&g[i * p..(i + 1) * p]);
        let hlo = out.row(i);
        for j in 0..k {
            assert!(
                (native[j] - hlo[j]).abs() < 1e-3 * (1.0 + native[j].abs()),
                "row {i} col {j}: rust {} vs hlo {}",
                native[j],
                hlo[j]
            );
        }
    }
}

#[test]
fn lm_hooks_emit_all_layers() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.model("music").unwrap().clone();
    let p = meta.p;
    let hb = rt.manifest.batch_size("hooks", "music").unwrap();
    let seq = meta.seq.unwrap();
    let vocab = meta.vocab.unwrap();
    let init = rt.executable("music_init").unwrap();
    let params = init.run(&[Arg::ScalarI32(0)]).unwrap().remove(0);
    let mut rng = Pcg::new(9);
    let tokens: Vec<i32> = (0..hb * seq).map(|_| rng.next_below(vocab) as i32).collect();
    let outs = rt
        .executable("music_hooks")
        .unwrap()
        .run(&[
            Arg::F32(params.data, vec![p]),
            Arg::I32(tokens, vec![hb, seq]),
        ])
        .unwrap();
    let l = meta.layers.len();
    assert_eq!(outs.len(), 2 * l);
    for (i, layer) in meta.layers.iter().enumerate() {
        assert_eq!(outs[i].shape, vec![hb, seq, layer.d_in], "{} x", layer.name);
        assert_eq!(
            outs[l + i].shape,
            vec![hb, seq, layer.d_out],
            "{} dy",
            layer.name
        );
        // gradients should be non-trivial
        let energy: f32 = outs[l + i].data.iter().map(|v| v * v).sum();
        assert!(energy > 0.0, "{} has zero grad energy", layer.name);
    }
}
