//! Integration: the fault-tolerance layer end to end — crash-safe
//! resumable caching (drop-without-finish, torn writes, SIGKILL through
//! the CLI), retrying reads under injected transient faults, degraded-mode
//! scoring with exact coverage accounting, and `grass verify`.

use grass::attrib::{from_spec, AttributionSpec, Attributor, StreamOpts};
use grass::sketch::rng::Pcg;
use grass::sketch::MethodSpec;
use grass::store::{
    FaultKind, FaultPlan, PayloadDtype, RetryPolicy, StoreMeta, StoreReader, StoreWriter,
};
use std::path::{Path, PathBuf};
use std::process::Command;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_fault_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn gaussian(rows: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..rows * k).map(|_| rng.next_gaussian()).collect()
}

fn raw_meta(k: usize, shard_rows: usize) -> StoreMeta {
    StoreMeta {
        k,
        n: 0,
        shard_rows,
        method: "raw".to_string(),
        seed: 0,
        model: String::new(),
        input_dim: 0,
        layer_dims: vec![],
        density: 1.0,
        dtype: PayloadDtype::F32,
    }
}

/// Sorted (name, bytes) of every committed shard file in a store dir.
fn shard_files(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().to_string_lossy().to_string();
            if name.starts_with("shard_") && name.ends_with(".bin") {
                Some((name, std::fs::read(e.path()).unwrap()))
            } else {
                None
            }
        })
        .collect();
    out.sort();
    out
}

fn graddot_spec(k: usize) -> AttributionSpec {
    AttributionSpec::new("graddot", MethodSpec::RandomMask { k }, 0)
}

/// A cache run dropped without `finish` resumes from its committed shards
/// and produces a store — and scores — bit-identical to an uninterrupted
/// run over the same deterministic row source.
#[test]
fn interrupted_cache_resumes_bit_identical_store_and_scores() {
    let (n, k, sr, m) = (48usize, 8usize, 6usize, 3usize);
    let rows = gaussian(n, k, 41);
    let queries = gaussian(m, k, 42);

    let ref_dir = tmpdir("resume_ref");
    let mut w = StoreWriter::create_described(&ref_dir, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows).unwrap();
    w.finish().unwrap();

    // "Crash" midway: half the rows pushed, writer dropped, no store.json.
    let res_dir = tmpdir("resume_res");
    let mut w = StoreWriter::create_described(&res_dir, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows[..(n / 2) * k]).unwrap();
    drop(w);
    assert!(!res_dir.join("store.json").exists());

    // Resume restarts at the committed watermark; the row source is
    // index-deterministic so recomputed rows match the reference exactly.
    let (mut w, committed) = StoreWriter::resume(&res_dir, &raw_meta(k, sr)).unwrap();
    assert!(committed > 0 && committed < n && committed % sr == 0, "{committed}");
    w.push_batch(&rows[committed * k..]).unwrap();
    let meta = w.finish().unwrap();
    assert_eq!(meta.n, n);

    assert_eq!(shard_files(&ref_dir), shard_files(&res_dir));
    let r_ref = StoreReader::open(&ref_dir).unwrap();
    let r_res = StoreReader::open(&res_dir).unwrap();
    assert!(r_res.verify_checksums().unwrap().all_ok());

    let opts = StreamOpts::default();
    let mut a_ref = from_spec(&graddot_spec(k)).unwrap();
    a_ref.cache_stream(&r_ref, &opts).unwrap();
    let mut a_res = from_spec(&graddot_spec(k)).unwrap();
    a_res.cache_stream(&r_res, &opts).unwrap();
    let s_ref = a_ref.attribute(&queries, m).unwrap();
    let s_res = a_res.attribute(&queries, m).unwrap();
    for i in 0..m * n {
        assert!(
            (s_ref.scores[i] - s_res.scores[i]).abs() <= 1e-6,
            "score {i}: {} vs {}",
            s_ref.scores[i],
            s_res.scores[i]
        );
    }
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&res_dir).ok();
}

/// An injected torn write aborts the commit with half a tmpfile on disk
/// and no manifest entry; resume discards the evidence and recommits, and
/// the repaired store matches a clean run byte for byte.
#[test]
fn torn_write_is_discarded_and_resume_recommits() {
    let (n, k, sr) = (24usize, 4usize, 6usize);
    let rows = gaussian(n, k, 51);

    let ref_dir = tmpdir("torn_ref");
    let mut w = StoreWriter::create_described(&ref_dir, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows).unwrap();
    w.finish().unwrap();

    let dir = tmpdir("torn_res");
    let plan = FaultPlan::new();
    plan.fail_write(1);
    let mut w = StoreWriter::create_described(&dir, raw_meta(k, sr)).unwrap();
    w.inject_faults(plan);
    let err = w.push_batch(&rows).unwrap_err();
    assert!(format!("{err:#}").contains("injected torn write"), "{err:#}");
    drop(w);
    // The torn tmpfile survives the drop; only shard 0 is manifest-listed.
    assert!(dir.join("shard_0001.bin.tmp").exists());

    let (mut w, committed) = StoreWriter::resume(&dir, &raw_meta(k, sr)).unwrap();
    assert_eq!(committed, sr, "only the shard committed before the tear counts");
    assert!(!dir.join("shard_0001.bin.tmp").exists());
    w.push_batch(&rows[committed * k..]).unwrap();
    w.finish().unwrap();

    assert_eq!(shard_files(&ref_dir), shard_files(&dir));
    assert!(StoreReader::open(&dir).unwrap().verify_checksums().unwrap().all_ok());
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Transient read faults injected under a full registry-built scorer are
/// absorbed by the retry policy: scores match the fault-free run and the
/// shared read log counts the retries; nothing is quarantined.
#[test]
fn transient_faults_retry_through_full_attributor() {
    let (n, k, sr, m) = (30usize, 8usize, 5usize, 3usize);
    let rows = gaussian(n, k, 61);
    let queries = gaussian(m, k, 62);
    let dir = tmpdir("retry");
    let mut w = StoreWriter::create_described(&dir, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows).unwrap();
    w.finish().unwrap();

    let mut aspec = AttributionSpec::new("if", MethodSpec::RandomMask { k }, 0);
    aspec.damping = 0.1;

    let reader = StoreReader::open(&dir).unwrap();
    let mut clean = from_spec(&aspec).unwrap();
    clean.cache_stream(&reader, &StreamOpts::default()).unwrap();
    let want = clean.attribute(&queries, m).unwrap();

    let mut reader = StoreReader::open(&dir).unwrap();
    let plan = FaultPlan::new();
    plan.fail_read(2, FaultKind::Transient, 0, 2);
    reader.inject_faults(plan);
    let opts = StreamOpts {
        retry: RetryPolicy {
            retries: 3,
            backoff: std::time::Duration::from_millis(1),
            seed: 0,
        },
        ..StreamOpts::default()
    };
    let mut eng = from_spec(&aspec).unwrap();
    eng.cache_stream(&reader, &opts).unwrap();
    let got = eng.attribute(&queries, m).unwrap();
    for i in 0..m * n {
        assert!(
            (got.scores[i] - want.scores[i]).abs() <= 1e-6,
            "score {i}: {} vs {}",
            got.scores[i],
            want.scores[i]
        );
    }
    assert!(opts.log.retries_attempted() >= 2, "{}", opts.log.retries_attempted());
    assert!(opts.log.quarantined().is_empty());
    let cov = eng.coverage().expect("streamed cache reports coverage");
    assert!(!cov.is_degraded(), "{cov:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// One corrupt shard: the strict path refuses to score; `skip_corrupt`
/// quarantines it, zeroes its rows, matches the full run on every
/// surviving row, and reports exact coverage.
#[test]
fn degraded_skip_corrupt_matches_full_run_on_surviving_rows() {
    let (n, k, sr, m) = (40usize, 8usize, 5usize, 3usize);
    let rows = gaussian(n, k, 71);
    let queries = gaussian(m, k, 72);
    let dir = tmpdir("degraded");
    let mut w = StoreWriter::create_described(&dir, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows).unwrap();
    w.finish().unwrap();

    // Full-run reference scores before any corruption.
    let reader = StoreReader::open(&dir).unwrap();
    let mut full = from_spec(&graddot_spec(k)).unwrap();
    full.cache_stream(&reader, &StreamOpts::default()).unwrap();
    let want = full.attribute(&queries, m).unwrap();

    // Truncate shard 3 (rows 15..20) behind the manifest's back.
    let victim = dir.join("shard_0003.bin");
    let f = std::fs::OpenOptions::new().write(true).open(&victim).unwrap();
    let len = f.metadata().unwrap().len();
    f.set_len(len - 8).unwrap();
    drop(f);

    // Strict mode: the corruption is a hard error, not a silent zero
    // (surfaced at whichever pass touches the bad shard first).
    let reader = StoreReader::open(&dir).unwrap();
    let mut strict = from_spec(&graddot_spec(k)).unwrap();
    let res = strict
        .cache_stream(&reader, &StreamOpts::default())
        .and_then(|_| strict.attribute(&queries, m).map(|_| ()));
    assert!(res.is_err());

    // Degraded mode: quarantine, score the rest, account for every row.
    let opts = StreamOpts {
        skip_corrupt: true,
        ..StreamOpts::default()
    };
    let mut deg = from_spec(&graddot_spec(k)).unwrap();
    deg.cache_stream(&reader, &opts).unwrap();
    let got = deg.attribute(&queries, m).unwrap();
    for qi in 0..m {
        for i in 0..n {
            let v = got.scores[qi * n + i];
            if (15..20).contains(&i) {
                assert_eq!(v, 0.0, "quarantined row {i} must score zero");
            } else {
                assert!(
                    (v - want.scores[qi * n + i]).abs() <= 1e-6,
                    "surviving row {i}: {v} vs {}",
                    want.scores[qi * n + i]
                );
            }
        }
    }
    let cov = deg.coverage().expect("streamed cache reports coverage");
    assert_eq!(cov.rows_total, n);
    assert_eq!(cov.rows_scored, n - sr);
    assert_eq!(cov.quarantined, vec![3]);
    assert!(cov.is_degraded());
    assert!(cov.describe().contains("35/40"), "{}", cov.describe());
    std::fs::remove_dir_all(&dir).ok();
}

/// In-memory caches have no shards to lose: `coverage()` is None, so
/// callers can distinguish "nothing to report" from "100% coverage".
#[test]
fn coverage_is_none_for_in_memory_caches() {
    let (n, k) = (12usize, 6usize);
    let rows = gaussian(n, k, 81);
    let mut aspec = AttributionSpec::new("if", MethodSpec::RandomMask { k }, 0);
    aspec.damping = 0.1;
    let mut eng = from_spec(&aspec).unwrap();
    eng.cache(&rows, n).unwrap();
    assert!(eng.coverage().is_none());
}

/// SIGKILL a real `grass cache` run mid-write, resume it through the CLI,
/// and end up with a store byte-identical to an uninterrupted run — the
/// CLI-level version of the resume contract, plus `grass verify`.
#[test]
fn killed_cli_cache_run_resumes_verifies_and_scores() {
    let exe = env!("CARGO_BIN_EXE_grass");
    let ref_dir = tmpdir("cli_kill_ref");
    let res_dir = tmpdir("cli_kill_res");
    let base = |store: &Path| {
        vec![
            "cache".to_string(),
            "--model".into(),
            "synth".into(),
            "--method".into(),
            "factgrass:kin=8,kout=8,kl=16".into(),
            "--n".into(),
            "200".into(),
            "--seed".into(),
            "5".into(),
            "--shard-rows".into(),
            "16".into(),
            "--store".into(),
            store.to_str().unwrap().into(),
        ]
    };

    let out = Command::new(exe).args(base(&ref_dir)).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Throttled run killed mid-write: no store.json, shards committed.
    let mut args = base(&res_dir);
    args.extend(["--throttle-ms".to_string(), "10".to_string()]);
    let mut child = Command::new(exe).args(&args).spawn().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(500));
    child.kill().unwrap();
    child.wait().unwrap();
    assert!(!res_dir.join("store.json").exists(), "kill landed too late");

    let mut args = base(&res_dir);
    args.push("--resume".to_string());
    let out = Command::new(exe).args(&args).output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(out.status.success(), "{stdout}{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("resuming:"), "{stdout}");

    let out = Command::new(exe)
        .args(["verify", "--store", res_dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(0));
    assert!(String::from_utf8_lossy(&out.stdout).contains("verify: OK"));

    assert_eq!(shard_files(&ref_dir), shard_files(&res_dir));

    // Deterministic scoring on both stores prints identical top-k lines.
    let attribute = |dir: &Path| {
        let out = Command::new(exe)
            .args([
                "attribute",
                "--store",
                dir.to_str().unwrap(),
                "--queries",
                "3",
                "--scorer",
                "graddot",
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout)
            .lines()
            .filter(|l| l.trim_start().starts_with("query "))
            .map(str::to_string)
            .collect::<Vec<_>>()
    };
    let top_ref = attribute(&ref_dir);
    let top_res = attribute(&res_dir);
    assert!(!top_ref.is_empty());
    assert_eq!(top_ref, top_res);
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&res_dir).ok();
}

/// Quantized stores get the same integrity guarantees: manifest CRCs are
/// computed over the encoded f16 bytes, so a single bit flip in an f16
/// shard fails `grass verify` with exit 2.
#[test]
fn verify_detects_bit_flip_in_f16_shard() {
    let exe = env!("CARGO_BIN_EXE_grass");
    let dir = tmpdir("verify_f16");
    let dir_s = dir.to_str().unwrap();
    let out = Command::new(exe)
        .args([
            "cache", "--model", "synth", "--method", "sjlt:k=32", "--p", "256", "--n", "64",
            "--seed", "9", "--shard-rows", "16", "--dtype", "f16", "--store", dir_s,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // The encoded shard really is half the f32 size: 16 rows × 32 × 2 B.
    let victim = dir.join("shard_0001.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    assert_eq!(bytes.len(), 16 * 32 * 2);
    let out = Command::new(exe).args(["verify", "--store", dir_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(0), "{}", String::from_utf8_lossy(&out.stdout));

    bytes[17] ^= 0x01; // same length, wrong CRC over the encoded payload
    std::fs::write(&victim, &bytes).unwrap();
    let out = Command::new(exe).args(["verify", "--store", dir_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(2), "{}", String::from_utf8_lossy(&out.stdout));
    assert!(String::from_utf8_lossy(&out.stdout).contains("verify: FAILED"));
    std::fs::remove_dir_all(&dir).ok();
}

/// The CLI resume contract holds for quantized payloads: a SIGKILLed
/// `grass cache --dtype f16` run resumed with the same flags produces a
/// store byte-identical (encoded shards included) to an uninterrupted run.
#[test]
fn killed_f16_cli_cache_resumes_byte_identical() {
    let exe = env!("CARGO_BIN_EXE_grass");
    let ref_dir = tmpdir("cli_kill_f16_ref");
    let res_dir = tmpdir("cli_kill_f16_res");
    let base = |store: &Path| {
        vec![
            "cache".to_string(),
            "--model".into(),
            "synth".into(),
            "--method".into(),
            "factgrass:kin=8,kout=8,kl=16".into(),
            "--n".into(),
            "200".into(),
            "--seed".into(),
            "5".into(),
            "--shard-rows".into(),
            "16".into(),
            "--dtype".into(),
            "f16".into(),
            "--store".into(),
            store.to_str().unwrap().into(),
        ]
    };

    let out = Command::new(exe).args(base(&ref_dir)).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let mut args = base(&res_dir);
    args.extend(["--throttle-ms".to_string(), "10".to_string()]);
    let mut child = Command::new(exe).args(&args).spawn().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(500));
    child.kill().unwrap();
    child.wait().unwrap();
    assert!(!res_dir.join("store.json").exists(), "kill landed too late");

    // Resuming under a different dtype is refused with a descriptive
    // error — the interrupted shards are already f16-encoded.
    let mut args = base(&res_dir);
    for a in &mut args {
        if a == "f16" {
            *a = "bf16".to_string();
        }
    }
    args.push("--resume".to_string());
    let out = Command::new(exe).args(&args).output().unwrap();
    assert!(!out.status.success(), "dtype-switching resume must fail");

    let mut args = base(&res_dir);
    args.push("--resume".to_string());
    let out = Command::new(exe).args(&args).output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(out.status.success(), "{stdout}{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("resuming:"), "{stdout}");

    let out = Command::new(exe)
        .args(["verify", "--store", res_dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(0));
    assert_eq!(shard_files(&ref_dir), shard_files(&res_dir));
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&res_dir).ok();
}

/// `grass verify` exit codes: 0 on a clean store, 2 on checksum mismatch,
/// 2 on a manifest-less legacy store — which `--upgrade` checksums in
/// place back to 0.
#[test]
fn verify_cli_detects_corruption_and_upgrades_legacy() {
    let exe = env!("CARGO_BIN_EXE_grass");
    let (n, k, sr) = (32usize, 8usize, 8usize);
    let rows = gaussian(n, k, 91);
    let dir = tmpdir("verify_cli");
    let mut w = StoreWriter::create_described(&dir, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows).unwrap();
    w.finish().unwrap();
    let dir_s = dir.to_str().unwrap();

    let out = Command::new(exe).args(["verify", "--store", dir_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(0), "{}", String::from_utf8_lossy(&out.stdout));
    assert!(String::from_utf8_lossy(&out.stdout).contains("verify: OK"));

    // Bit-flip one byte: same length, wrong CRC.
    let victim = dir.join("shard_0002.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes[5] ^= 0x40;
    std::fs::write(&victim, &bytes).unwrap();
    let out = Command::new(exe).args(["verify", "--store", dir_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stdout).contains("verify: FAILED"));

    // Legacy store: no manifest → exit 2 with guidance; --upgrade fixes it.
    let legacy = tmpdir("verify_legacy");
    let mut w = StoreWriter::create_described(&legacy, raw_meta(k, sr)).unwrap();
    w.push_batch(&rows).unwrap();
    w.finish().unwrap();
    std::fs::remove_file(legacy.join("manifest.json")).unwrap();
    let legacy_s = legacy.to_str().unwrap();
    let out = Command::new(exe).args(["verify", "--store", legacy_s]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stdout).contains("no manifest.json"));
    let out = Command::new(exe)
        .args(["verify", "--store", legacy_s, "--upgrade"])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert_eq!(out.status.code(), Some(0), "{stdout}");
    assert!(stdout.contains("upgraded:"), "{stdout}");
    assert!(stdout.contains("verify: OK"), "{stdout}");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&legacy).ok();
}

/// CLI degraded mode end to end: a corrupted shard fails a strict
/// `grass attribute` (exit 1) but completes under `--skip-corrupt` with
/// coverage reporting and the dedicated exit code 3.
#[test]
fn cli_attribute_skip_corrupt_reports_coverage_and_exit_code() {
    let exe = env!("CARGO_BIN_EXE_grass");
    let dir = tmpdir("cli_degraded");
    let dir_s = dir.to_str().unwrap();
    let out = Command::new(exe)
        .args([
            "cache", "--model", "synth", "--method", "sjlt:k=32", "--p", "256", "--n", "96",
            "--seed", "7", "--shard-rows", "16", "--store", dir_s,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let victim = dir.join("shard_0003.bin");
    let f = std::fs::OpenOptions::new().write(true).open(&victim).unwrap();
    let len = f.metadata().unwrap().len();
    f.set_len(len - 8).unwrap();
    drop(f);

    let strict = Command::new(exe)
        .args(["attribute", "--store", dir_s, "--queries", "2", "--scorer", "graddot"])
        .output()
        .unwrap();
    assert_eq!(strict.status.code(), Some(1), "{}", String::from_utf8_lossy(&strict.stdout));
    let err = String::from_utf8_lossy(&strict.stderr).to_string();
    assert!(err.contains("shard 3"), "{err}");

    let out = Command::new(exe)
        .args([
            "attribute",
            "--store",
            dir_s,
            "--queries",
            "2",
            "--scorer",
            "graddot",
            "--skip-corrupt",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert_eq!(out.status.code(), Some(3), "{stdout}");
    assert!(stdout.contains("coverage: 80/96"), "{stdout}");
    assert!(stdout.contains("quarantined shards: [3]"), "{stdout}");
    assert!(stdout.contains("completed degraded"), "{stdout}");
    std::fs::remove_dir_all(&dir).ok();
}
