//! Store edge cases the out-of-core streaming path must survive: empty
//! stores, a single row on a ragged final shard, non-divisible
//! `push_batch` tails, cursor/parallel agreement with `read_all`, and the
//! corrupted-shard regression (truncation must surface as a descriptive
//! error naming the shard and byte counts, not a bare I/O error).

use grass::attrib::{from_spec, AttributionSpec, Attributor, StreamOpts};
use grass::serve::ShardCache;
use grass::sketch::MethodSpec;
use grass::store::{ReadLog, RetryPolicy, RowBlock, StoreReader, StoreWriter};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "grass_store_stream_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Row i is `[i, i+0.5, ..]` so misplaced rows are detectable.
fn row(i: usize, k: usize) -> Vec<f32> {
    (0..k).map(|j| i as f32 + j as f32 * 0.5).collect()
}

fn write_store(dir: &PathBuf, n: usize, k: usize, shard_rows: usize) {
    let mut w = StoreWriter::create(dir, k, "edge", 0, shard_rows).unwrap();
    for i in 0..n {
        w.push(&row(i, k)).unwrap();
    }
    w.finish().unwrap();
}

/// Collect (index, first value) for every row three ways and require
/// bit-identical agreement with `read_all`.
fn assert_all_paths_agree(reader: &StoreReader, n: usize, k: usize) {
    let all = reader.read_all().unwrap();
    assert_eq!(all.len(), n * k);

    let mut seq = Vec::new();
    reader
        .for_each_row(|i, r| seq.push((i, r.to_vec())))
        .unwrap();
    assert_eq!(seq.len(), n);
    for (i, r) in &seq {
        assert_eq!(r.as_slice(), &all[i * k..(i + 1) * k], "for_each_row {i}");
    }

    // Cursor with a deliberately awkward chunk size.
    let mut cur = reader.cursor_with(3, &[]);
    let mut buf = Vec::new();
    let mut rows_seen = 0usize;
    while let Some(b) = cur.next_block(&mut buf).unwrap() {
        for j in 0..b.rows {
            let got = &buf[j * k..(j + 1) * k];
            let want = &all[(b.start + j) * k..(b.start + j + 1) * k];
            assert_eq!(got, want, "cursor row {}", b.start + j);
        }
        rows_seen += b.rows;
    }
    assert_eq!(rows_seen, n);

    // Parallel visitation covers every row exactly once.
    let seen = Mutex::new(vec![0usize; n]);
    reader
        .par_for_each_block(2, &[], 3, |_, b, data, _| {
            let mut g = seen.lock().unwrap();
            for j in 0..b.rows {
                g[b.start + j] += 1;
                assert_eq!(
                    &data[j * k..(j + 1) * k],
                    &all[(b.start + j) * k..(b.start + j + 1) * k]
                );
            }
            Ok(())
        })
        .unwrap();
    assert!(seen.into_inner().unwrap().iter().all(|&c| c == 1));
}

#[test]
fn empty_store_streams_nothing_and_scores_empty() {
    let dir = tmpdir("empty");
    let k = 4;
    write_store(&dir, 0, k, 8);
    let reader = StoreReader::open(&dir).unwrap();
    assert_eq!(reader.meta.n, 0);
    assert_eq!(reader.num_shards(), 0);
    assert!(reader.read_all().unwrap().is_empty());
    assert!(reader.plan_blocks(4, &[]).is_empty());
    let mut cur = reader.cursor();
    let mut buf = Vec::new();
    assert_eq!(cur.next_block(&mut buf).unwrap(), None);
    reader
        .for_each_row(|_, _| panic!("empty store yielded a row"))
        .unwrap();
    reader
        .par_for_each_shard(4, |_, _, _, _| panic!("empty store yielded a block"))
        .unwrap();

    // A streamed scorer over the empty store produces an m × 0 matrix.
    let mut gd = from_spec(&AttributionSpec::new(
        "graddot",
        MethodSpec::RandomMask { k },
        0,
    ))
    .unwrap();
    gd.cache_stream(&reader, &StreamOpts::default()).unwrap();
    let s = gd.attribute(&vec![0.0; 2 * k], 2).unwrap();
    assert_eq!((s.m, s.n), (2, 0));
    assert!(s.scores.is_empty());
    assert!(gd.self_influence().unwrap().is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_row_on_partial_last_shard() {
    let dir = tmpdir("partial");
    let (n, k) = (9usize, 3usize); // shard_rows 4 → shards of 4, 4, 1
    write_store(&dir, n, k, 4);
    let reader = StoreReader::open(&dir).unwrap();
    assert_eq!(reader.num_shards(), 3);
    let (start, data) = reader.read_shard(2).unwrap();
    assert_eq!(start, 8);
    assert_eq!(data, row(8, k));
    assert_all_paths_agree(&reader, n, k);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn push_batch_with_non_divisible_final_batch() {
    let dir = tmpdir("tail");
    let (n, k) = (23usize, 5usize);
    let mut w = StoreWriter::create(&dir, k, "edge", 0, 6).unwrap();
    // Batches of 10, 10, then a ragged 3-row tail, against 6-row shards.
    let all: Vec<f32> = (0..n).flat_map(|i| row(i, k)).collect();
    w.push_batch(&all[..10 * k]).unwrap();
    w.push_batch(&all[10 * k..20 * k]).unwrap();
    w.push_batch(&all[20 * k..]).unwrap();
    let meta = w.finish().unwrap();
    assert_eq!(meta.n, n);
    let reader = StoreReader::open(&dir).unwrap();
    assert_eq!(reader.read_all().unwrap(), all);
    assert_all_paths_agree(&reader, n, k);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exact_shard_multiple_has_no_phantom_rows() {
    let dir = tmpdir("exact");
    let (n, k) = (12usize, 2usize); // exactly 3 shards of 4
    write_store(&dir, n, k, 4);
    let reader = StoreReader::open(&dir).unwrap();
    assert_eq!(reader.num_shards(), 3);
    assert_all_paths_agree(&reader, n, k);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_shard_is_a_descriptive_error() {
    let dir = tmpdir("corrupt");
    let (n, k) = (10usize, 4usize);
    write_store(&dir, n, k, 4); // shards: 4, 4, 2 rows
    // Truncate the middle shard by 5 bytes.
    let shard1 = dir.join("shard_0001.bin");
    let full_len = std::fs::metadata(&shard1).unwrap().len();
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(&shard1)
        .unwrap();
    f.set_len(full_len - 5).unwrap();
    drop(f);

    // The expected byte count is computed here independently of the
    // writer: 4 rows × k columns × 4 bytes per f32 element.
    let expected_bytes = 4 * k * std::mem::size_of::<f32>();
    assert_eq!(full_len as usize, expected_bytes, "test premise");
    let actual_bytes = expected_bytes - 5;

    let reader = StoreReader::open(&dir).unwrap();
    // Healthy shards still read.
    assert!(reader.read_shard(0).is_ok());
    assert!(reader.read_shard(2).is_ok());
    // The truncated shard names its index, its on-disk path, the row/column
    // geometry, and both the expected and the actual byte counts.
    let err = format!("{:#}", reader.read_shard(1).unwrap_err());
    assert!(err.contains("shard 1"), "{err}");
    assert!(err.contains("shard_0001.bin"), "{err}");
    assert!(err.contains(&format!("require {expected_bytes} bytes")), "{err}");
    assert!(err.contains(&format!("holds {actual_bytes} bytes")), "{err}");
    assert!(err.contains(&format!("4 rows × k = {k}")), "{err}");
    assert!(err.contains("truncated or corrupted"), "{err}");
    // Every whole-store path surfaces the same failure.
    assert!(reader.read_all().is_err());
    let mut cur = reader.cursor();
    let mut buf = Vec::new();
    let mut saw_err = false;
    loop {
        match cur.next_block(&mut buf) {
            Ok(None) => break,
            Ok(Some(_)) => {}
            Err(_) => {
                saw_err = true;
                break;
            }
        }
    }
    assert!(saw_err, "cursor must surface the truncated shard");
    assert!(reader
        .par_for_each_shard(2, |_, _, _, _| Ok(()))
        .is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// The serving daemon's concurrency model: many threads stream the same
/// open store at once (each a guarded multi-worker pass, sharing one warm
/// [`ShardCache`]). Every pass must visit every row exactly once with
/// bit-correct contents — no cross-thread corruption, no double visits —
/// and the shared cache must actually serve repeat passes from memory.
#[test]
fn concurrent_guarded_readers_share_one_store_without_corruption() {
    let dir = tmpdir("concurrent");
    let (n, k) = (64usize, 8usize); // shard_rows 5 → 13 shards, ragged tail
    write_store(&dir, n, k, 5);
    let mut reader = StoreReader::open(&dir).unwrap();
    let cache = Arc::new(ShardCache::new(1 << 20));
    reader.attach_cache(cache.clone());
    // Warm the cache with one sequential pass (13 misses); the concurrent
    // passes below must then be pure hits — first-touch miss races between
    // threads would otherwise make the miss count nondeterministic.
    reader.read_all().unwrap();
    assert_eq!(cache.stats().misses as usize, reader.num_shards());
    let reader = &reader;

    std::thread::scope(|s| {
        for t in 0..4usize {
            s.spawn(move || {
                let seen = Mutex::new(vec![0usize; n]);
                reader
                    .par_for_each_block_guarded(
                        3,
                        &[],
                        2,
                        &RetryPolicy::none(),
                        false,
                        &ReadLog::default(),
                        |_, b, data, _| {
                            let mut g = seen.lock().unwrap();
                            for j in 0..b.rows {
                                g[b.start + j] += 1;
                                assert_eq!(
                                    &data[j * k..(j + 1) * k],
                                    &row(b.start + j, k)[..],
                                    "thread {t}: row {} corrupted",
                                    b.start + j
                                );
                            }
                            Ok(())
                        },
                    )
                    .unwrap();
                assert!(
                    seen.into_inner().unwrap().iter().all(|&c| c == 1),
                    "thread {t}: some row visited != once"
                );
            });
        }
    });

    // 4 passes × 13 shards with a budget holding the whole store: the
    // shared cache must have absorbed the repeat reads.
    let stats = cache.stats();
    assert!(stats.hits > 0, "shared cache saw no hits: {stats:?}");
    assert!(
        stats.misses as usize <= reader.num_shards(),
        "each shard should miss at most once: {stats:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn row_blocks_never_cross_shards_even_with_ranges() {
    let dir = tmpdir("ranges");
    let (n, k) = (20usize, 2usize);
    write_store(&dir, n, k, 6); // shard boundaries at 6, 12, 18
    let reader = StoreReader::open(&dir).unwrap();
    let blocks = reader.plan_blocks(50, &[3..15, 17..20]);
    assert_eq!(
        blocks,
        vec![
            RowBlock { start: 3, rows: 3 },
            RowBlock { start: 6, rows: 6 },
            RowBlock { start: 12, rows: 3 },
            RowBlock { start: 17, rows: 1 },
            RowBlock { start: 18, rows: 2 },
        ]
    );
    // Selected rows stream in order with correct contents.
    let mut cur = reader.cursor_with(50, &[3..15, 17..20]);
    let mut buf = Vec::new();
    let mut seen = Vec::new();
    while let Some(b) = cur.next_block(&mut buf).unwrap() {
        for j in 0..b.rows {
            seen.push((b.start + j, buf[j * k]));
        }
    }
    let want: Vec<(usize, f32)> = (3..15).chain(17..20).map(|i| (i, i as f32)).collect();
    assert_eq!(seen, want);
    std::fs::remove_dir_all(&dir).ok();
}
