//! Integration: the preconditioner subsystem end to end — fit → persist
//! (`precond.bin`) → `open_checked`-style validation → artifact-backed
//! attribution that skips the FIM pass entirely while producing identical
//! scores — plus the `grass fit` / `--precond` / `--damping grid` CLI
//! surface on a runtime-free synthetic store.

use grass::attrib::blockwise::BlockLayout;
use grass::attrib::{
    Attributor, InfluenceEngine, PrecondArtifact, PrecondSpec, StreamOpts,
};
use grass::sketch::rng::Pcg;
use grass::store::{Manifest, StoreReader, StoreWriter, PRECOND_FILE};
use std::path::PathBuf;
use std::process::Command;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("grass_precond_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn gaussian(rows: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..rows * k).map(|_| rng.next_gaussian()).collect()
}

fn write_raw_store(dir: &PathBuf, rows: &[f32], k: usize, shard_rows: usize, seed: u64) {
    let mut w = StoreWriter::create(dir, k, "raw", seed, shard_rows).unwrap();
    w.push_batch(rows).unwrap();
    w.finish().unwrap();
}

/// The roundtrip contract: fit → persist → validate → attribute twice,
/// with the artifact-backed run streaming zero FIM-pass rows and scoring
/// identically to ≤ 1e-6 relative.
#[test]
fn artifact_roundtrip_skips_fim_pass_with_identical_scores() {
    let (n, k, m) = (60usize, 16usize, 5usize);
    let dir = tmpdir("roundtrip");
    let g = gaussian(n, k, 51);
    write_raw_store(&dir, &g, k, 7, 0);
    let reader = StoreReader::open(&dir).unwrap();
    let queries = gaussian(m, k, 52);
    let opts = StreamOpts::with_budget(4096);

    // Run 1: no artifact — the FIM ingest pass streams every row.
    let mut refit = InfluenceEngine::new(k, 0.1);
    refit.cache_stream(&reader, &opts).unwrap();
    assert_eq!(Attributor::precond_stats(&refit).fim_rows, n);
    let s1 = Attributor::attribute(&refit, &queries, m).unwrap();

    // Fit + persist, then validate like open_checked.
    let layout = BlockLayout::new(vec![k]);
    let art = PrecondArtifact::fit(&reader, &opts, &layout).unwrap();
    assert_eq!(art.rows, n);
    let path = art.save(&dir).unwrap();
    assert!(path.ends_with(PRECOND_FILE));
    let loaded = PrecondArtifact::load(&dir).unwrap();
    loaded.validate_store(&reader.meta).unwrap();
    loaded.validate_layout(&layout).unwrap();

    // A store the artifact was NOT fitted on is rejected descriptively.
    let dir2 = tmpdir("roundtrip_other");
    write_raw_store(&dir2, &g, k, 7, 99); // different seed
    let other = StoreReader::open(&dir2).unwrap();
    let err = format!("{:#}", loaded.validate_store(&other.meta).unwrap_err());
    assert!(err.contains("seed") && err.contains("99"), "{err}");
    let err = format!(
        "{:#}",
        loaded
            .validate_layout(&BlockLayout::new(vec![8, 8]))
            .unwrap_err()
    );
    assert!(err.contains("[8, 8]"), "{err}");

    // Runs 2 and 3: artifact-backed — zero FIM-pass rows, same scores.
    for run in 0..2 {
        let aopts = StreamOpts {
            artifact: Some(Arc::new(loaded.clone())),
            ..StreamOpts::with_budget(4096)
        };
        let mut reused = InfluenceEngine::new(k, 0.1);
        reused.cache_stream(&reader, &aopts).unwrap();
        let stats = Attributor::precond_stats(&reused);
        assert_eq!(stats.fim_rows, 0, "run {run} streamed FIM rows");
        assert!(stats.describe.contains("damped-cholesky"), "{}", stats.describe);
        let s2 = Attributor::attribute(&reused, &queries, m).unwrap();
        assert_eq!((s2.m, s2.n), (s1.m, s1.n));
        for i in 0..m * n {
            let (a, b) = (s2.scores[i], s1.scores[i]);
            assert!(
                (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
                "run {run} score {i}: artifact {a} vs refit {b}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// One artifact serves every solver family: eig at full rank matches the
/// damped engine (≤ 1e-4 rel, the acceptance bound), and a truncated rank
/// still attributes with zero FIM-pass rows.
#[test]
fn one_artifact_serves_damped_and_eig() {
    let (n, k, m) = (48usize, 12usize, 4usize);
    let dir = tmpdir("families");
    let g = gaussian(n, k, 61);
    write_raw_store(&dir, &g, k, 9, 0);
    let reader = StoreReader::open(&dir).unwrap();
    let queries = gaussian(m, k, 62);
    let layout = BlockLayout::new(vec![k]);
    let base = StreamOpts::default();
    let art = Arc::new(PrecondArtifact::fit(&reader, &base, &layout).unwrap());
    let aopts = StreamOpts {
        artifact: Some(art),
        ..StreamOpts::default()
    };

    let mut damped = InfluenceEngine::new(k, 0.05);
    damped.cache_stream(&reader, &aopts).unwrap();
    let sd = Attributor::attribute(&damped, &queries, m).unwrap();

    let mut eig = InfluenceEngine::with_precond(
        k,
        PrecondSpec::Eig {
            rank: k,
            lambda: 0.05,
        },
    );
    eig.cache_stream(&reader, &aopts).unwrap();
    assert_eq!(Attributor::precond_stats(&eig).fim_rows, 0);
    let se = Attributor::attribute(&eig, &queries, m).unwrap();
    for i in 0..m * n {
        assert!(
            (sd.scores[i] - se.scores[i]).abs() <= 1e-4 * (1.0 + sd.scores[i].abs()),
            "at {i}: damped {} vs eig {}",
            sd.scores[i],
            se.scores[i]
        );
    }

    let mut low = InfluenceEngine::with_precond(
        k,
        PrecondSpec::Eig {
            rank: 3,
            lambda: 0.05,
        },
    );
    low.cache_stream(&reader, &aopts).unwrap();
    assert_eq!(Attributor::precond_stats(&low).fim_rows, 0);
    let sl = Attributor::attribute(&low, &queries, m).unwrap();
    assert!(sl.scores.iter().all(|v| v.is_finite()));
    std::fs::remove_dir_all(&dir).ok();
}

/// CLI: cache → attribute (full FIM pass) → fit → attribute twice
/// (zero FIM-pass rows, byte-identical ranking output), the eig family
/// from the same artifact, `--damping grid` recording the grid, and a
/// stale artifact rejected after the store is re-cached.
#[test]
fn cli_fit_then_artifact_backed_attribute() {
    let dir = tmpdir("cli");
    let dir_s = dir.to_str().unwrap().to_string();
    let exe = env!("CARGO_BIN_EXE_grass");
    let run = |cli: &[&str]| {
        let out = Command::new(exe).args(cli).output().expect("spawn grass");
        (
            out.status.success(),
            String::from_utf8_lossy(&out.stdout).to_string(),
            String::from_utf8_lossy(&out.stderr).to_string(),
        )
    };

    let (ok, stdout, stderr) = run(&[
        "cache", "--model", "synth", "--method", "sjlt:k=32", "--p", "1024", "--n", "48",
        "--seed", "5", "--store", &dir_s,
    ]);
    assert!(ok, "cache failed: {stdout}{stderr}");

    // Before fitting: the FIM pass streams all 48 rows.
    let (ok, out1, stderr) = run(&[
        "attribute", "--store", &dir_s, "--queries", "4", "--scorer", "if",
    ]);
    assert!(ok, "attribute failed: {out1}{stderr}");
    assert!(out1.contains("fim-pass rows: 48"), "{out1}");

    // Fit + persist the artifact.
    let (ok, stdout, stderr) = run(&["fit", "--store", &dir_s]);
    assert!(ok, "fit failed: {stdout}{stderr}");
    assert!(stdout.contains("48 rows"), "{stdout}");
    assert!(dir.join(PRECOND_FILE).exists());

    // After fitting: zero FIM-pass rows, identical ranking output, twice.
    let rankings = |s: &str| -> Vec<String> {
        s.lines()
            .filter(|l| l.trim_start().starts_with("query "))
            .map(|l| l.to_string())
            .collect()
    };
    let mut prev: Option<Vec<String>> = None;
    for _ in 0..2 {
        let (ok, out2, stderr) = run(&[
            "attribute", "--store", &dir_s, "--queries", "4", "--scorer", "if",
        ]);
        assert!(ok, "artifact-backed attribute failed: {out2}{stderr}");
        assert!(out2.contains("fim-pass rows: 0"), "{out2}");
        // Artifact-backed runs are deterministic: both build the solver
        // from the same persisted FIMs and write per-row score columns
        // exactly once. (Run-to-run equality with the refit path is
        // pinned at ≤ 1e-6 by the library-level roundtrip test — the
        // streaming refit's f64 merge order is worker-scheduled, so its
        // formatted output is not byte-pinned here.)
        assert!(!rankings(&out2).is_empty(), "{out2}");
        if let Some(p) = &prev {
            assert_eq!(&rankings(&out2), p, "artifact run ranking drifted");
        }
        prev = Some(rankings(&out2));
    }

    // The same artifact serves the eig family.
    let (ok, out3, stderr) = run(&[
        "attribute", "--store", &dir_s, "--queries", "4", "--scorer", "if", "--precond",
        "eig:32",
    ]);
    assert!(ok, "eig attribute failed: {out3}{stderr}");
    assert!(out3.contains("fim-pass rows: 0"), "{out3}");
    assert!(out3.contains("eig(r=32"), "{out3}");

    // Damping grid: the grid is recorded and a λ selected.
    let (ok, out4, stderr) = run(&[
        "attribute", "--store", &dir_s, "--queries", "4", "--scorer", "if", "--damping",
        "grid",
    ]);
    assert!(ok, "grid attribute failed: {out4}{stderr}");
    assert!(out4.contains("damping grid"), "{out4}");
    assert!(out4.contains("selected λ"), "{out4}");

    // Re-caching the store (new seed) strands the artifact: attribution
    // must reject it descriptively instead of silently mis-scoring.
    let (ok, stdout, stderr) = run(&[
        "cache", "--model", "synth", "--method", "sjlt:k=32", "--p", "1024", "--n", "48",
        "--seed", "6", "--store", &dir_s,
    ]);
    assert!(ok, "re-cache failed: {stdout}{stderr}");
    let (ok, stdout, stderr) = run(&[
        "attribute", "--store", &dir_s, "--queries", "4", "--scorer", "if",
    ]);
    assert!(!ok, "stale artifact must be rejected: {stdout}");
    assert!(stderr.contains("grass fit"), "{stderr}");
    // --no-artifact bypasses the stale artifact and refits.
    let (ok, out5, stderr) = run(&[
        "attribute", "--store", &dir_s, "--queries", "4", "--scorer", "if", "--no-artifact",
    ]);
    assert!(ok, "--no-artifact attribute failed: {out5}{stderr}");
    assert!(out5.contains("fim-pass rows: 48"), "{out5}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Every way `precond.bin` can rot — a bit-flipped FIM payload, a manifest
/// recording the wrong checksum, a truncated payload on a manifest-less
/// legacy store — is rejected by `grass attribute` with a descriptive
/// error, and `--no-artifact` falls back to a full refit each time.
#[test]
fn corrupt_artifacts_are_rejected_with_no_artifact_fallback() {
    let dir = tmpdir("corrupt");
    let dir_s = dir.to_str().unwrap().to_string();
    let exe = env!("CARGO_BIN_EXE_grass");
    let run = |cli: &[&str]| {
        let out = Command::new(exe).args(cli).output().expect("spawn grass");
        (
            out.status.success(),
            String::from_utf8_lossy(&out.stdout).to_string(),
            String::from_utf8_lossy(&out.stderr).to_string(),
        )
    };

    let (ok, stdout, stderr) = run(&[
        "cache", "--model", "synth", "--method", "sjlt:k=32", "--p", "512", "--n", "48",
        "--seed", "5", "--store", &dir_s,
    ]);
    assert!(ok, "cache failed: {stdout}{stderr}");
    let (ok, stdout, stderr) = run(&["fit", "--store", &dir_s]);
    assert!(ok, "fit failed: {stdout}{stderr}");
    let art = dir.join(PRECOND_FILE);
    let pristine = std::fs::read(&art).unwrap();

    let attribute = || run(&["attribute", "--store", &dir_s, "--queries", "2", "--scorer", "if"]);
    let fallback = || {
        run(&[
            "attribute", "--store", &dir_s, "--queries", "2", "--scorer", "if", "--no-artifact",
        ])
    };
    let (ok, out, stderr) = attribute();
    assert!(ok, "{out}{stderr}");
    assert!(out.contains("fim-pass rows: 0"), "{out}");

    // 1. Bit-flipped FIM payload: every length check still passes, the
    //    whole-file checksum does not.
    let mut bytes = pristine.clone();
    let last = bytes.len() - 3;
    bytes[last] ^= 0x01;
    std::fs::write(&art, &bytes).unwrap();
    let (ok, _out, stderr) = attribute();
    assert!(!ok, "bit-flipped artifact must be rejected");
    assert!(stderr.contains("failed its checksum"), "{stderr}");
    assert!(stderr.contains("--no-artifact"), "{stderr}");
    let (ok, out, stderr) = fallback();
    assert!(ok, "--no-artifact fallback failed: {out}{stderr}");
    assert!(out.contains("fim-pass rows: 48"), "{out}");

    // 2. Manifest records the wrong checksum: the pristine file no longer
    //    matches what the manifest claims.
    std::fs::write(&art, &pristine).unwrap();
    let mut man = Manifest::load(&dir).unwrap().expect("store has a manifest");
    let recorded = man.precond_crc.expect("fit recorded the artifact checksum");
    man.precond_crc = Some(recorded ^ 0xdead_beef);
    man.save(&dir).unwrap();
    let (ok, _out, stderr) = attribute();
    assert!(!ok, "manifest checksum mismatch must be rejected");
    assert!(stderr.contains("failed its checksum"), "{stderr}");
    man.precond_crc = Some(recorded);
    man.save(&dir).unwrap();
    let (ok, out, stderr) = attribute();
    assert!(ok && out.contains("fim-pass rows: 0"), "{out}{stderr}");

    // 3. Truncated payload on a manifest-less legacy store: no checksum to
    //    compare, but the exact-length check still catches it.
    std::fs::remove_file(dir.join("manifest.json")).unwrap();
    std::fs::write(&art, &pristine[..pristine.len() - 8]).unwrap();
    let (ok, _out, stderr) = attribute();
    assert!(!ok, "truncated artifact must be rejected");
    assert!(stderr.contains("bytes on disk"), "{stderr}");
    let (ok, out, stderr) = fallback();
    assert!(ok, "--no-artifact fallback failed: {out}{stderr}");
    assert!(out.contains("fim-pass rows: 48"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}
