//! Integration: end-to-end attribution quality on a real (small) workload —
//! trains the MLP via HLO train-steps, caches compressed gradients, and
//! checks that influence scores carry class-level signal: same-class
//! training samples should receive higher attribution than other-class
//! samples for a given query (the minimal sanity property LDS builds on).

use grass::attrib::influence::InfluenceEngine;
use grass::data::images::SynthDigits;
use grass::eval::retrain::{TaskData, Trainer};
use grass::runtime::Runtime;
use grass::sketch::{Compressor, MethodSpec};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime load"))
}

#[test]
fn influence_scores_carry_class_signal() {
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(&rt, "mlp").unwrap();
    let p = trainer.p;
    let n = 256;
    let m = 32;
    let train = SynthDigits::generate(n, 11);
    let test = SynthDigits::generate(m, 12);
    let train_td = TaskData::Labelled(&train);
    let test_td = TaskData::Labelled(&test);

    // Train to convergence-ish on the small set.
    let init = trainer.init(7).unwrap();
    let all: Vec<usize> = (0..n).collect();
    let params = trainer.train(init, &train_td, &all, 8, 0.2, 3).unwrap();

    // Sanity: training actually learned the task.
    let test_idx: Vec<usize> = (0..m).collect();
    let losses = trainer.losses(&params, &test_td, &test_idx).unwrap();
    let mean_loss: f32 = losses.iter().sum::<f32>() / m as f32;
    assert!(
        mean_loss < 1.8,
        "model failed to learn (mean test loss {mean_loss}; chance = ln(10) ≈ 2.3)"
    );

    // Cache: compress per-sample gradients with SJLT.
    let spec = MethodSpec::Sjlt { k: 512, s: 1 };
    let c = spec.build(p, 77);
    let g_train = trainer.grads(&params, &train_td, &all).unwrap();
    let g_test = trainer.grads(&params, &test_td, &test_idx).unwrap();
    let mut ctr = vec![0.0f32; n * 512];
    c.compress_batch(&g_train, n, &mut ctr);
    let mut cte = vec![0.0f32; m * 512];
    c.compress_batch(&g_test, m, &mut cte);

    // Attribute.
    let engine = InfluenceEngine::new(512, 1e-3);
    let scores = engine.attribute(&ctr, n, &cte, m).unwrap();

    // Class signal: mean |score| relationship — for each query, the top-10
    // attributed samples should be enriched in the query's class.
    let mut enrich = 0.0f64;
    for q in 0..m {
        let (_, yq) = test.sample(q);
        let mut order: Vec<usize> = (0..n).collect();
        let srow = &scores[q * n..(q + 1) * n];
        order.sort_by(|&a, &b| srow[b].partial_cmp(&srow[a]).unwrap());
        let hits = order[..10]
            .iter()
            .filter(|&&i| train.sample(i).1 == yq)
            .count();
        enrich += hits as f64 / 10.0;
    }
    enrich /= m as f64;
    // Base rate is ~0.1 (10 classes); demand clear enrichment.
    assert!(
        enrich > 0.25,
        "top-10 class enrichment too weak: {enrich:.3} (chance ≈ 0.1)"
    );
    eprintln!("class enrichment in top-10: {enrich:.3} (chance ≈ 0.1)");
}

#[test]
fn compressed_influence_approximates_uncompressed() {
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(&rt, "mlp").unwrap();
    let p = trainer.p;
    let (n, m) = (128, 16);
    let train = SynthDigits::generate(n, 21);
    let test = SynthDigits::generate(m, 22);
    let train_td = TaskData::Labelled(&train);
    let test_td = TaskData::Labelled(&test);
    let init = trainer.init(5).unwrap();
    let all: Vec<usize> = (0..n).collect();
    let params = trainer.train(init, &train_td, &all, 4, 0.2, 9).unwrap();
    let test_idx: Vec<usize> = (0..m).collect();
    let g_train = trainer.grads(&params, &train_td, &all).unwrap();
    let g_test = trainer.grads(&params, &test_td, &test_idx).unwrap();

    // GradDot in full space vs SJLT-compressed space: rank correlation per
    // query should be strongly positive (JL preservation of inner products).
    let full = grass::attrib::graddot::graddot_scores(&g_train, n, p, &g_test, m);
    let spec = MethodSpec::Sjlt { k: 1024, s: 1 };
    let c = spec.build(p, 3);
    let mut ctr = vec![0.0f32; n * 1024];
    c.compress_batch(&g_train, n, &mut ctr);
    let mut cte = vec![0.0f32; m * 1024];
    c.compress_batch(&g_test, m, &mut cte);
    let comp = grass::attrib::graddot::graddot_scores(&ctr, n, 1024, &cte, m);

    let mut mean_rho = 0.0;
    for q in 0..m {
        mean_rho +=
            grass::linalg::stats::spearman(&full[q * n..(q + 1) * n], &comp[q * n..(q + 1) * n]);
    }
    mean_rho /= m as f64;
    assert!(
        mean_rho > 0.7,
        "compressed GradDot lost rank structure: ρ = {mean_rho:.3}"
    );
    eprintln!("GradDot rank preservation under SJLT_1024: ρ = {mean_rho:.3}");
}
