//! Synthetic dataset substrates (DESIGN.md §5 substitutions).
//!
//! The paper's datasets (MNIST, CIFAR2, MAESTRO, WikiText, OpenWebText) are
//! unavailable offline; these generators produce learnable tasks with the
//! same tensor shapes and class/sequence structure, which is what the LDS
//! comparison between compression methods needs (it ranks methods on a
//! *fixed* task — see DESIGN.md for the argument).

pub mod corpus;
pub mod images;
pub mod queries;
pub mod synthgrad;

pub use corpus::{MusicEvents, ThemedCorpus};
pub use images::{SynthCifar2, SynthDigits};
pub use synthgrad::{SynthGrads, SynthHooks};

/// A labelled dataset of flat feature vectors.
#[derive(Debug, Clone)]
pub struct Labelled {
    /// n × feature_len, row-major.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub feature_shape: Vec<usize>,
    pub n: usize,
}

impl Labelled {
    pub fn feature_len(&self) -> usize {
        self.feature_shape.iter().product()
    }

    pub fn sample(&self, i: usize) -> (&[f32], i32) {
        let w = self.feature_len();
        (&self.x[i * w..(i + 1) * w], self.y[i])
    }

    /// Gather a batch by indices (pads by repeating the last index).
    pub fn gather(&self, idx: &[usize], batch: usize) -> (Vec<f32>, Vec<i32>) {
        let w = self.feature_len();
        let mut x = Vec::with_capacity(batch * w);
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let i = idx[b.min(idx.len() - 1)];
            x.extend_from_slice(&self.x[i * w..(i + 1) * w]);
            y.push(self.y[i]);
        }
        (x, y)
    }
}

/// A token-sequence dataset.
#[derive(Debug, Clone)]
pub struct Sequences {
    /// n × seq, row-major token ids.
    pub tokens: Vec<i32>,
    pub seq: usize,
    pub n: usize,
    /// Optional per-sequence metadata (e.g. theme id for Fig 9).
    pub tags: Vec<u32>,
}

impl Sequences {
    pub fn sample(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq..(i + 1) * self.seq]
    }

    pub fn gather(&self, idx: &[usize], batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.seq);
        for b in 0..batch {
            let i = idx[b.min(idx.len() - 1)];
            out.extend_from_slice(self.sample(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_pads_with_last() {
        let d = Labelled {
            x: vec![1.0, 2.0, 3.0, 4.0],
            y: vec![0, 1],
            feature_shape: vec![2],
            n: 2,
        };
        let (x, y) = d.gather(&[1], 3);
        assert_eq!(x, vec![3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
        assert_eq!(y, vec![1, 1, 1]);
    }

    #[test]
    fn sequences_sample() {
        let s = Sequences {
            tokens: vec![1, 2, 3, 4, 5, 6],
            seq: 3,
            n: 2,
            tags: vec![0, 1],
        };
        assert_eq!(s.sample(1), &[4, 5, 6]);
        assert_eq!(s.gather(&[0, 1], 2), vec![1, 2, 3, 4, 5, 6]);
    }
}
