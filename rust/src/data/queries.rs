//! Shared synthetic query-gradient generation.
//!
//! One implementation of "regenerate + compress `m` query gradients
//! against a store's recorded geometry", used by `grass attribute`,
//! `grass query` (client-side `--send raw|compressed` payloads), the
//! serving daemon (server-side `synth` payloads), and the integration
//! tests — so batch, served, and test scores all start from identical
//! query sketches.

use crate::coordinator::CompressorBank;
use crate::data::synthgrad::{SynthGrads, SynthHooks, SYNTH_SEQ};
use crate::sketch::Scratch;
use crate::store::StoreMeta;
use crate::Result;
use anyhow::ensure;

/// Regenerate + compress `m` synthetic query gradients against the store's
/// recorded geometry. Returns the `m × k` matrix and per-query classes.
/// Deterministic in the store seed, so every caller sees the same sketches.
pub fn synth_queries(
    meta: &StoreMeta,
    bank: &CompressorBank,
    m: usize,
) -> Result<(Vec<f32>, Vec<usize>)> {
    let mut scratch = Scratch::new();
    let k = bank.output_dim();
    if let Some(cs) = bank.as_factored() {
        let hooks = SynthHooks::new(meta.layer_dims.clone(), meta.seed);
        let mut out = vec![0.0f32; m * k];
        let mut classes = Vec::with_capacity(m);
        for q in 0..m {
            let (sample, class) = hooks.query(q);
            classes.push(class);
            let mut off = 0;
            for (li, c) in cs.iter().enumerate() {
                let (x, dy) = &sample[li];
                c.compress_batch_with(
                    1,
                    SYNTH_SEQ,
                    x,
                    dy,
                    &mut out[q * k..(q + 1) * k],
                    k,
                    off,
                    &mut scratch,
                );
                off += c.output_dim();
            }
        }
        Ok((out, classes))
    } else {
        let (raw, classes) = synth_raw_queries(meta, m)?;
        let out = compress_raw_queries(bank, &raw, m)?;
        Ok((out, classes))
    }
}

/// Uncompressed `m × input_dim` synthetic query gradients for a *flat*
/// store, regenerated from the recorded seed + density so they live on the
/// same class supports the cached train rows used. This is what a client
/// ships with `--send raw`; factored stores have no single flat gradient
/// vector and are rejected.
pub fn synth_raw_queries(meta: &StoreMeta, m: usize) -> Result<(Vec<f32>, Vec<usize>)> {
    ensure!(
        meta.layer_dims.is_empty(),
        "store method '{}' is factorized — raw query gradients are per-layer hook pairs; \
         use synthetic or pre-compressed queries instead",
        meta.method
    );
    ensure!(
        meta.input_dim > 0,
        "store records no input_dim (pre-redesign cache?); re-run `grass cache`"
    );
    let src = SynthGrads::with_density(meta.input_dim, meta.seed, meta.density as f32);
    Ok(src.queries(m))
}

/// Compress raw `m × input_dim` query gradients through a flat bank into
/// the `m × k` sketch the scorers consume — the server side of a `raw`
/// payload, and the second half of [`synth_queries`] for flat stores.
pub fn compress_raw_queries(bank: &CompressorBank, raw: &[f32], m: usize) -> Result<Vec<f32>> {
    let c = bank
        .as_flat()
        .ok_or_else(|| anyhow::anyhow!("raw query gradients need a flat (non-factorized) bank"))?;
    ensure!(
        raw.len() == m * c.input_dim(),
        "raw queries hold {} values but m = {m} × input_dim = {} requires {}",
        raw.len(),
        c.input_dim(),
        m * c.input_dim()
    );
    let k = bank.output_dim();
    let mut out = vec![0.0f32; m * k];
    let mut scratch = Scratch::new();
    c.compress_batch_with(raw, m, &mut out, &mut scratch);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::shapes::ModelShapes;
    use crate::sketch::MethodSpec;

    fn flat_meta(p: usize, seed: u64) -> (StoreMeta, CompressorBank) {
        let spec = MethodSpec::parse("sjlt:k=16").unwrap();
        let shapes = ModelShapes::flat(p);
        let bank = spec.build_bank(&shapes, seed).unwrap();
        let meta = StoreMeta::describe(&spec, seed, "synth", &shapes, 8).unwrap();
        (meta, bank)
    }

    #[test]
    fn raw_then_compress_matches_synth_queries() {
        let (meta, bank) = flat_meta(64, 9);
        let m = 3;
        let (direct, classes) = synth_queries(&meta, &bank, m).unwrap();
        let (raw, raw_classes) = synth_raw_queries(&meta, m).unwrap();
        let via_raw = compress_raw_queries(&bank, &raw, m).unwrap();
        assert_eq!(classes, raw_classes);
        assert_eq!(direct, via_raw, "raw→compress must equal the one-shot path");
        assert_eq!(direct.len(), m * bank.output_dim());
    }

    #[test]
    fn deterministic_across_calls() {
        let (meta, bank) = flat_meta(64, 9);
        let a = synth_queries(&meta, &bank, 4).unwrap();
        let b = synth_queries(&meta, &bank, 4).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn factored_store_rejects_raw_queries() {
        let spec = MethodSpec::parse("factgrass:kin=4,kout=4,kl=16").unwrap();
        let layers = crate::data::synthgrad::default_synth_layers();
        let shapes = ModelShapes::factored(layers);
        let bank = spec.build_bank(&shapes, 3).unwrap();
        let meta = StoreMeta::describe(&spec, 3, "synth", &shapes, 8).unwrap();
        let err = synth_raw_queries(&meta, 2).unwrap_err();
        assert!(err.to_string().contains("factorized"), "{err}");
        // ... but the factored synth path still works end to end.
        let (q, classes) = synth_queries(&meta, &bank, 2).unwrap();
        assert_eq!(q.len(), 2 * bank.output_dim());
        assert_eq!(classes.len(), 2);
    }

    #[test]
    fn compress_rejects_wrong_width() {
        let (_, bank) = flat_meta(64, 9);
        let err = compress_raw_queries(&bank, &[0.0; 10], 3).unwrap_err();
        assert!(err.to_string().contains("requires"), "{err}");
    }
}
