//! Synthetic image datasets: parametric digits (MNIST analogue, 14×14) and
//! two-class textured images (CIFAR2 analogue, 3×16×16).

use super::Labelled;
use crate::sketch::rng::Pcg;

/// Parametric "digits": each class is a fixed stroke template over a 14×14
//  grid; samples add per-sample jitter, elastic shift, and pixel noise.
/// Learnable by a small MLP to >90% train accuracy — enough structure for
/// LDS to discriminate attribution quality.
pub struct SynthDigits;

impl SynthDigits {
    pub const SIDE: usize = 14;
    pub const CLASSES: usize = 10;

    fn template(class: usize, x: f32, y: f32) -> f32 {
        // Simple per-class analytic stroke fields in [0,1]² → intensity.
        let (cx, cy) = (x - 0.5, y - 0.5);
        let r = (cx * cx + cy * cy).sqrt();
        match class {
            0 => (-(r - 0.32).abs() * 18.0).exp(),                      // ring
            1 => (-(cx.abs()) * 16.0).exp(),                            // vertical bar
            2 => (-((cy - cx * cx * 2.0 + 0.2).abs()) * 12.0).exp(),    // parabola
            3 => (-((cy.abs() - 0.18).abs()) * 14.0).exp(),             // two bars
            4 => (-((cx + cy).abs()) * 14.0).exp().max((-(cx.abs()) * 18.0).exp() * 0.7),
            5 => (-((cy + cx * 1.5 - 0.1).abs()) * 12.0).exp(),         // slash
            6 => (-(r - 0.25).abs() * 14.0).exp().max((-((cx + 0.2).abs()) * 16.0).exp() * 0.6),
            7 => (-((cy - 0.25).abs()) * 16.0).exp().max((-((cx - cy * 0.8).abs()) * 12.0).exp() * 0.8),
            8 => (-(((r - 0.18).abs()).min((r - 0.38).abs())) * 16.0).exp(),
            _ => (-(r - 0.3).abs() * 12.0).exp().max((-((cx - 0.15).abs()) * 14.0).exp() * 0.7),
        }
    }

    /// Generate `n` samples with labels uniform over the 10 classes.
    pub fn generate(n: usize, seed: u64) -> Labelled {
        let side = Self::SIDE;
        let mut rng = Pcg::new(seed ^ 0xD161);
        let mut x = Vec::with_capacity(n * side * side);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.next_below(Self::CLASSES);
            let dx = 0.08 * (rng.next_f32() - 0.5);
            let dy = 0.08 * (rng.next_f32() - 0.5);
            let amp = 0.8 + 0.4 * rng.next_f32();
            for py in 0..side {
                for px in 0..side {
                    let fx = px as f32 / (side - 1) as f32 + dx;
                    let fy = py as f32 / (side - 1) as f32 + dy;
                    let v = amp * Self::template(class, fx, fy) + 0.08 * rng.next_gaussian();
                    x.push(v);
                }
            }
            y.push(class as i32);
        }
        Labelled {
            x,
            y,
            feature_shape: vec![side * side],
            n,
        }
    }
}

/// Two-class textured colour images (CIFAR2 = cat-vs-dog binarised CIFAR10
/// in the paper): class 0 is low-frequency blobs, class 1 is oriented
/// high-frequency stripes, both with colour jitter and noise.
pub struct SynthCifar2;

impl SynthCifar2 {
    pub const SIDE: usize = 16;
    pub const CHANNELS: usize = 3;

    pub fn generate(n: usize, seed: u64) -> Labelled {
        let side = Self::SIDE;
        let mut rng = Pcg::new(seed ^ 0xC1FA);
        let mut x = Vec::with_capacity(n * 3 * side * side);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.next_below(2);
            let theta = rng.next_f32() * std::f32::consts::PI;
            let freq = 2.5 + 1.5 * rng.next_f32();
            let (bx, by) = (rng.next_f32(), rng.next_f32());
            let hue = [rng.next_f32(), rng.next_f32(), rng.next_f32()];
            for c in 0..3 {
                for py in 0..side {
                    for px in 0..side {
                        let fx = px as f32 / side as f32;
                        let fy = py as f32 / side as f32;
                        let base = if class == 0 {
                            // blob: gaussian bump at (bx, by)
                            let d2 = (fx - bx).powi(2) + (fy - by).powi(2);
                            (-d2 * 14.0).exp()
                        } else {
                            // stripes along theta
                            let u = fx * theta.cos() + fy * theta.sin();
                            0.5 + 0.5 * (u * freq * std::f32::consts::TAU).sin()
                        };
                        let v = base * (0.5 + 0.5 * hue[c]) + 0.1 * rng.next_gaussian();
                        x.push(v);
                    }
                }
            }
            y.push(class as i32);
        }
        Labelled {
            x,
            y,
            feature_shape: vec![3, side, side],
            n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_shapes_and_labels() {
        let d = SynthDigits::generate(100, 1);
        assert_eq!(d.n, 100);
        assert_eq!(d.feature_len(), 196);
        assert_eq!(d.x.len(), 100 * 196);
        assert!(d.y.iter().all(|&c| (0..10).contains(&c)));
        // all 10 classes present in 100 draws (overwhelmingly likely)
        let classes: std::collections::HashSet<_> = d.y.iter().collect();
        assert!(classes.len() >= 8);
    }

    #[test]
    fn digits_deterministic_per_seed() {
        let a = SynthDigits::generate(10, 5);
        let b = SynthDigits::generate(10, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = SynthDigits::generate(10, 6);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn digit_classes_are_separable() {
        // nearest-template classification on clean coordinates should beat
        // chance by a lot — the task must be learnable.
        let d = SynthDigits::generate(300, 2);
        let side = SynthDigits::SIDE;
        let mut correct = 0;
        for i in 0..d.n {
            let (xi, yi) = d.sample(i);
            let mut best = (f32::MAX, 0usize);
            for class in 0..10 {
                let mut dist = 0.0f32;
                for py in 0..side {
                    for px in 0..side {
                        let fx = px as f32 / (side - 1) as f32;
                        let fy = py as f32 / (side - 1) as f32;
                        let t = SynthDigits::template(class, fx, fy);
                        let diff = xi[py * side + px] - t;
                        dist += diff * diff;
                    }
                }
                if dist < best.0 {
                    best = (dist, class);
                }
            }
            if best.1 as i32 == yi {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n as f64;
        assert!(acc > 0.5, "template accuracy too low: {acc}");
    }

    #[test]
    fn cifar2_shapes_and_balance() {
        let d = SynthCifar2::generate(200, 3);
        assert_eq!(d.feature_len(), 3 * 16 * 16);
        let ones = d.y.iter().filter(|&&c| c == 1).count();
        assert!((40..160).contains(&ones), "class imbalance: {ones}/200");
    }
}
