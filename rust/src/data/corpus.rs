//! Synthetic token corpora: a themed byte-level corpus (WikiText /
//! OpenWebText analogue; themes give Fig-9-style qualitative attribution a
//! ground truth) and synthetic music-event sequences (MAESTRO analogue).

use super::Sequences;
use crate::sketch::rng::Pcg;

/// Themed text corpus. Each theme has a distinct vocabulary of "words"
/// (byte n-grams); documents are theme-pure word streams with shared
/// function words, so a language model learns theme-conditional statistics
/// and influence should concentrate on same-theme documents.
pub struct ThemedCorpus;

pub const THEMES: &[&str] = &["privacy", "sports", "cooking", "finance", "astronomy", "music"];

impl ThemedCorpus {
    /// Per-theme content words (byte-level tokens are the characters).
    fn theme_words(theme: usize) -> &'static [&'static str] {
        match theme {
            0 => &["privacy", "data", "policy", "consent", "tracking", "encrypt", "journalist", "leak", "gdpr", "surveillance"],
            1 => &["match", "goal", "league", "coach", "stadium", "score", "playoff", "referee", "champion", "transfer"],
            2 => &["recipe", "butter", "oven", "simmer", "garlic", "season", "knead", "roast", "whisk", "saute"],
            3 => &["market", "equity", "yield", "hedge", "dividend", "asset", "margin", "futures", "bond", "audit"],
            4 => &["galaxy", "orbit", "nebula", "telescope", "quasar", "eclipse", "comet", "parallax", "redshift", "pulsar"],
            _ => &["chord", "tempo", "melody", "sonata", "rhythm", "octave", "timbre", "cadence", "harmony", "fugue"],
        }
    }

    const FUNCTION_WORDS: &'static [&'static str] =
        &["the", "of", "and", "to", "in", "is", "for", "with", "on", "as"];

    /// Render one document of roughly `seq` bytes for a theme.
    pub fn document(theme: usize, seq: usize, rng: &mut Pcg) -> String {
        let words = Self::theme_words(theme);
        let mut doc = String::with_capacity(seq + 16);
        while doc.len() < seq + 1 {
            let w = if rng.next_f32() < 0.35 {
                Self::FUNCTION_WORDS[rng.next_below(Self::FUNCTION_WORDS.len())]
            } else {
                words[rng.next_below(words.len())]
            };
            doc.push_str(w);
            doc.push(' ');
        }
        doc
    }

    /// Generate `n` byte-level token sequences of length `seq` with theme
    /// tags. Tokens are raw bytes (vocab 256).
    pub fn generate(n: usize, seq: usize, seed: u64) -> Sequences {
        let mut rng = Pcg::new(seed ^ 0xC0FF);
        let mut tokens = Vec::with_capacity(n * seq);
        let mut tags = Vec::with_capacity(n);
        for _ in 0..n {
            let theme = rng.next_below(THEMES.len());
            let doc = Self::document(theme, seq, &mut rng);
            let bytes = doc.as_bytes();
            for t in 0..seq {
                tokens.push(bytes[t % bytes.len()] as i32);
            }
            tags.push(theme as u32);
        }
        Sequences {
            tokens,
            seq,
            n,
            tags,
        }
    }

    /// A query prompt for a theme (Fig 9 style).
    pub fn query(theme: usize, seq: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg::new(seed ^ 0x9E41);
        let doc = Self::document(theme, seq, &mut rng);
        doc.as_bytes()[..seq].iter().map(|&b| b as i32).collect()
    }
}

/// Synthetic music-event sequences (MAESTRO analogue): events are drawn
/// from a vocab of 128 (note-on/off/velocity buckets); each piece follows a
/// random walk over a scale with piece-level key and tempo structure.
pub struct MusicEvents;

impl MusicEvents {
    pub const VOCAB: usize = 128;

    pub fn generate(n: usize, seq: usize, seed: u64) -> Sequences {
        let mut rng = Pcg::new(seed ^ 0x3164);
        let scale = [0i32, 2, 4, 5, 7, 9, 11]; // major scale degrees
        let mut tokens = Vec::with_capacity(n * seq);
        let mut tags = Vec::with_capacity(n);
        for _ in 0..n {
            let key = rng.next_below(12) as i32;
            let mut degree: i32 = rng.next_below(7) as i32;
            let register = 36 + 12 * rng.next_below(3) as i32;
            for _ in 0..seq {
                // random walk over scale degrees with occasional leaps
                let step = match rng.next_below(10) {
                    0 => 4,
                    1 => -4,
                    x if x < 6 => 1,
                    _ => -1,
                };
                degree = (degree + step).rem_euclid(14);
                let octave = degree / 7;
                let pitch = register + 12 * octave + key + scale[(degree % 7) as usize];
                tokens.push(pitch.clamp(0, Self::VOCAB as i32 - 1));
            }
            tags.push(key as u32);
        }
        Sequences {
            tokens,
            seq,
            n,
            tags,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes_and_ranges() {
        let c = ThemedCorpus::generate(50, 64, 1);
        assert_eq!(c.n, 50);
        assert_eq!(c.tokens.len(), 50 * 64);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        assert!(c.tags.iter().all(|&t| (t as usize) < THEMES.len()));
    }

    #[test]
    fn documents_are_theme_distinct() {
        let mut rng = Pcg::new(2);
        let d0 = ThemedCorpus::document(0, 200, &mut rng);
        let d1 = ThemedCorpus::document(1, 200, &mut rng);
        assert!(d0.contains("privacy") || d0.contains("data") || d0.contains("consent"));
        assert!(!d1.contains("privacy"));
    }

    #[test]
    fn queries_match_theme_vocabulary() {
        let q = ThemedCorpus::query(0, 64, 3);
        let text: String = q.iter().map(|&b| b as u8 as char).collect();
        let theme_hit = ThemedCorpus::theme_words(0)
            .iter()
            .any(|w| text.contains(w));
        assert!(theme_hit, "query lacked theme words: {text}");
    }

    #[test]
    fn music_tokens_in_vocab() {
        let m = MusicEvents::generate(20, 32, 4);
        assert!(m
            .tokens
            .iter()
            .all(|&t| (0..MusicEvents::VOCAB as i32).contains(&t)));
        // sequences should not be constant
        let first = m.sample(0);
        assert!(first.iter().any(|&t| t != first[0]));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = ThemedCorpus::generate(5, 32, 9);
        let b = ThemedCorpus::generate(5, 32, 9);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.tags, b.tags);
    }
}
