//! Deterministic synthetic gradient substrate — the runtime-free source
//! behind `grass cache`/`grass attribute` smoke runs when no PJRT
//! artifacts are compiled (CI, fresh checkouts).
//!
//! Per-sample "gradients" are class template + noise: sample `i` of class
//! `c = i mod classes` draws `g_i = t_c + σ·ε_i` with a fixed per-class
//! template `t_c`. Same-class samples therefore have strongly correlated
//! gradients, so attribution scores computed on the synthetic store carry
//! real class-level signal (top-influence rows share the query's class) —
//! enough structure for an end-to-end cache → attribute smoke to assert
//! on, with no model execution anywhere.
//!
//! Everything is derived by counter-based hashing
//! ([`crate::sketch::rng::hash3`]) from `(seed, stream kind, index)`, so
//! any sample or query can be regenerated in isolation at attribute time —
//! the store only needs to record the seed. The kind goes through the full
//! mixer (never an additive salt), so the template/train/query streams
//! cannot alias at shifted indices.

use crate::sketch::rng::{hash2, hash3, to_gaussian, Pcg};
use crate::sketch::SparseRows;

/// Model name recorded in store metadata for synthetic caches.
pub const SYNTH_MODEL: &str = "synth";

/// Number of gradient classes the generator plants.
pub const SYNTH_CLASSES: usize = 8;

/// Noise scale relative to the unit-scale class template.
const NOISE: f32 = 0.5;

/// Stream kinds: templates, train-sample noise, query noise, class
/// support sets (the sparse-mode coordinate selection).
const KIND_TEMPLATE: u64 = 0x7E3B_1A01;
const KIND_TRAIN: u64 = 0x7E3B_1A02;
const KIND_QUERY: u64 = 0x7E3B_1A03;
const KIND_SUPPORT: u64 = 0x7E3B_1A04;

/// Flat synthetic per-sample gradients of dimension `p`.
///
/// With `density < 1.0` the generator is **genuinely sparse**: each class
/// owns a deterministic support of `⌈density·p⌉` coordinates, and both the
/// template and the per-sample noise live only on that support — so
/// same-class rows share their support (and correlate, like real
/// per-sample gradients whose non-zeros concentrate in the same layers)
/// while the other `p·(1 − density)` coordinates are exact zeros.
/// [`SynthGrads::rows_sparse`] emits the CSR form directly, never
/// materialising the dense row; the dense accessors scatter the same
/// values, so sparse and dense views of a sample agree bit-for-bit.
#[derive(Debug, Clone)]
pub struct SynthGrads {
    pub p: usize,
    pub seed: u64,
    /// Fraction of coordinates in each class's support; 1.0 = dense.
    pub density: f32,
    /// Memoized per-class sorted supports (sparse mode; empty when
    /// dense). Only [`SYNTH_CLASSES`] distinct supports exist, so they
    /// are sampled once at construction instead of once per row.
    supports: Vec<Vec<u32>>,
}

impl SynthGrads {
    pub fn new(p: usize, seed: u64) -> Self {
        Self::with_density(p, seed, 1.0)
    }

    /// Sparse-mode constructor: per-class supports of `⌈density·p⌉`
    /// coordinates. `density = 1.0` is the dense generator, bit-identical
    /// to [`SynthGrads::new`].
    pub fn with_density(p: usize, seed: u64, density: f32) -> Self {
        assert!(p > 0, "need a positive gradient dimension");
        assert!(
            density > 0.0 && density <= 1.0,
            "density must be in (0, 1], got {density}"
        );
        let supports = if density < 1.0 {
            let k = ((density as f64 * p as f64).ceil() as usize).clamp(1, p);
            (0..SYNTH_CLASSES)
                .map(|class| {
                    let mut rng = Pcg::new(hash3(seed, KIND_SUPPORT, class as u64));
                    rng.sample_distinct(p, k)
                })
                .collect()
        } else {
            Vec::new()
        };
        Self {
            p,
            seed,
            density,
            supports,
        }
    }

    /// Non-zeros per row in sparse mode (= `p` when dense).
    pub fn nnz_per_row(&self) -> usize {
        if self.density >= 1.0 {
            self.p
        } else {
            self.supports[0].len()
        }
    }

    /// Sparse-mode values on the class support: template + noise, both
    /// counter-addressed per coordinate so any row regenerates in
    /// isolation.
    fn sparse_pairs(&self, class: usize, noise_root: u64) -> (&[u32], Vec<f32>) {
        let idx = &self.supports[class];
        let tkey = hash3(self.seed, KIND_TEMPLATE, class as u64);
        let vals = idx
            .iter()
            .map(|&j| {
                let t = to_gaussian(hash3(tkey, j as u64, 0), hash3(tkey, j as u64, 1));
                let e = to_gaussian(hash3(noise_root, j as u64, 0), hash3(noise_root, j as u64, 1));
                t + NOISE * e
            })
            .collect();
        (idx, vals)
    }

    fn template(&self, class: usize, out: &mut [f32]) {
        let mut rng = Pcg::new(hash3(self.seed, KIND_TEMPLATE, class as u64));
        for v in out.iter_mut() {
            *v = rng.next_gaussian();
        }
    }

    fn fill(&self, class: usize, noise_stream: u64, out: &mut [f32]) {
        if self.density < 1.0 {
            // Dense view of the sparse generator: scatter the exact values
            // the CSR path emits, zeros elsewhere.
            out.fill(0.0);
            let (idx, vals) = self.sparse_pairs(class, noise_stream);
            for (&j, &v) in idx.iter().zip(&vals) {
                out[j as usize] = v;
            }
            return;
        }
        self.template(class, out);
        let mut rng = Pcg::new(noise_stream);
        for v in out.iter_mut() {
            *v += NOISE * rng.next_gaussian();
        }
    }

    /// Class label of train sample `i`.
    pub fn class(&self, i: usize) -> usize {
        i % SYNTH_CLASSES
    }

    /// Train sample `i`'s gradient.
    pub fn row(&self, i: usize) -> Vec<f32> {
        let mut g = vec![0.0f32; self.p];
        self.fill(self.class(i), hash3(self.seed, KIND_TRAIN, i as u64), &mut g);
        g
    }

    /// Contiguous `count × p` block starting at train index `start`.
    pub fn rows(&self, start: usize, count: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; count * self.p];
        for (off, chunk) in out.chunks_mut(self.p).enumerate() {
            let i = start + off;
            self.fill(self.class(i), hash3(self.seed, KIND_TRAIN, i as u64), chunk);
        }
        out
    }

    /// Contiguous CSR block of `count` train rows starting at `start`,
    /// built directly in index space — `O(count · nnz)`, never touching
    /// the `p·(1 − density)` zero coordinates. Works at any density
    /// (dense rows just store all `p` entries).
    pub fn rows_sparse(&self, start: usize, count: usize) -> SparseRows {
        let mut out = SparseRows::new(self.p);
        if self.density >= 1.0 {
            let all: Vec<u32> = (0..self.p as u32).collect();
            let mut buf = vec![0.0f32; self.p];
            for off in 0..count {
                let i = start + off;
                self.fill(self.class(i), hash3(self.seed, KIND_TRAIN, i as u64), &mut buf);
                out.push_row(&all, &buf);
            }
            return out;
        }
        for off in 0..count {
            let i = start + off;
            let (idx, vals) =
                self.sparse_pairs(self.class(i), hash3(self.seed, KIND_TRAIN, i as u64));
            out.push_row(idx, &vals);
        }
        out
    }

    /// Query `q`'s gradient (distinct noise stream from every train
    /// sample) and its class label `q mod classes`.
    pub fn query(&self, q: usize) -> (Vec<f32>, usize) {
        let class = q % SYNTH_CLASSES;
        let mut g = vec![0.0f32; self.p];
        self.fill(class, hash3(self.seed, KIND_QUERY, q as u64), &mut g);
        (g, class)
    }

    /// Contiguous `count × p` query block starting at query index 0.
    pub fn queries(&self, count: usize) -> (Vec<f32>, Vec<usize>) {
        let mut out = vec![0.0f32; count * self.p];
        let mut classes = Vec::with_capacity(count);
        for (q, chunk) in out.chunks_mut(self.p).enumerate() {
            let class = q % SYNTH_CLASSES;
            self.fill(class, hash3(self.seed, KIND_QUERY, q as u64), chunk);
            classes.push(class);
        }
        (out, classes)
    }
}

/// Default hooked-layer geometry for factorized synthetic caches.
pub fn default_synth_layers() -> Vec<(usize, usize)> {
    vec![(96, 64), (64, 96)]
}

/// Timesteps per synthetic hook sample.
pub const SYNTH_SEQ: usize = 4;

/// Factorized synthetic hooks: per-layer `(x: T×d_in, dy: T×d_out)` pairs
/// with the same class-template structure as [`SynthGrads`].
#[derive(Debug, Clone)]
pub struct SynthHooks {
    pub layers: Vec<(usize, usize)>,
    pub seed: u64,
}

impl SynthHooks {
    pub fn new(layers: Vec<(usize, usize)>, seed: u64) -> Self {
        assert!(!layers.is_empty(), "need at least one hooked layer");
        Self { layers, seed }
    }

    pub fn class(&self, i: usize) -> usize {
        i % SYNTH_CLASSES
    }

    fn sample_with(&self, class: usize, noise_root: u64) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.layers
            .iter()
            .enumerate()
            .map(|(li, &(d_in, d_out))| {
                let flat = SynthGrads::new(SYNTH_SEQ * (d_in + d_out), hash2(self.seed, li as u64));
                let mut buf = vec![0.0f32; SYNTH_SEQ * (d_in + d_out)];
                flat.fill(class, hash2(noise_root, li as u64), &mut buf);
                let dy = buf.split_off(SYNTH_SEQ * d_in);
                (buf, dy)
            })
            .collect()
    }

    /// Train sample `i`'s per-layer hooks.
    pub fn sample(&self, i: usize) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.sample_with(self.class(i), hash3(self.seed, KIND_TRAIN, i as u64))
    }

    /// Query `q`'s per-layer hooks and class label.
    pub fn query(&self, q: usize) -> (Vec<(Vec<f32>, Vec<f32>)>, usize) {
        let class = q % SYNTH_CLASSES;
        (
            self.sample_with(class, hash3(self.seed, KIND_QUERY, q as u64)),
            class,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_index_addressable() {
        let g = SynthGrads::new(64, 7);
        assert_eq!(g.row(5), g.row(5));
        let block = g.rows(3, 4);
        assert_eq!(&block[64..128], g.row(4).as_slice());
        let (q0, c0) = g.query(0);
        assert_eq!(c0, 0);
        assert_ne!(q0, g.row(0), "query stream must differ from train stream");
        let (qs, classes) = g.queries(3);
        assert_eq!(&qs[64..128], g.query(1).0.as_slice());
        assert_eq!(classes, vec![0, 1, 2]);
    }

    #[test]
    fn same_class_rows_correlate_more_than_cross_class() {
        let g = SynthGrads::new(256, 11);
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        // samples 0 and 8 share class 0; sample 1 is class 1
        let (a, b, c) = (g.row(0), g.row(SYNTH_CLASSES), g.row(1));
        assert!(
            dot(&a, &b) > dot(&a, &c),
            "planted class structure missing: {} vs {}",
            dot(&a, &b),
            dot(&a, &c)
        );
    }

    #[test]
    fn sparse_mode_matches_dense_view_and_keeps_class_signal() {
        let g = SynthGrads::with_density(512, 5, 0.05);
        // CSR and dense views of the same sample agree bit-for-bit.
        let sp = g.rows_sparse(2, 3);
        assert_eq!(sp.to_dense(), g.rows(2, 3));
        assert_eq!(sp.n(), 3);
        // Every row carries exactly the support's nnz.
        assert_eq!(sp.nnz(0), g.nnz_per_row());
        assert!((sp.density() as f32 - 0.05).abs() < 0.01);
        // Same-class rows share their support and correlate above
        // cross-class rows (which overlap in only ~density² of coords).
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let (a, b, c) = (g.row(0), g.row(SYNTH_CLASSES), g.row(1));
        assert!(
            dot(&a, &b) > dot(&a, &c),
            "sparse class structure missing: {} vs {}",
            dot(&a, &b),
            dot(&a, &c)
        );
        // Queries live on the same class supports, so attribute-time
        // queries correlate with sparse cached rows.
        let (q, class) = g.query(0);
        assert_eq!(class, 0);
        assert!(dot(&q, &a) > dot(&q, &c));
        // Determinism + full-density CSR fallback.
        assert_eq!(g.rows_sparse(2, 3), g.rows_sparse(2, 3));
        let dense = SynthGrads::new(64, 9);
        assert_eq!(dense.rows_sparse(0, 2).to_dense(), dense.rows(0, 2));
    }

    #[test]
    fn hooks_shapes_and_determinism() {
        let h = SynthHooks::new(vec![(24, 16), (16, 8)], 3);
        let s = h.sample(2);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].0.len(), SYNTH_SEQ * 24);
        assert_eq!(s[0].1.len(), SYNTH_SEQ * 16);
        assert_eq!(s[1].0.len(), SYNTH_SEQ * 16);
        assert_eq!(s[1].1.len(), SYNTH_SEQ * 8);
        assert_eq!(h.sample(2), h.sample(2));
        let (q, class) = h.query(1);
        assert_eq!(class, 1);
        assert_eq!(q[0].0.len(), SYNTH_SEQ * 24);
    }
}
