//! Experiment configuration: JSON-file configs with CLI overrides, so every
//! table/figure run is reproducible from a checked-in config plus a seed.

use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::Result;
use std::path::Path;

/// Scale of a quantitative experiment (Table 1 family).
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Training-set size drawn from the synthetic generator.
    pub n_train: usize,
    /// Test/query-set size.
    pub n_test: usize,
    /// TRAK checkpoints (Table 1a–c).
    pub checkpoints: usize,
    /// LDS subsets.
    pub subsets: usize,
    /// Subset fraction (paper: 0.5).
    pub subset_frac: f64,
    /// SGD epochs per (re)train.
    pub epochs: usize,
    pub lr: f32,
    /// Compression dimensions to sweep.
    pub ks: Vec<usize>,
    pub seed: u64,
    /// Fast mode shrinks everything for CI smoke runs.
    pub fast: bool,
}

impl Default for ExpConfig {
    fn default() -> Self {
        Self {
            n_train: 2000,
            n_test: 128,
            checkpoints: 3,
            subsets: 16,
            subset_frac: 0.5,
            epochs: 3,
            lr: 0.1,
            ks: vec![512, 1024, 2048],
            seed: 42,
            fast: false,
        }
    }
}

impl ExpConfig {
    /// Load from a JSON file (missing keys fall back to defaults).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let j = Json::parse(&std::fs::read_to_string(path)?)?;
        let mut cfg = Self::default();
        cfg.apply_json(&j);
        Ok(cfg)
    }

    fn apply_json(&mut self, j: &Json) {
        let get = |k: &str| j.get(k).and_then(|v| v.as_usize());
        if let Some(v) = get("n_train") {
            self.n_train = v;
        }
        if let Some(v) = get("n_test") {
            self.n_test = v;
        }
        if let Some(v) = get("checkpoints") {
            self.checkpoints = v;
        }
        if let Some(v) = get("subsets") {
            self.subsets = v;
        }
        if let Some(v) = j.get("subset_frac").and_then(|v| v.as_f64()) {
            self.subset_frac = v;
        }
        if let Some(v) = get("epochs") {
            self.epochs = v;
        }
        if let Some(v) = j.get("lr").and_then(|v| v.as_f64()) {
            self.lr = v as f32;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_u64()) {
            self.seed = v;
        }
        if let Some(arr) = j.get("ks").and_then(|v| v.as_arr()) {
            self.ks = arr.iter().filter_map(|v| v.as_usize()).collect();
        }
    }

    /// Apply CLI overrides (`--n-train`, `--subsets`, `--ks 512,1024`, …).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.n_train = args.get_usize("n-train", self.n_train)?;
        self.n_test = args.get_usize("n-test", self.n_test)?;
        self.checkpoints = args.get_usize("checkpoints", self.checkpoints)?;
        self.subsets = args.get_usize("subsets", self.subsets)?;
        self.subset_frac = args.get_f64("subset-frac", self.subset_frac)?;
        self.epochs = args.get_usize("epochs", self.epochs)?;
        self.lr = args.get_f64("lr", self.lr as f64)? as f32;
        self.seed = args.get_u64("seed", self.seed)?;
        self.ks = args.get_usize_list("ks", &self.ks)?;
        if args.get_bool("fast") {
            self.fast = true;
            self.n_train = self.n_train.min(400);
            self.n_test = self.n_test.min(32);
            self.checkpoints = self.checkpoints.min(2);
            self.subsets = self.subsets.min(6);
            self.epochs = self.epochs.min(1);
            self.ks.truncate(1);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let mut cfg = ExpConfig::default();
        let args = Args::parse(
            ["x", "--n-train", "100", "--ks", "8,16", "--seed", "9"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.n_train, 100);
        assert_eq!(cfg.ks, vec![8, 16]);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn fast_mode_shrinks() {
        let mut cfg = ExpConfig::default();
        let args =
            Args::parse(["x", "--fast"].iter().map(|s| s.to_string())).unwrap();
        cfg.apply_args(&args).unwrap();
        assert!(cfg.fast);
        assert!(cfg.n_train <= 400);
        assert_eq!(cfg.ks.len(), 1);
    }

    #[test]
    fn from_json_roundtrip() {
        let dir = std::env::temp_dir().join(format!("grass_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"n_train": 77, "ks": [4], "lr": 0.5}"#).unwrap();
        let cfg = ExpConfig::from_file(&p).unwrap();
        assert_eq!(cfg.n_train, 77);
        assert_eq!(cfg.ks, vec![4]);
        assert!((cfg.lr - 0.5).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
