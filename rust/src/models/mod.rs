//! Model geometry registry.
//!
//! The Rust side needs layer shapes in two places: (1) the Table 2
//! throughput harness runs the factorized compressors over the *exact*
//! Llama-3.1-8B linear-layer geometry with synthetic activations (the
//! paper's billion-scale experiment measures compression throughput, which
//! depends only on shapes); (2) the attribution pipeline maps manifest
//! layer metadata onto compressors.

pub mod shapes;

pub use shapes::{gpt2_small_layers, llama8b_layers, LayerShape};
