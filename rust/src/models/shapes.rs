//! Linear-layer geometries of the models the paper evaluates.

/// One linear layer's shape within a transformer block stack.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerShape {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
    /// How many times this layer repeats across the model (blocks).
    pub count: usize,
}

impl LayerShape {
    pub fn new(name: &str, d_in: usize, d_out: usize, count: usize) -> Self {
        Self {
            name: name.to_string(),
            d_in,
            d_out,
            count,
        }
    }

    pub fn params(&self) -> usize {
        self.d_in * self.d_out * self.count
    }
}

/// The gradient geometry a compressor bank is built against — the one
/// argument [`crate::sketch::MethodSpec::build_bank`] needs.
///
/// Flat compressors consume `p` (the flattened gradient dimension);
/// factorized compressors consume the per-layer `(d_in, d_out)` pairs of
/// the hooked linear layers. Both views live here so every construction
/// site (CLI, coordinator, experiment harnesses, store validation) shares
/// one shape vocabulary.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ModelShapes {
    /// Flat gradient dimension `p` (0 when only hooked layers are known).
    pub p: usize,
    /// Hooked linear layers as `(d_in, d_out)` pairs (empty for flat-only
    /// models).
    pub layers: Vec<(usize, usize)>,
}

impl ModelShapes {
    /// Flat-gradient geometry only.
    pub fn flat(p: usize) -> Self {
        Self { p, layers: vec![] }
    }

    /// Hooked-layer geometry; `p` is the summed linear parameter count.
    pub fn factored(layers: Vec<(usize, usize)>) -> Self {
        let p = layers.iter().map(|&(i, o)| i * o).sum();
        Self { p, layers }
    }

    /// A single hooked layer (ablation sweeps, micro-benchmarks).
    pub fn single(d_in: usize, d_out: usize) -> Self {
        Self::factored(vec![(d_in, d_out)])
    }

    /// One bank entry per distinct [`LayerShape`] (the Table 2 harness
    /// builds one compressor per shape and replays it `count` times).
    pub fn from_layer_shapes(layers: &[LayerShape]) -> Self {
        Self::factored(layers.iter().map(|l| (l.d_in, l.d_out)).collect())
    }
}

/// Llama-3.1-8B linear layers (paper §4.2 substrate): 32 blocks,
/// d_model = 4096, GQA with 8 KV heads (so k/v project to 1024), SwiGLU
/// FFN with intermediate 14336. Vocab/embedding layers are excluded, as in
/// LoGra, which hooks only the block linear layers.
pub fn llama8b_layers() -> Vec<LayerShape> {
    let d = 4096;
    let kv = 1024; // 8 KV heads × 128
    let ff = 14336;
    vec![
        LayerShape::new("q_proj", d, d, 32),
        LayerShape::new("k_proj", d, kv, 32),
        LayerShape::new("v_proj", d, kv, 32),
        LayerShape::new("o_proj", d, d, 32),
        LayerShape::new("gate_proj", d, ff, 32),
        LayerShape::new("up_proj", d, ff, 32),
        LayerShape::new("down_proj", ff, d, 32),
    ]
}

/// GPT-2 small linear layers (paper Table 1d substrate): 12 blocks,
/// d_model = 768, fused qkv, 4× FFN.
pub fn gpt2_small_layers() -> Vec<LayerShape> {
    let d = 768;
    vec![
        LayerShape::new("qkv", d, 3 * d, 12),
        LayerShape::new("proj", d, d, 12),
        LayerShape::new("fc1", d, 4 * d, 12),
        LayerShape::new("fc2", 4 * d, d, 12),
    ]
}

/// Total parameter count over a layer stack.
pub fn total_params(layers: &[LayerShape]) -> usize {
    layers.iter().map(|l| l.params()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama8b_block_params_match_published_architecture() {
        let layers = llama8b_layers();
        // Per-block linear params: q 16.8M + k 4.2M + v 4.2M + o 16.8M
        // + gate 58.7M + up 58.7M + down 58.7M ≈ 218M; ×32 ≈ 6.98B —
        // the linear-layer share of the 8B total (rest: embeddings, norms).
        let total = total_params(&layers);
        assert!(
            (6_800_000_000..7_200_000_000).contains(&total),
            "unexpected Llama-8B linear total: {total}"
        );
        assert_eq!(layers.iter().map(|l| l.count).max(), Some(32));
    }

    #[test]
    fn gpt2_small_matches_124m_share() {
        let total = total_params(&gpt2_small_layers());
        // 12 × (768·2304 + 768·768 + 768·3072 + 3072·768) ≈ 85M of the 124M.
        assert!((80_000_000..90_000_000).contains(&total), "{total}");
    }

    #[test]
    fn layer_params() {
        let l = LayerShape::new("x", 10, 20, 3);
        assert_eq!(l.params(), 600);
    }

    #[test]
    fn model_shapes_views() {
        assert_eq!(ModelShapes::flat(42).p, 42);
        assert!(ModelShapes::flat(42).layers.is_empty());
        let s = ModelShapes::factored(vec![(4, 6), (6, 2)]);
        assert_eq!(s.p, 4 * 6 + 6 * 2);
        assert_eq!(ModelShapes::single(3, 5).layers, vec![(3, 5)]);
        let from = ModelShapes::from_layer_shapes(&[
            LayerShape::new("a", 8, 8, 32),
            LayerShape::new("b", 8, 16, 32),
        ]);
        assert_eq!(from.layers, vec![(8, 8), (8, 16)]);
    }
}
