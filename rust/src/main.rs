//! `grass` — the leader CLI.
//!
//! ```text
//! grass exp fig4 [--ks 512,...] [--out results.json]
//! grass exp table1a|table1b|table1c|table1d [--fast] [--ks ...] [...]
//! grass exp table2 [--ks 256,1024,4096] [--tokens 256] [--reps 8]
//! grass exp fig9 [--kl 256]
//! grass cache --model mlp --method sjlt:k=1024 --n 1000 --store DIR [--resume] [--dtype f16]
//! grass quantize --store DIR --dtype f16 [--out DIR]
//! grass fit --store DIR [--precond damped|blockwise|eig:r]
//! grass attribute --store DIR --queries 8 --scorer if [--precond ...] [--damping grid]
//! grass verify --store DIR [--upgrade]
//! grass info
//! ```
//!
//! Exit codes: 0 success, 1 error, 2 verify failed / corruption detected,
//! 3 attribution completed degraded (`--skip-corrupt` quarantined shards).

use anyhow::{anyhow, bail, ensure, Result};
use grass::attrib::precond::select;
use grass::attrib::{
    from_spec, AttributionSpec, Attributor, PrecondArtifact, PrecondSpec, Preconditioner,
    ScoreMatrix, StreamOpts, DEFAULT_MEM_BUDGET,
};
use grass::config::ExpConfig;
use grass::coordinator::{pipeline::Source, CachePipeline, CompressorBank, PipelineConfig};
use grass::data::corpus::ThemedCorpus;
use grass::data::images::SynthDigits;
use grass::data::queries::{compress_raw_queries, synth_queries, synth_raw_queries};
use grass::data::synthgrad::{
    default_synth_layers, SYNTH_CLASSES, SYNTH_MODEL, SYNTH_SEQ, SynthGrads, SynthHooks,
};
use grass::exp;
use grass::serve;
use grass::serve::proto::{self, CoverageInfo, QueryPayload, Request, Response, ScoreRequest};
use grass::util::json::Json;
use grass::models::shapes::ModelShapes;
use grass::runtime::{Arg, Runtime};
use grass::sketch::{MethodSpec, Scratch};
use grass::store::{
    PayloadDtype, RetryPolicy, RowGroups, StoreMeta, StoreReader, StoreWriter, DEFAULT_SHARD_ROWS,
};
use grass::util::cli::Args;
use std::path::Path;

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run() -> Result<i32> {
    let args = Args::from_env()?;
    // Global escape hatch: pin every kernel to the scalar reference path
    // (equivalent to GRASS_NO_SIMD=1) for A/B timing or sidestepping a
    // suspect vector path in the field. Must run before any kernel does.
    if args.get_bool("no-simd") {
        grass::linalg::simd::set_simd_enabled(false);
    }
    match args.subcommand.as_deref() {
        Some("exp") => run_exp(&args).map(|()| 0),
        Some("cache") => run_cache(&args).map(|()| 0),
        Some("fit") => run_fit(&args).map(|()| 0),
        Some("attribute") => run_attribute(&args),
        Some("verify") => run_verify(&args),
        Some("quantize") => run_quantize(&args).map(|()| 0),
        Some("serve") => run_serve(&args).map(|()| 0),
        Some("query") => run_query(&args),
        Some("info") => run_info().map(|()| 0),
        _ => {
            print_help();
            Ok(0)
        }
    }
}

fn print_help() {
    println!(
        "grass — Scalable Data Attribution with Gradient Sparsification and Sparse Projection

USAGE:
  grass exp <fig4|table1a|table1b|table1c|table1d|table2|fig9|ablation|all> [flags]
  grass cache --model <mlp|resnet_lite|gpt2_tiny|music|synth> --method <spec>
              [--n N] [--p P] [--seed S] [--store DIR] [--fast]
              [--density 0.01 (flat synth: sparse gradients via CSR kernels)]
              [--shard-rows R|0=auto] [--mem-budget 256M]
              [--resume (continue a killed run from its committed shards)]
              [--throttle-ms T (slow the synthetic writer; crash-testing aid)]
              [--dtype f32|f16|bf16|int8 (payload codec; f32 default)]
  grass quantize --store DIR --dtype <f16|bf16|int8>
                 [--out DIR (default: rewrite the store in place)]
  grass fit --store DIR [--precond damped|blockwise|eig:r[,λ]] [--damping 1e-3]
            [--mem-budget 256M] [--workers N]
  grass attribute --store DIR [--queries M] [--scorer if|graddot|trak|tracin|blockwise]
                  [--precond identity|damped:λ|eig:r[,λ]|blockwise]
                  [--damping 1e-3|grid] [--top 5] [--self-influence]
                  [--mem-budget 256M] [--workers N] [--row-groups 0..512,512..1024|block=N]
                  [--no-artifact] [--method <spec> --seed S to cross-check the store]
                  [--retries 2] [--retry-backoff 50 (ms)]
                  [--skip-corrupt (quarantine bad shards, score the rest; exit 3)]
                  [--format text|json] [--shard-cache 0 (warm shard-byte LRU budget)]
  grass verify --store DIR [--upgrade (write a manifest over a legacy store)]
  grass serve --store DIR --addr HOST:PORT [--scorers if,graddot] [--workers 2]
              [--max-queue 32] [--deadline-ms 10000] [--shard-cache 256M]
              [--mem-budget 256M] [--skip-corrupt] [--verify] [--no-artifact]
              [--retries 2] [--retry-backoff 50] [--damping 1e-3] [--precond SPEC]
              [--drain-ms 5000] [--idle-ms 30000] [--breaker 3] [--quiet]
  grass query --addr HOST:PORT [--queries M] [--scorer if] [--top 5]
              [--send synth|raw|compressed (raw/compressed need --store DIR)]
              [--include-scores] [--self-influence] [--deadline-ms B]
              [--timeout-ms T (connect/read budget; 0 = block forever)]
              [--stats | --ping | --shutdown | --reload [--store DIR]]
              [--format text|json]
  grass info

EXIT CODES:
  0 success | 1 error | 2 verify failed / corruption detected |
  3 attribution completed degraded (--skip-corrupt quarantined shards) |
  4 query shed by the daemon (typed overloaded / deadline_exceeded reply)

COMMON FLAGS:
  --ks 512,1024,2048    compression dimensions
  --n-train / --n-test / --subsets / --checkpoints / --epochs / --lr / --seed
  --fast                shrink everything for a smoke run
  --no-simd             pin every kernel to the scalar reference path
                        (any subcommand; env equivalent GRASS_NO_SIMD=1)
  --out results.json    append table to a JSON report

METHOD SPECS (flat):        rm:k=.. | sm:k=.. | sjlt:k=..,s=1 | gauss:k=.. |
                            fjlt:k=.. | grass:k=..,kp=..,mask=rm|sm
METHOD SPECS (factorized,   factgrass:kin=..,kout=..,kl=..,mask=rm|sm |
 per hooked layer):         logra:kin=..,kout=.. | factsjlt:kin=..,kout=.. |
                            factmask:kin=..,kout=..,mask=rm|sm

`grass attribute` streams the store out-of-core: train rows are read one
shard block per worker under --mem-budget, so stores far larger than RAM
attribute correctly; --row-groups aggregates scores per row group
(GGDA-style). The second-order solve is pluggable (--precond): identity,
damped Cholesky, an eigen-truncated low-rank inverse (eig:r — O(k·r) per
row), or the per-layer blockwise family. `grass fit` streams the FIM once
and persists it as precond.bin next to store.json; later attribute runs
validate and reuse it, reporting `fim-pass rows: 0`. `--damping grid`
selects λ over the paper's grid by LDS on held-out subsets. For banks whose kernels profit from CSR input (sjlt,
logra, factsjlt), the pipeline's grad workers density-probe each
gradient batch and auto-dispatch between the dense batch kernels and the
nnz-proportional CSR kernels (sparse/dense counts and observed input
density appear in the pipeline metrics). Stores are fault-tolerant:
every shard commits atomically (tmpfile → fsync → rename) with its
CRC32C recorded in manifest.json, `grass cache --resume` restarts a
killed run from its committed shards, `grass verify` scans every
checksum, and `grass attribute --retries/--skip-corrupt` retries
transient read errors and can score around corrupt shards (coverage
reported, exit code 3). Shard payloads are quantizable (`--dtype
f16|bf16|int8` at cache time, or `grass quantize` offline): rows are
encoded on commit and dequantized on read, fused into the streaming
scorers, so f16/bf16 halve and int8 roughly quarter the shard bytes;
stores without a recorded dtype read as f32. `grass serve` keeps all of that state hot in a
long-running daemon — store opened once per epoch, bank + precond
artifact resident, warm shard cache with prefetch — answering scoring
requests over newline-delimited JSON/TCP with admission control (queue
bound + deadlines → typed overloaded/deadline_exceeded replies) and
per-reply coverage; `grass query` is the client. The daemon is
supervised: worker panics answer with a typed internal error and the
worker respawns, shards that keep failing reads trip a circuit breaker
(--breaker), byte-dribbling clients are reaped after --idle-ms, and
SIGTERM/SIGINT or `grass query --shutdown` drains in-flight work within
--drain-ms before dumping final metrics. `grass query --reload` swaps in
a rewritten/appended store (optionally from a new --store DIR) with zero
downtime. Full reference: docs/CLI.md;
data-flow and memory model: docs/ARCHITECTURE.md."
    );
}

fn run_info() -> Result<()> {
    let rt = Runtime::load(Runtime::artifacts_dir())?;
    println!("platform: {}", rt.platform());
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for (name, spec) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            spec.inputs.len(),
            spec.outputs.len(),
            spec.file
        );
    }
    println!("models:");
    for (name, meta) in &rt.manifest.models {
        println!(
            "  {name}: P = {}, {} hooked layers",
            meta.p,
            meta.layers.len()
        );
    }
    Ok(())
}

fn run_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out = args.get("out");

    // Pure-CPU experiments that need no PJRT artifacts:
    if which == "fig4" {
        let ks = args.get_usize_list("ks", &[512, 2048, 8192])?;
        let budget = args.get_u64("budget-ms", 300)?;
        let t = exp::fig4::run(&ks, budget, out)?;
        t.print();
        return Ok(());
    }
    if which == "ablation" {
        let p = args.get_usize("p", 131_072)?;
        let k = args.get_usize("k", 2048)?;
        exp::ablation::run_grass_kprime(p, k, out)?.print();
        exp::ablation::run_factgrass_blowup(out)?.print();
        return Ok(());
    }
    if which == "table2" {
        let ks = args.get_usize_list("ks", &[256, 1024, 4096])?;
        let tokens = args.get_usize("tokens", 256)?;
        let reps = args.get_usize("reps", 4)?;
        let t = exp::table2::run(&ks, tokens, reps, out)?;
        t.print();
        return Ok(());
    }

    let rt = Runtime::load(Runtime::artifacts_dir())?;
    let mut cfg = ExpConfig::default();
    if let Some(path) = args.get("config") {
        cfg = ExpConfig::from_file(path)?;
    }
    cfg.apply_args(args)?;

    let save = |t: &exp::report::Table| -> Result<()> {
        t.print();
        if let Some(path) = out {
            t.save(path)?;
        }
        Ok(())
    };

    match which {
        "table1a" => save(&exp::table1::run_table1a(&rt, &cfg)?)?,
        "table1b" => save(&exp::table1::run_table1b(&rt, &cfg)?)?,
        "table1c" => save(&exp::table1::run_table1c(&rt, &cfg)?)?,
        "table1d" => {
            let mut c = cfg.clone();
            if args.get("ks").is_none() {
                c.ks = vec![16, 64, 256]; // per-layer k_l (perfect squares)
            }
            save(&exp::table1::run_table1d(&rt, &c)?)?;
        }
        "fig9" => {
            let kl = args.get_usize("kl", 256)?;
            let outcome = exp::fig9::run(&rt, &cfg, kl)?;
            outcome.table.print();
            println!(
                "top-10 same-theme fraction: {:.0}% (query theme: {})",
                outcome.top10_theme_hit * 100.0,
                outcome.query_theme
            );
        }
        "all" => {
            save(&exp::table1::run_table1a(&rt, &cfg)?)?;
            save(&exp::table1::run_table1b(&rt, &cfg)?)?;
            save(&exp::table1::run_table1c(&rt, &cfg)?)?;
            let mut c = cfg.clone();
            c.ks = vec![16, 64, 256];
            save(&exp::table1::run_table1d(&rt, &c)?)?;
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// cache
// ---------------------------------------------------------------------------

fn run_cache(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mlp").to_string();
    let spec = MethodSpec::parse(args.get_or("method", "sjlt:k=1024"))?;
    let fast = args.get_bool("fast");
    let n = args.get_usize("n", if fast { 64 } else { 1000 })?;
    let seed = args.get_u64("seed", 42)?;
    let store = args.get_or("store", "grass_store").to_string();

    if model == SYNTH_MODEL {
        return cache_synthetic(&spec, n, seed, &store, args);
    }
    match Runtime::load(Runtime::artifacts_dir()) {
        Ok(rt) => cache_with_runtime(&rt, &model, &spec, n, seed, &store, args),
        Err(e) => {
            eprintln!(
                "warning: PJRT runtime unavailable ({e:#}); caching from the \
                 deterministic synthetic gradient source instead (model '{SYNTH_MODEL}')"
            );
            cache_synthetic(&spec, n, seed, &store, args)
        }
    }
}

/// Pipeline config from the shared cache-stage flags: `--shard-rows`
/// (0 = auto-size from the budget), `--mem-budget`, `--resume`, and
/// `--dtype` (payload codec the shards are encoded with; f32 default).
fn cache_pipeline_config(args: &Args) -> Result<PipelineConfig> {
    Ok(PipelineConfig {
        shard_rows: args.get_usize("shard-rows", DEFAULT_SHARD_ROWS)?,
        mem_budget: args.get_bytes("mem-budget", DEFAULT_MEM_BUDGET)?,
        resume: args.get_bool("resume"),
        dtype: PayloadDtype::parse(args.get_or("dtype", "f32"))?,
        ..PipelineConfig::default()
    })
}

/// Open the store writer for a synthetic cache run: fresh, or — under
/// `--resume` — positioned after the checksum-validated shards a killed
/// earlier run committed.
fn open_writer(dir: &Path, meta: StoreMeta, resume: bool) -> Result<(StoreWriter, usize)> {
    if resume {
        let (w, committed) = StoreWriter::resume(dir, &meta)?;
        println!(
            "resuming: {committed} rows already committed at {}, continuing from row {committed}",
            dir.display()
        );
        Ok((w, committed))
    } else {
        Ok((StoreWriter::create_described(dir, meta)?, 0))
    }
}

fn cache_with_runtime(
    rt: &Runtime,
    model: &str,
    spec: &MethodSpec,
    n: usize,
    seed: u64,
    store: &str,
    args: &Args,
) -> Result<()> {
    // The density knob shapes the synthetic gradient source only; a
    // runtime model's gradients are whatever the model produces. Reject
    // rather than silently ignore.
    ensure!(
        args.get("density").is_none(),
        "--density applies only to the synthetic gradient source (--model {SYNTH_MODEL}); \
         model '{model}' computes real gradients"
    );
    let model_meta = rt.manifest.model(model)?.clone();
    let shapes = model_meta.shapes();
    let bank = spec.build_bank(&shapes, seed)?;

    // init params (untrained demo; `grass attribute` re-derives them from
    // the stored seed so the projections and gradients match).
    let init = rt.executable(&format!("{model}_init"))?;
    let params = init
        .run(&[Arg::ScalarI32(seed as i32)])?
        .remove(0)
        .data;

    let pipeline = CachePipeline::new(rt, model, params, cache_pipeline_config(args)?);
    let dir = Path::new(store);
    let meta = if bank.is_factored() {
        let seq = model_meta
            .seq
            .ok_or_else(|| anyhow!("model '{model}' has no sequence length for the hooks path"))?;
        let data = ThemedCorpus::generate(n, seq, seed);
        pipeline.run(
            &Source::Sequences(&data),
            &bank,
            dir,
            &spec.spec_string(),
            seed,
        )?
    } else {
        let data = SynthDigits::generate(n, seed);
        pipeline.run(
            &Source::Labelled(&data),
            &bank,
            dir,
            &spec.spec_string(),
            seed,
        )?
    };
    println!("cached {} rows of k={} into {store}", meta.n, meta.k);
    println!("{}", pipeline.metrics.report());
    Ok(())
}

/// Runtime-free cache: compress the deterministic synthetic gradient
/// source through the spec's bank and persist a fully described store.
/// `--density D` (flat specs) draws genuinely sparse class-template
/// gradients and routes them through the CSR kernels end to end.
fn cache_synthetic(
    spec: &MethodSpec,
    n: usize,
    seed: u64,
    store: &str,
    args: &Args,
) -> Result<()> {
    let dir = Path::new(store);
    let cfg = cache_pipeline_config(args)?;
    let density = args.get_f64("density", 1.0)?;
    ensure!(
        density > 0.0 && density <= 1.0,
        "--density must be in (0, 1], got {density}"
    );
    ensure!(
        !(spec.is_factorized() && density < 1.0),
        "--density applies to the flat synthetic gradient source; \
         factorized specs cache dense synthetic hooks"
    );
    let resume = args.get_bool("resume");
    // Crash-testing aid: sleep this long after each pushed chunk so an
    // external SIGKILL can land mid-run deterministically.
    let throttle = args.get_u64("throttle-ms", 0)?;
    let nap = |ms: u64| {
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    };
    let mut scratch = Scratch::new();
    let meta = if spec.is_factorized() {
        let layers = default_synth_layers();
        let shapes = ModelShapes::factored(layers.clone());
        let bank = spec.build_bank(&shapes, seed)?;
        let cs = bank.as_factored().expect("factorized spec builds a factored bank");
        let k = bank.output_dim();
        let mut described =
            StoreMeta::describe(spec, seed, SYNTH_MODEL, &shapes, cfg.effective_shard_rows(k))?;
        described.dtype = cfg.dtype;
        let (mut w, committed) = open_writer(dir, described, resume)?;
        let hooks = SynthHooks::new(layers, seed);
        let mut row = vec![0.0f32; k];
        for i in committed..n {
            let sample = hooks.sample(i);
            let mut off = 0;
            for (li, c) in cs.iter().enumerate() {
                let (x, dy) = &sample[li];
                c.compress_batch_with(1, SYNTH_SEQ, x, dy, &mut row, k, off, &mut scratch);
                off += c.output_dim();
            }
            w.push(&row)?;
            nap(throttle);
        }
        w.finish()?
    } else {
        let p = args.get_usize("p", 4096)?;
        let shapes = ModelShapes::flat(p);
        let bank = spec.build_bank(&shapes, seed)?;
        let c = bank.as_flat().expect("flat spec builds a flat bank");
        let k = c.output_dim();
        let mut described =
            StoreMeta::describe(spec, seed, SYNTH_MODEL, &shapes, cfg.effective_shard_rows(k))?;
        described.density = density;
        described.dtype = cfg.dtype;
        let (mut w, committed) = open_writer(dir, described, resume)?;
        let src = SynthGrads::with_density(p, seed, density as f32);
        let chunk = 64usize;
        let mut out = vec![0.0f32; chunk * k];
        // The synthetic source is deterministic per row index, so
        // restarting at the committed-row watermark reproduces exactly the
        // rows an uninterrupted run would have written there.
        let mut start = committed;
        while start < n {
            let count = chunk.min(n - start);
            if density < 1.0 {
                // CSR end to end: the source emits index/value pairs and
                // the sparse kernels never touch a zero coordinate.
                let rows = src.rows_sparse(start, count);
                c.compress_sparse_batch_with(&rows, &mut out[..count * k], &mut scratch);
            } else {
                let rows = src.rows(start, count);
                c.compress_batch_with(&rows, count, &mut out[..count * k], &mut scratch);
            }
            w.push_batch(&out[..count * k])?;
            start += count;
            nap(throttle);
        }
        w.finish()?
    };
    println!(
        "cached {} rows of k={} into {store} (synthetic source, method {}, density {density})",
        meta.n,
        meta.k,
        spec.spec_string()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// fit
// ---------------------------------------------------------------------------

/// `grass fit`: stream the store's rows once, accumulate the per-block
/// FIMs, and persist them as `precond.bin` next to `store.json`. Later
/// `grass attribute` runs validate the artifact (method/seed/k/rows) and
/// build any preconditioner — any λ, any rank — from it without touching
/// the train rows again.
fn run_fit(args: &Args) -> Result<()> {
    let store = args.get_or("store", "grass_store").to_string();
    let reader = StoreReader::open(&store)?;
    let damping = args.get_f64("damping", PrecondSpec::DEFAULT_LAMBDA)?;
    let pspec = PrecondSpec::parse_with(args.get_or("precond", "damped"), damping)?;
    ensure!(
        pspec.needs_fim(),
        "the identity preconditioner has nothing to fit"
    );
    // Per-layer layout for the blockwise family needs the recorded
    // geometry; the monolithic families fit one [k] block.
    let layer_dims: Vec<usize> = if matches!(pspec, PrecondSpec::Blockwise { .. }) {
        let shapes = reader.meta.shapes();
        ensure!(
            shapes.p > 0 || !shapes.layers.is_empty(),
            "store at {store} records no gradient geometry (pre-redesign cache?); \
             re-run `grass cache`"
        );
        reader.meta.spec()?.build_bank(&shapes, reader.meta.seed)?.layer_dims()
    } else {
        vec![]
    };
    let layout = pspec.layout_for(reader.meta.k, &layer_dims);
    let opts = StreamOpts {
        mem_budget: args.get_bytes("mem-budget", DEFAULT_MEM_BUDGET)?,
        workers: args.get_usize("workers", 0)?,
        ..StreamOpts::default()
    };
    let (artifact, fit_dur) =
        grass::util::bench::time_once(|| PrecondArtifact::fit(&reader, &opts, &layout));
    let artifact = artifact?;
    let path = artifact.save(&store)?;
    // Prove the artifact actually builds the requested solver before
    // reporting success.
    let pre = pspec.build(&artifact.fims, &layout)?;
    println!(
        "fitted {} FIM block(s) over {} rows in {:.1} ms → {}",
        artifact.fims.len(),
        artifact.rows,
        fit_dur.as_secs_f64() * 1e3,
        path.display()
    );
    println!("precond: {}", pre.describe());
    Ok(())
}

// ---------------------------------------------------------------------------
// attribute
// ---------------------------------------------------------------------------

fn run_attribute(args: &Args) -> Result<i32> {
    let store = args.get_or("store", "grass_store").to_string();
    let m = args.get_usize("queries", 8)?;
    let scorer = args.get_or("scorer", "if").to_string();
    // `--damping` is a number, or the literal `grid` (select λ over the
    // paper's grid by LDS on held-out subsets).
    let grid_requested = args.get("damping") == Some("grid");
    let damping = if grid_requested {
        PrecondSpec::DEFAULT_LAMBDA
    } else {
        args.get_f64("damping", 1e-3)?
    };
    let top = args.get_usize("top", 5)?;

    let mut reader = StoreReader::open(&store)?;
    // Optional warm shard cache: the FIM, self-influence, and score
    // passes re-read the same shards, so a byte-budgeted LRU of decoded
    // shard bytes (with sequential prefetch) turns passes 2+ into memory
    // reads. Off by default — batch runs over huge stores should stream.
    let cache_bytes = args.get_bytes("shard-cache", 0)?;
    if cache_bytes > 0 {
        let cache = std::sync::Arc::new(grass::serve::ShardCache::new(cache_bytes));
        cache.spawn_prefetcher(std::path::PathBuf::from(&store));
        reader.attach_cache(cache);
    }
    let reader = reader;
    // Out-of-core streaming knobs: byte budget for the per-worker shard
    // buffers, worker count, optional GGDA-style row grouping, and the
    // fault-tolerance policy (retry transient read errors; optionally
    // quarantine corrupt shards and keep scoring the rest).
    let mut opts = StreamOpts {
        mem_budget: args.get_bytes("mem-budget", DEFAULT_MEM_BUDGET)?,
        workers: args.get_usize("workers", 0)?,
        groups: match args.get("row-groups") {
            Some(s) => Some(parse_row_groups(s, reader.meta.n)?),
            None => None,
        },
        retry: RetryPolicy {
            retries: args.get_usize("retries", 2)?,
            backoff: std::time::Duration::from_millis(args.get_u64("retry-backoff", 50)?),
            seed: reader.meta.seed,
        },
        skip_corrupt: args.get_bool("skip-corrupt"),
        ..StreamOpts::default()
    };
    let grouped = opts.groups.is_some();
    let spec = reader.meta.spec()?;
    let seed = reader.meta.seed;
    // A user-pinned --method/--seed is validated against the store: a
    // mismatch is a hard, descriptive error instead of silent mis-scoring.
    if args.get("method").is_some() || args.get("seed").is_some() {
        let requested = match args.get("method") {
            Some(ms) => MethodSpec::parse(ms)?,
            None => spec.clone(),
        };
        StoreReader::open_checked(&store, &requested, args.get_u64("seed", seed)?)?;
    }

    let shapes = reader.meta.shapes();
    ensure!(
        shapes.p > 0 || !shapes.layers.is_empty(),
        "store at {store} records no gradient geometry (pre-redesign cache?); re-run `grass cache`"
    );
    let bank = spec.build_bank(&shapes, seed)?;
    ensure!(
        bank.output_dim() == reader.meta.k,
        "rebuilt bank emits {} columns but the store has k = {}",
        bank.output_dim(),
        reader.meta.k
    );

    // Compressed query gradients, from the same substrate the cache used.
    let model = reader.meta.model.clone();
    let (queries, classes) = if model == SYNTH_MODEL || model.is_empty() {
        synth_queries(&reader.meta, &bank, m)?
    } else {
        runtime_queries(&reader.meta, &bank, m)?
    };

    // Preconditioner: explicit --precond, or the scorer's default family;
    // `--damping grid` replaces λ with the LDS-selected grid value.
    let base_pspec = match args.get("precond") {
        Some(s) => PrecondSpec::parse_with(s, damping)?,
        None => PrecondSpec::default_for_scorer(&scorer, damping),
    };

    // Fitted-solver artifact: `precond.bin` is loaded and validated
    // against the store (a mismatch is a hard, descriptive error) only
    // when this run can actually consume it — identity-preconditioned
    // scorers never touch it, and grouped runs refit on the selected
    // rows (the grid still wants the full-store FIMs either way).
    let wants_artifact = base_pspec.needs_fim() && (opts.groups.is_none() || grid_requested);
    let artifact = if args.get_bool("no-artifact") || !wants_artifact {
        None
    } else {
        match PrecondArtifact::load_if_present(&store)? {
            Some(a) => {
                a.validate_store(&reader.meta)?;
                Some(std::sync::Arc::new(a))
            }
            None => None,
        }
    };

    let (pspec, grid_artifact) = if grid_requested {
        select_damping_by_grid(
            &reader,
            &opts,
            &base_pspec,
            &bank.layer_dims(),
            &queries,
            m,
            &classes,
            artifact.as_ref(),
            args,
        )?
    } else {
        (base_pspec, None)
    };
    // Artifacts cover the whole store; grouped runs refit on the
    // selected rows, so they never consume one. A grid run's freshly
    // fitted FIMs double as the attribute-stage artifact, so the solver
    // build never re-streams what the grid just accumulated.
    if pspec.needs_fim() && opts.groups.is_none() {
        opts.artifact = grid_artifact.or(artifact);
    }

    // Scorer through the declarative registry.
    let mut aspec = AttributionSpec::new(&scorer, spec, seed);
    aspec.damping = damping;
    aspec.layout = bank.layer_dims();
    aspec.precond = Some(pspec);
    let mut attributor: Box<dyn Attributor> = from_spec(&aspec)?;
    let meta = attributor.cache_stream(&reader, &opts)?;
    let scores = attributor.attribute(&queries, m)?;

    if args.get_or("format", "text") == "json" {
        return attribute_json(args, &meta, attributor.as_ref(), &scores, &classes, m, top);
    }

    println!(
        "attributed {m} queries against {} cached rows (scorer '{}', method {}, k={}, \
         streamed under {} budget, {} score columns)",
        meta.n,
        attributor.name(),
        meta.method,
        meta.k,
        fmt_bytes(opts.mem_budget),
        scores.n,
    );
    let pstats = attributor.precond_stats();
    println!(
        "precond: {} | fim-pass rows: {}",
        pstats.describe, pstats.fim_rows
    );
    let mut hits = 0usize;
    let mut ranked = 0usize;
    let tag = if grouped { "group " } else { "#" };
    for q in 0..m {
        let best = scores.top_k(q, top);
        let parts: Vec<String> = best
            .iter()
            .map(|(i, s)| format!("{tag}{i} ({s:+.3})"))
            .collect();
        let label = classes
            .get(q)
            .map(|c| format!(" [class {c}]"))
            .unwrap_or_default();
        println!("  query {q}{label}: top-{top} {}", parts.join(", "));
        if let Some(&qc) = classes.get(q) {
            if !grouped {
                hits += best
                    .iter()
                    .filter(|(i, _)| i % SYNTH_CLASSES == qc)
                    .count();
                ranked += best.len();
            }
        }
    }
    if ranked > 0 && (model == SYNTH_MODEL || model.is_empty()) {
        println!(
            "top-{top} same-class fraction: {:.0}% (chance ≈ {:.0}%)",
            100.0 * hits as f64 / ranked as f64,
            100.0 / SYNTH_CLASSES as f64
        );
    }
    if args.get_bool("self-influence") {
        let si = attributor.self_influence()?;
        let mut order: Vec<usize> = (0..si.len()).collect();
        order.sort_by(|&a, &b| si[b].partial_cmp(&si[a]).unwrap_or(std::cmp::Ordering::Equal));
        let parts: Vec<String> = order
            .iter()
            .take(top)
            .map(|&i| format!("{tag}{i} ({:+.3})", si[i]))
            .collect();
        println!("top-{top} self-influence: {}", parts.join(", "));
    }
    // Degraded-mode accounting: a run that quarantined shards reports
    // exactly what it scored and exits with the distinct "completed
    // degraded" code so callers can tell partial from full results.
    if let Some(cov) = attributor.coverage() {
        if opts.skip_corrupt || cov.is_degraded() {
            println!("coverage: {}", cov.describe());
        }
        if cov.is_degraded() {
            println!("attribution completed degraded (exit code 3)");
            return Ok(3);
        }
    }
    Ok(0)
}

/// `--format json`: machine-readable attribute output — scores, top-k,
/// self-influence, precond stats, coverage — with the same exit semantics
/// as the text path (3 when degraded). The serve-vs-batch parity gate in
/// CI diffs this against `grass query` responses.
fn attribute_json(
    args: &Args,
    meta: &StoreMeta,
    attributor: &dyn Attributor,
    scores: &ScoreMatrix,
    classes: &[usize],
    m: usize,
    top: usize,
) -> Result<i32> {
    let pstats = attributor.precond_stats();
    let top_json = Json::Arr(
        (0..m)
            .map(|q| {
                Json::Arr(
                    scores
                        .top_k(q, top)
                        .into_iter()
                        .map(|(i, s)| {
                            Json::obj(vec![
                                ("index", Json::Num(i as f64)),
                                ("score", Json::Num(s as f64)),
                            ])
                        })
                        .collect(),
                )
            })
            .collect(),
    );
    let score_rows = Json::Arr((0..m).map(|q| Json::arr_f32(scores.row(q))).collect());
    let mut pairs = vec![
        ("scorer", Json::Str(attributor.name().to_string())),
        ("method", Json::Str(meta.method.clone())),
        ("k", Json::Num(meta.k as f64)),
        ("rows", Json::Num(meta.n as f64)),
        ("m", Json::Num(m as f64)),
        ("n", Json::Num(scores.n as f64)),
        (
            "precond",
            Json::obj(vec![
                ("describe", Json::Str(pstats.describe.clone())),
                ("fim_rows", Json::Num(pstats.fim_rows as f64)),
            ]),
        ),
        ("top", top_json),
        ("scores", score_rows),
    ];
    if !classes.is_empty() {
        pairs.push(("classes", Json::arr_usize(classes)));
    }
    if args.get_bool("self-influence") {
        pairs.push(("self_influence", Json::arr_f32(&attributor.self_influence()?)));
    }
    let mut exit = 0;
    if let Some(cov) = attributor.coverage() {
        let info = CoverageInfo {
            rows_total: cov.rows_total,
            rows_scored: cov.rows_scored,
            quarantined: cov.quarantined,
            retries_attempted: cov.retries_attempted,
        };
        if info.is_degraded() {
            exit = 3;
        }
        pairs.push(("coverage", info.to_json()));
    }
    pairs.push(("exit_code", Json::Num(exit as f64)));
    println!("{}", Json::obj(pairs).to_string_pretty());
    Ok(exit)
}

// ---------------------------------------------------------------------------
// verify
// ---------------------------------------------------------------------------

/// `grass verify`: full integrity scan of a store — every shard re-read
/// and compared (exact length + CRC32C) against `manifest.json`, plus
/// `precond.bin` when its checksum was recorded. Exit 0 when everything
/// matches, 2 when anything is missing, mis-sized, checksum-failed, or the
/// store has no manifest (`--upgrade` writes one in place over a healthy
/// legacy store).
fn run_verify(args: &Args) -> Result<i32> {
    let store = args.get_or("store", "grass_store").to_string();
    let mut reader = StoreReader::open(&store)?;
    if !reader.has_manifest() {
        if args.get_bool("upgrade") {
            let man = reader.write_manifest()?;
            println!(
                "upgraded: checksummed {} shard(s) into manifest.json at {store}",
                man.shards.len()
            );
        } else {
            println!(
                "store at {store} has no manifest.json — shard checksums cannot be verified; \
                 run `grass verify --store {store} --upgrade` to checksum it in place"
            );
            return Ok(2);
        }
    }
    let report = reader.verify_checksums()?;
    for (idx, status) in &report.shards {
        println!("shard {idx:04}: {status}");
    }
    if let Some(status) = report.precond {
        println!("precond.bin: {status}");
    }
    if report.all_ok() {
        println!(
            "verify: OK ({} shards, {} rows)",
            reader.num_shards(),
            reader.meta.n
        );
        Ok(0)
    } else {
        let bad = report.shards.iter().filter(|(_, s)| !s.is_ok()).count();
        println!(
            "verify: FAILED ({bad} of {} shards bad)",
            reader.num_shards()
        );
        Ok(2)
    }
}

// ---------------------------------------------------------------------------
// quantize
// ---------------------------------------------------------------------------

/// `grass quantize`: offline payload-codec converter. Streams the source
/// store's decoded f32 rows and re-encodes them under `--dtype` into a
/// fully described store — at `--out DIR`, or (default) in place via an
/// atomic staging-directory swap. Because the source rows decode to the
/// exact f32 values the writer would have seen, the output is
/// byte-identical to a cache run that used `--dtype` natively.
fn run_quantize(args: &Args) -> Result<()> {
    let store = args.get_or("store", "grass_store").to_string();
    let dtype = PayloadDtype::parse(args.get_or("dtype", "f16"))?;
    let in_place = args.get("out").is_none();
    let out_dir = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("{store}.quantize.tmp"));

    {
        let reader = StoreReader::open(&store)?;
        let src = reader.meta.dtype;
        ensure!(
            src.is_lossless(),
            "store at {store} already holds lossy '{src}' payloads; re-quantizing would \
             compound rounding error — re-run `grass cache --dtype {dtype}` from the source"
        );
        if dtype == src {
            println!("store at {store} already uses payload dtype {dtype}; nothing to do");
            return Ok(());
        }
        let (n, k) = (reader.meta.n, reader.meta.k);
        let meta = StoreMeta {
            dtype,
            n: 0,
            ..reader.meta.clone()
        };
        let _ = std::fs::remove_dir_all(&out_dir);
        let mut w = StoreWriter::create_described(Path::new(&out_dir), meta)?;
        let mut cur = reader.cursor_with(reader.meta.shard_rows.max(1), &[]);
        let mut buf = Vec::new();
        let mut written = 0usize;
        while let Some(b) = cur.next_block(&mut buf)? {
            ensure!(
                b.start == written,
                "cursor returned rows out of order (block at {} after {written} written)",
                b.start
            );
            w.push_batch(&buf[..b.rows * k])?;
            written += b.rows;
        }
        let out_meta = w.finish()?;
        ensure!(
            out_meta.n == n,
            "quantized store holds {} rows but the source holds {n}",
            out_meta.n
        );
        println!(
            "quantized {n} rows × k={k}: {src} → {dtype} \
             ({} → {} shard bytes/row)",
            src.row_bytes(k),
            dtype.row_bytes(k)
        );
    }

    if in_place {
        // Swap the staging directory over the source atomically enough
        // that a healthy store exists at `store` at every step: the source
        // is parked, the staging dir takes its name, then the park is
        // dropped. The open reader is gone by now (scope above).
        let old = format!("{store}.quantize.old");
        let _ = std::fs::remove_dir_all(&old);
        std::fs::rename(&store, &old)?;
        std::fs::rename(&out_dir, &store)?;
        std::fs::remove_dir_all(&old)?;
        println!("rewrote {store} in place");
    } else {
        println!("wrote {out_dir} (source {store} untouched)");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// serve / query
// ---------------------------------------------------------------------------

/// `grass serve`: long-running attribution daemon over one store. Hot
/// state (store handle + warm shard cache, compressor bank, precond
/// artifact, per-scorer ingest) is built once per epoch; requests are
/// scored by a supervised worker pool with admission control. Stop it
/// with SIGTERM/SIGINT or `grass query --addr ... --shutdown` (both
/// drain within `--drain-ms`); swap in a rewritten or appended store
/// without downtime via `grass query --addr ... --reload`.
fn run_serve(args: &Args) -> Result<()> {
    let scorers = match args.get("scorers") {
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        None => vec!["if".to_string(), "graddot".to_string()],
    };
    let cfg = serve::ServeConfig {
        store: std::path::PathBuf::from(args.get_or("store", "grass_store")),
        addr: args.get_or("addr", "127.0.0.1:4571").to_string(),
        scorers,
        workers: args.get_usize("workers", 2)?,
        max_in_flight: args.get_usize("max-queue", 32)?,
        deadline_ms: args.get_u64("deadline-ms", 10_000)?,
        mem_budget: args.get_bytes("mem-budget", DEFAULT_MEM_BUDGET)?,
        cache_bytes: args.get_bytes("shard-cache", 256 << 20)?,
        skip_corrupt: args.get_bool("skip-corrupt"),
        retries: args.get_usize("retries", 2)?,
        retry_backoff_ms: args.get_u64("retry-backoff", 50)?,
        verify: args.get_bool("verify"),
        use_artifact: !args.get_bool("no-artifact"),
        damping: args.get_f64("damping", 1e-3)?,
        precond: args.get("precond").map(String::from),
        drain_ms: args.get_u64("drain-ms", 5_000)?,
        idle_ms: args.get_u64("idle-ms", 30_000)?,
        breaker: args.get_usize("breaker", 3)?,
        quiet: args.get_bool("quiet"),
        // `..Default::default()` also covers the test-only fault-injection
        // field, which does not exist in release builds.
        ..Default::default()
    };
    serve::run(cfg)
}

/// `grass query`: one-shot client for the serving daemon. Sends a single
/// request (score by default; `--stats`/`--ping`/`--shutdown`/`--reload`
/// for the control plane), prints the reply, and maps typed
/// admission-shed replies (overloaded / deadline_exceeded) to exit
/// code 4. `--timeout-ms` bounds connect and reply reads; a timeout is a
/// plain error (exit 1) naming the daemon and the budget.
fn run_query(args: &Args) -> Result<i32> {
    let addr = args.get_or("addr", "127.0.0.1:4571").to_string();
    let id = args.get_u64("id", 1)?;
    let req = if args.get_bool("ping") {
        Request::Ping { id }
    } else if args.get_bool("stats") {
        Request::Stats { id }
    } else if args.get_bool("shutdown") {
        Request::Shutdown { id }
    } else if args.get_bool("reload") {
        Request::Reload {
            id,
            store: args.get("store").map(String::from),
        }
    } else {
        let m = args.get_usize("queries", 4)?;
        let send = args.get_or("send", "synth").to_string();
        let queries = match send.as_str() {
            "synth" => QueryPayload::Synth { m },
            "raw" | "compressed" => {
                // The client regenerates the deterministic query gradients
                // locally from the store's recorded geometry (the same
                // shared helper the server and `grass attribute` use), so
                // the daemon receives genuinely client-supplied payloads.
                let store = args.get("store").ok_or_else(|| {
                    anyhow!("--send {send} regenerates query gradients locally; pass --store DIR")
                })?;
                let reader = StoreReader::open(store)?;
                if send == "raw" {
                    let (rows, _) = synth_raw_queries(&reader.meta, m)?;
                    QueryPayload::Raw { m, rows }
                } else {
                    let bank = reader
                        .meta
                        .spec()?
                        .build_bank(&reader.meta.shapes(), reader.meta.seed)?;
                    let (rows, _) = synth_queries(&reader.meta, &bank, m)?;
                    QueryPayload::Compressed { m, rows }
                }
            }
            other => bail!("--send must be synth|raw|compressed, got '{other}'"),
        };
        let deadline_ms = match args.get("deadline-ms") {
            Some(_) => Some(args.get_u64("deadline-ms", 0)?),
            None => None,
        };
        Request::Score(ScoreRequest {
            id,
            scorer: args.get_or("scorer", "if").to_string(),
            top_k: args.get_usize("top", 5)?,
            include_scores: args.get_bool("include-scores"),
            self_influence: args.get_bool("self-influence"),
            deadline_ms,
            queries,
        })
    };

    let timeout_ms = args.get_u64("timeout-ms", 0)?;
    let stream = connect_daemon(&addr, timeout_ms)?;
    let mut writer = std::io::BufWriter::new(stream.try_clone()?);
    let mut reader = std::io::BufReader::new(stream);
    proto::write_frame(&mut writer, &req.to_line())?;
    let frame = proto::read_frame(&mut reader)
        .map_err(|e| {
            if timeout_ms > 0 {
                anyhow!("no reply from the daemon at {addr} within {timeout_ms} ms: {e:#}")
            } else {
                e
            }
        })?
        .ok_or_else(|| anyhow!("daemon at {addr} closed the connection without replying"))?;
    let resp = Response::from_json(&frame)?;

    if args.get_or("format", "text") == "json" {
        println!("{}", resp.to_json().to_string_pretty());
    } else {
        print_response_text(&resp);
    }
    Ok(match &resp {
        Response::Scores(r) => {
            if r.coverage.is_degraded() {
                3
            } else {
                0
            }
        }
        Response::Error { kind, .. } if kind.is_shed() => 4,
        Response::Error { .. } => 1,
        _ => 0,
    })
}

/// Connect to the daemon, optionally under a `--timeout-ms` budget. With
/// a budget, every resolved address is tried with `connect_timeout` and
/// the socket's read/write timeouts are set, so an unreachable or hung
/// daemon becomes a descriptive error instead of an indefinite hang.
/// `timeout_ms == 0` keeps the legacy blocking behavior.
fn connect_daemon(addr: &str, timeout_ms: u64) -> Result<std::net::TcpStream> {
    use std::net::{TcpStream, ToSocketAddrs};
    if timeout_ms == 0 {
        return TcpStream::connect(addr)
            .map_err(|e| anyhow!("connecting to the daemon at {addr}: {e}"));
    }
    let budget = std::time::Duration::from_millis(timeout_ms);
    let resolved: Vec<std::net::SocketAddr> = addr
        .to_socket_addrs()
        .map_err(|e| anyhow!("resolving daemon address {addr}: {e}"))?
        .collect();
    ensure!(!resolved.is_empty(), "daemon address {addr} resolved to nothing");
    let mut last_err = None;
    for sock in &resolved {
        match TcpStream::connect_timeout(sock, budget) {
            Ok(s) => {
                s.set_read_timeout(Some(budget))?;
                s.set_write_timeout(Some(budget))?;
                return Ok(s);
            }
            Err(e) => last_err = Some(e),
        }
    }
    let e = last_err.expect("resolved is non-empty, so at least one connect ran");
    bail!("connecting to the daemon at {addr} within {timeout_ms} ms: {e}")
}

/// Human-readable rendering of a daemon reply (the `--format json` path
/// prints the raw frame instead).
fn print_response_text(resp: &Response) {
    match resp {
        Response::Scores(r) => {
            println!(
                "scored {} queries against {} rows (scorer '{}', {:.1} ms server-side)",
                r.m, r.coverage.rows_total, r.scorer, r.elapsed_ms
            );
            for (q, best) in r.top.iter().enumerate() {
                let parts: Vec<String> = best
                    .iter()
                    .map(|(i, s)| format!("#{i} ({s:+.3})"))
                    .collect();
                let label = r
                    .classes
                    .as_ref()
                    .and_then(|c| c.get(q))
                    .map(|c| format!(" [class {c}]"))
                    .unwrap_or_default();
                println!("  query {q}{label}: top {}", parts.join(", "));
            }
            if let Some(si) = &r.self_influence {
                let mut order: Vec<usize> = (0..si.len()).collect();
                order.sort_by(|&a, &b| {
                    si[b].partial_cmp(&si[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                let parts: Vec<String> = order
                    .iter()
                    .take(r.top.first().map_or(5, |t| t.len().max(1)))
                    .map(|&i| format!("#{i} ({:+.3})", si[i]))
                    .collect();
                println!("top self-influence: {}", parts.join(", "));
            }
            if r.coverage.is_degraded() {
                println!(
                    "coverage: {}/{} rows scored | quarantined shards: {:?} (degraded, exit 3)",
                    r.coverage.rows_scored, r.coverage.rows_total, r.coverage.quarantined
                );
            }
        }
        Response::Stats { stats, .. } => println!("{}", stats.to_string_pretty()),
        Response::Pong { .. } => println!("pong"),
        Response::ShuttingDown { .. } => println!("daemon shutting down"),
        Response::Reloaded { epoch, store, .. } => {
            println!("daemon reloaded store {store} (epoch {epoch})");
        }
        Response::Error { kind, message, .. } => {
            println!("daemon replied {}: {message}", kind.as_str());
            if kind.is_shed() {
                println!("(admission shed — exit 4)");
            }
        }
    }
}

/// `--damping grid` (App. B.2): fit (or reuse) the FIMs once, score every
/// λ in the paper's grid by LDS on held-out subsets of the cached rows
/// against the synthetic class datamodel, print the grid as a run-report
/// table (saved to `--out` when given), and return the base spec at the
/// selected λ plus the FIM artifact the grid evaluated on (so the
/// attribute stage builds its solver from it instead of re-streaming).
#[allow(clippy::too_many_arguments)]
fn select_damping_by_grid(
    reader: &StoreReader,
    opts: &StreamOpts,
    base: &PrecondSpec,
    layer_dims: &[usize],
    queries: &[f32],
    m: usize,
    classes: &[usize],
    artifact: Option<&std::sync::Arc<PrecondArtifact>>,
    args: &Args,
) -> Result<(PrecondSpec, Option<std::sync::Arc<PrecondArtifact>>)> {
    ensure!(
        base.needs_fim(),
        "preconditioner '{}' has no damping to select; --damping grid needs a \
         FIM-preconditioned --precond",
        base.spec_string()
    );
    let model = reader.meta.model.as_str();
    ensure!(
        model == SYNTH_MODEL || model.is_empty(),
        "--damping grid scores the grid by LDS against the synthetic class datamodel; \
         store model '{model}' records no retraining ground truth"
    );
    let k = reader.meta.k;
    let layout = base.layout_for(k, layer_dims);
    // FIMs: reuse the validated artifact when its layout matches,
    // otherwise one streaming fit (not persisted to disk — `grass fit`
    // does that — but handed back so the attribute stage reuses it).
    let fitted: std::sync::Arc<PrecondArtifact> = match artifact {
        Some(a) if a.layout == layout.dims => a.clone(),
        _ => {
            let clean = StreamOpts {
                groups: None,
                artifact: None,
                ..opts.clone()
            };
            std::sync::Arc::new(PrecondArtifact::fit(reader, &clean, &layout)?)
        }
    };
    let fims = &fitted.fims;
    // Held-out rows: the first min(n, 256) cached rows, read in-core so
    // each grid λ scores query-side at O(m·k²) without re-streaming.
    let n_val = reader.meta.n.min(256);
    ensure!(n_val > 0, "store has no rows to hold out for the grid");
    let mut val = vec![0.0f32; n_val * k];
    let mut cur = reader.cursor_with(reader.meta.shard_rows.max(1), &[0..n_val]);
    let mut buf = Vec::new();
    while let Some(b) = cur.next_block(&mut buf)? {
        val[b.start * k..(b.start + b.rows) * k].copy_from_slice(&buf[..b.rows * k]);
    }
    let s_count = args.get_usize("grid-subsets", 24)?;
    let subsets = grass::eval::subsets::sample_subsets(n_val, s_count, 0.5, reader.meta.seed);
    let losses = select::class_proxy_losses(&subsets, SYNTH_CLASSES, classes, reader.meta.seed);
    let report = select::grid_by_lds(
        base, fims, &layout, &val, n_val, queries, m, &subsets, &losses,
    )?;
    let mut table = exp::report::Table::new(
        &format!("damping grid (LDS on {s_count} held-out subsets of {n_val} rows)"),
        &["lambda", "lds"],
    );
    for e in &report.entries {
        table.row(vec![
            format!("{:.0e}", e.lambda),
            e.lds
                .map(|v| format!("{v:.4}"))
                .unwrap_or_else(|| "not PD".into()),
        ]);
    }
    table.print();
    if let Some(path) = args.get("out") {
        table.save(path)?;
    }
    println!(
        "selected λ = {:.0e} (LDS {:.4})",
        report.best_lambda, report.best_lds
    );
    Ok((base.with_lambda(report.best_lambda), Some(fitted)))
}

/// Human-readable binary byte size (inverse of `util::cli::parse_bytes`).
fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.1}G", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.0}M", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.0}K", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

/// Parse `--row-groups`: an explicit half-open range list
/// (`"0..512,512..1024"`) or uniform blocks (`"block=256"`) over the
/// store's `n` rows.
fn parse_row_groups(s: &str, n: usize) -> Result<RowGroups> {
    if let Some(size) = s.strip_prefix("block=").or_else(|| s.strip_prefix("blocks=")) {
        let block: usize = size
            .trim()
            .parse()
            .map_err(|e| anyhow!("--row-groups block size '{size}': {e}"))?;
        ensure!(block > 0, "--row-groups block size must be positive");
        ensure!(n > 0, "store has no rows to group");
        return Ok(RowGroups::blocks(n, block));
    }
    let groups = RowGroups::parse(s)?;
    groups.validate(n)?;
    Ok(groups)
}

/// Compute + compress `m` real query gradients through the PJRT runtime,
/// re-deriving the cached model's parameters from the stored seed.
fn runtime_queries(
    meta: &StoreMeta,
    bank: &CompressorBank,
    m: usize,
) -> Result<(Vec<f32>, Vec<usize>)> {
    let rt = Runtime::load(Runtime::artifacts_dir()).map_err(|e| {
        anyhow!(
            "store was cached from model '{}' but the PJRT runtime is unavailable: {e:#}",
            meta.model
        )
    })?;
    let model = meta.model.as_str();
    let model_meta = rt.manifest.model(model)?.clone();
    let init = rt.executable(&format!("{model}_init"))?;
    let params = init
        .run(&[Arg::ScalarI32(meta.seed as i32)])?
        .remove(0)
        .data;
    let k = bank.output_dim();
    let query_seed = meta.seed ^ 0x7E57;
    if let Some(cs) = bank.as_factored() {
        let seq = model_meta
            .seq
            .ok_or_else(|| anyhow!("model '{model}' has no sequence length"))?;
        let data = ThemedCorpus::generate(m, seq, query_seed);
        let idx: Vec<usize> = (0..m).collect();
        let hooks = exp::table1::collect_hooks(&rt, model, &params, &data, &idx)?;
        let (out, _) = exp::table1::compress_hooks(&hooks, cs);
        let classes = data.tags.iter().map(|&t| t as usize).collect();
        Ok((out, classes))
    } else {
        let trainer = grass::eval::retrain::Trainer::new(&rt, model)?;
        let data = SynthDigits::generate(m, query_seed);
        let idx: Vec<usize> = (0..m).collect();
        let grads = trainer.grads(
            &params,
            &grass::eval::retrain::TaskData::Labelled(&data),
            &idx,
        )?;
        let c = bank.as_flat().expect("flat bank");
        let mut out = vec![0.0f32; m * k];
        c.compress_batch(&grads, m, &mut out);
        let classes = data.y.iter().map(|&y| y as usize).collect();
        Ok((out, classes))
    }
}
