//! `grass` — the leader CLI.
//!
//! ```text
//! grass exp fig4 [--ks 512,...] [--out results.json]
//! grass exp table1a|table1b|table1c|table1d [--fast] [--ks ...] [...]
//! grass exp table2 [--ks 256,1024,4096] [--tokens 256] [--reps 8]
//! grass exp fig9 [--kl 256]
//! grass cache --model mlp --method sjlt:k=1024 --n 1000 --store DIR
//! grass info
//! ```

use anyhow::{bail, Result};
use grass::config::ExpConfig;
use grass::coordinator::{CachePipeline, CompressorBank, PipelineConfig};
use grass::data::images::SynthDigits;
use grass::exp;
use grass::runtime::Runtime;
use grass::sketch::MethodSpec;
use grass::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("exp") => run_exp(&args),
        Some("cache") => run_cache(&args),
        Some("info") => run_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "grass — Scalable Data Attribution with Gradient Sparsification and Sparse Projection

USAGE:
  grass exp <fig4|table1a|table1b|table1c|table1d|table2|fig9|ablation|all> [flags]
  grass cache --model <mlp|resnet_lite|gpt2_tiny|music> --method <spec> [--n N] [--store DIR]
  grass info

COMMON FLAGS:
  --ks 512,1024,2048    compression dimensions
  --n-train / --n-test / --subsets / --checkpoints / --epochs / --lr / --seed
  --fast                shrink everything for a smoke run
  --out results.json    append table to a JSON report

METHOD SPECS: rm:k=.. | sm:k=.. | sjlt:k=..,s=1 | gauss:k=.. | fjlt:k=.. |
              grass:k=..,kp=..,mask=rm|sm"
    );
}

fn run_info() -> Result<()> {
    let rt = Runtime::load(Runtime::artifacts_dir())?;
    println!("platform: {}", rt.platform());
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for (name, spec) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            spec.inputs.len(),
            spec.outputs.len(),
            spec.file
        );
    }
    println!("models:");
    for (name, meta) in &rt.manifest.models {
        println!(
            "  {name}: P = {}, {} hooked layers",
            meta.p,
            meta.layers.len()
        );
    }
    Ok(())
}

fn run_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out = args.get("out");

    // Pure-CPU experiments that need no PJRT artifacts:
    if which == "fig4" {
        let ks = args.get_usize_list("ks", &[512, 2048, 8192])?;
        let budget = args.get_u64("budget-ms", 300)?;
        let t = exp::fig4::run(&ks, budget, out)?;
        t.print();
        return Ok(());
    }
    if which == "ablation" {
        let p = args.get_usize("p", 131_072)?;
        let k = args.get_usize("k", 2048)?;
        exp::ablation::run_grass_kprime(p, k, out)?.print();
        exp::ablation::run_factgrass_blowup(out)?.print();
        return Ok(());
    }
    if which == "table2" {
        let ks = args.get_usize_list("ks", &[256, 1024, 4096])?;
        let tokens = args.get_usize("tokens", 256)?;
        let reps = args.get_usize("reps", 4)?;
        let t = exp::table2::run(&ks, tokens, reps, out)?;
        t.print();
        return Ok(());
    }

    let rt = Runtime::load(Runtime::artifacts_dir())?;
    let mut cfg = ExpConfig::default();
    if let Some(path) = args.get("config") {
        cfg = ExpConfig::from_file(path)?;
    }
    cfg.apply_args(args)?;

    let save = |t: &exp::report::Table| -> Result<()> {
        t.print();
        if let Some(path) = out {
            t.save(path)?;
        }
        Ok(())
    };

    match which {
        "table1a" => save(&exp::table1::run_table1a(&rt, &cfg)?)?,
        "table1b" => save(&exp::table1::run_table1b(&rt, &cfg)?)?,
        "table1c" => save(&exp::table1::run_table1c(&rt, &cfg)?)?,
        "table1d" => {
            let mut c = cfg.clone();
            if args.get("ks").is_none() {
                c.ks = vec![16, 64, 256]; // per-layer k_l (perfect squares)
            }
            save(&exp::table1::run_table1d(&rt, &c)?)?;
        }
        "fig9" => {
            let kl = args.get_usize("kl", 256)?;
            let outcome = exp::fig9::run(&rt, &cfg, kl)?;
            outcome.table.print();
            println!(
                "top-10 same-theme fraction: {:.0}% (query theme: {})",
                outcome.top10_theme_hit * 100.0,
                outcome.query_theme
            );
        }
        "all" => {
            save(&exp::table1::run_table1a(&rt, &cfg)?)?;
            save(&exp::table1::run_table1b(&rt, &cfg)?)?;
            save(&exp::table1::run_table1c(&rt, &cfg)?)?;
            let mut c = cfg.clone();
            c.ks = vec![16, 64, 256];
            save(&exp::table1::run_table1d(&rt, &c)?)?;
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn run_cache(args: &Args) -> Result<()> {
    let rt = Runtime::load(Runtime::artifacts_dir())?;
    let model = args.get_or("model", "mlp").to_string();
    let spec = MethodSpec::parse(args.get_or("method", "sjlt:k=1024"))?;
    let n = args.get_usize("n", 1000)?;
    let seed = args.get_u64("seed", 42)?;
    let store = args.get_or("store", "grass_store").to_string();
    let p = rt.manifest.model(&model)?.p;

    // init params (untrained demo; pass --params to load a trained vector)
    let init = rt.executable(&format!("{model}_init"))?;
    let params = init
        .run(&[grass::runtime::Arg::ScalarI32(seed as i32)])?
        .remove(0)
        .data;

    let pipeline = CachePipeline::new(&rt, &model, params, PipelineConfig::default());
    let bank = CompressorBank::Flat(spec.build(p, seed));
    let data = SynthDigits::generate(n, seed);
    let meta = pipeline.run_flat(
        &grass::coordinator::pipeline::Source::Labelled(&data),
        &bank,
        std::path::Path::new(&store),
        &spec.spec_string(),
        seed,
    )?;
    println!("cached {} rows of k={} into {store}", meta.n, meta.k);
    println!("{}", pipeline.metrics.report());
    Ok(())
}
