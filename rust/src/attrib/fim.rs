//! Compressed FIM construction and inversion (the iFVP of §2.1, after
//! random projection: inversion cost drops from O(p²) to O(k²) per vector).

use crate::linalg::CholeskyFactor;
use crate::util::par;
use anyhow::Result;

/// `F̂ = Gᵀ G / n` over an `n × k` row-major compressed gradient matrix.
/// Parallelised over output rows; f64 accumulation.
pub fn accumulate_fim(grads: &[f32], n: usize, k: usize) -> Vec<f32> {
    assert_eq!(grads.len(), n * k);
    let mut fim = vec![0.0f32; k * k];
    par::par_chunks_mut(&mut fim, k, 1, |row_start, chunk| {
        for (off, frow) in chunk.chunks_mut(k).enumerate() {
            let a = row_start + off;
            // accumulate F[a][b] = Σ_i g[i][a] g[i][b] / n
            let mut acc = vec![0.0f64; k];
            for i in 0..n {
                let gi = &grads[i * k..(i + 1) * k];
                let ga = gi[a] as f64;
                if ga == 0.0 {
                    continue;
                }
                for (b, &gb) in gi.iter().enumerate() {
                    acc[b] += ga * gb as f64;
                }
            }
            for (b, v) in frow.iter_mut().enumerate() {
                *v = (acc[b] / n as f64) as f32;
            }
        }
    });
    fim
}

/// Incremental FIM accumulator for streaming caches (shard-by-shard).
pub struct FimAccumulator {
    k: usize,
    n: usize,
    sum: Vec<f64>,
}

impl FimAccumulator {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            n: 0,
            sum: vec![0.0; k * k],
        }
    }

    pub fn add_row(&mut self, g: &[f32]) {
        debug_assert_eq!(g.len(), self.k);
        for a in 0..self.k {
            let ga = g[a] as f64;
            if ga == 0.0 {
                continue;
            }
            let row = &mut self.sum[a * self.k..(a + 1) * self.k];
            for (b, &gb) in g.iter().enumerate() {
                row[b] += ga * gb as f64;
            }
        }
        self.n += 1;
    }

    pub fn add_batch(&mut self, rows: &[f32]) {
        for r in rows.chunks(self.k) {
            self.add_row(r);
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Fold another accumulator (e.g. a per-worker partial from the
    /// shard-parallel streaming ingest) into this one.
    pub fn merge(&mut self, other: FimAccumulator) {
        assert_eq!(self.k, other.k, "merging FIM accumulators of different k");
        for (a, b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        self.n += other.n;
    }

    pub fn finish(&self) -> Vec<f32> {
        let n = self.n.max(1) as f64;
        self.sum.iter().map(|&v| (v / n) as f32).collect()
    }
}

/// Damped inverse-FIM applicator: `g ↦ (F̂ + λI)⁻¹ g`.
pub struct Preconditioner {
    factor: CholeskyFactor,
}

impl Preconditioner {
    pub fn new(fim: &[f32], k: usize, damping: f64) -> Result<Self> {
        Ok(Self {
            factor: CholeskyFactor::factor_damped(fim, k, damping)?,
        })
    }

    pub fn dim(&self) -> usize {
        self.factor.dim()
    }

    pub fn apply(&self, g: &[f32]) -> Vec<f32> {
        self.factor.solve_f32(g)
    }

    /// Precondition every row of an `n × k` matrix in parallel, in place.
    pub fn apply_all(&self, grads: &mut [f32], n: usize) {
        let k = self.dim();
        assert_eq!(grads.len(), n * k);
        par::par_chunks_mut(grads, k, 8, |_, chunk| {
            for row in chunk.chunks_mut(k) {
                let solved = self.factor.solve_f32(row);
                row.copy_from_slice(&solved);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn fim_matches_naive() {
        let (n, k) = (17, 8);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let fim = accumulate_fim(&g, n, k);
        for a in 0..k {
            for b in 0..k {
                let mut want = 0.0f64;
                for i in 0..n {
                    want += g[i * k + a] as f64 * g[i * k + b] as f64;
                }
                want /= n as f64;
                assert!((fim[a * k + b] as f64 - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn streaming_accumulator_matches_batch() {
        let (n, k) = (23, 6);
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let batch = accumulate_fim(&g, n, k);
        let mut acc = FimAccumulator::new(k);
        acc.add_batch(&g);
        assert_eq!(acc.count(), n);
        let streamed = acc.finish();
        for i in 0..k * k {
            assert!((batch[i] - streamed[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn merged_partial_accumulators_match_single() {
        let (n, k) = (19, 5);
        let mut rng = Pcg::new(7);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let mut whole = FimAccumulator::new(k);
        whole.add_batch(&g);
        let mut a = FimAccumulator::new(k);
        let mut b = FimAccumulator::new(k);
        a.add_batch(&g[..7 * k]);
        b.add_batch(&g[7 * k..]);
        a.merge(b);
        assert_eq!(a.count(), n);
        let (fa, fw) = (a.finish(), whole.finish());
        for i in 0..k * k {
            assert!((fa[i] - fw[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn fim_is_symmetric_psd() {
        let (n, k) = (40, 10);
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let fim = accumulate_fim(&g, n, k);
        for a in 0..k {
            for b in 0..k {
                assert!((fim[a * k + b] - fim[b * k + a]).abs() < 1e-4);
            }
        }
        // PSD: factorable with tiny damping
        assert!(Preconditioner::new(&fim, k, 1e-6).is_ok());
    }

    #[test]
    fn precondition_identity_fim_is_scaling() {
        let k = 5;
        let mut fim = vec![0.0f32; k * k];
        for i in 0..k {
            fim[i * k + i] = 1.0;
        }
        let pre = Preconditioner::new(&fim, k, 1.0).unwrap(); // (I + I)⁻¹ = I/2
        let g = vec![2.0f32; k];
        let out = pre.apply(&g);
        for v in out {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn apply_all_matches_apply() {
        let (n, k) = (12, 7);
        let mut rng = Pcg::new(4);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let fim = accumulate_fim(&g, n, k);
        let pre = Preconditioner::new(&fim, k, 0.1).unwrap();
        let mut all = g.clone();
        pre.apply_all(&mut all, n);
        for i in 0..n {
            let one = pre.apply(&g[i * k..(i + 1) * k]);
            for j in 0..k {
                assert!((all[i * k + j] - one[j]).abs() < 1e-5);
            }
        }
    }
}
