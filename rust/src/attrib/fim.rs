//! Compressed FIM construction (the iFVP input of §2.1, after random
//! projection: inversion cost drops from O(p²) to O(k²) per vector).
//! Fitting and applying the inverse lives in [`super::precond`]; this
//! module owns the accumulation: batch ([`accumulate_fim`]) and streaming
//! ([`FimAccumulator`]), each with a sparse fast path that accumulates in
//! O(nnz²) per row instead of densifying first.

use crate::sketch::sparse::SparseRows;
use crate::util::par;

/// `F̂ = Gᵀ G / n` over an `n × k` row-major compressed gradient matrix.
/// Parallelised over output rows; f64 accumulation. Each worker owns one
/// reusable accumulator row — no per-output-row allocation (the PR 1
/// allocation-free convention).
pub fn accumulate_fim(grads: &[f32], n: usize, k: usize) -> Vec<f32> {
    assert_eq!(grads.len(), n * k);
    let mut fim = vec![0.0f32; k * k];
    par::par_chunks_mut(&mut fim, k, 1, |row_start, chunk| {
        // Per-worker scratch, reused across every output row this worker
        // computes (hoisted out of the row loop).
        let mut acc = vec![0.0f64; k];
        for (off, frow) in chunk.chunks_mut(k).enumerate() {
            let a = row_start + off;
            // accumulate F[a][b] = Σ_i g[i][a] g[i][b] / n
            acc.fill(0.0);
            for i in 0..n {
                let gi = &grads[i * k..(i + 1) * k];
                let ga = gi[a] as f64;
                if ga == 0.0 {
                    continue;
                }
                for (b, &gb) in gi.iter().enumerate() {
                    acc[b] += ga * gb as f64;
                }
            }
            for (b, v) in frow.iter_mut().enumerate() {
                *v = (acc[b] / n as f64) as f32;
            }
        }
    });
    fim
}

/// Incremental FIM accumulator for streaming caches (shard-by-shard).
pub struct FimAccumulator {
    k: usize,
    n: usize,
    sum: Vec<f64>,
}

impl FimAccumulator {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            n: 0,
            sum: vec![0.0; k * k],
        }
    }

    pub fn add_row(&mut self, g: &[f32]) {
        debug_assert_eq!(g.len(), self.k);
        for a in 0..self.k {
            let ga = g[a] as f64;
            if ga == 0.0 {
                continue;
            }
            let row = &mut self.sum[a * self.k..(a + 1) * self.k];
            for (b, &gb) in g.iter().enumerate() {
                row[b] += ga * gb as f64;
            }
        }
        self.n += 1;
    }

    /// Sparse fast path: fold one row given as sorted (index, value)
    /// pairs — the outer product touches only the nnz × nnz non-zero
    /// cells, O(nnz²) instead of the dense path's O(k²). This is how
    /// CSR-carried batches accumulate without densifying first.
    pub fn add_row_sparse(&mut self, idx: &[u32], vals: &[f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        let k = self.k;
        for (a, &ia) in idx.iter().enumerate() {
            let va = vals[a] as f64;
            if va == 0.0 {
                continue;
            }
            debug_assert!((ia as usize) < k);
            let row = &mut self.sum[ia as usize * k..(ia as usize + 1) * k];
            for (&ib, &vb) in idx.iter().zip(vals) {
                row[ib as usize] += va * vb as f64;
            }
        }
        self.n += 1;
    }

    pub fn add_batch(&mut self, rows: &[f32]) {
        for r in rows.chunks(self.k) {
            self.add_row(r);
        }
    }

    /// Fold a CSR batch through the sparse fast path — O(Σ nnz_i²) total.
    pub fn add_batch_sparse(&mut self, rows: &SparseRows) {
        assert_eq!(
            rows.dim(),
            self.k,
            "CSR batch dim does not match the accumulator's k"
        );
        for i in 0..rows.n() {
            let (idx, vals) = rows.row(i);
            self.add_row_sparse(idx, vals);
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Fold another accumulator (e.g. a per-worker partial from the
    /// shard-parallel streaming ingest) into this one.
    pub fn merge(&mut self, other: FimAccumulator) {
        assert_eq!(self.k, other.k, "merging FIM accumulators of different k");
        for (a, b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        self.n += other.n;
    }

    pub fn finish(&self) -> Vec<f32> {
        let n = self.n.max(1) as f64;
        self.sum.iter().map(|&v| (v / n) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn fim_matches_naive() {
        let (n, k) = (17, 8);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let fim = accumulate_fim(&g, n, k);
        for a in 0..k {
            for b in 0..k {
                let mut want = 0.0f64;
                for i in 0..n {
                    want += g[i * k + a] as f64 * g[i * k + b] as f64;
                }
                want /= n as f64;
                assert!((fim[a * k + b] as f64 - want).abs() < 1e-4);
            }
        }
    }

    /// No-regression check for the hoisted per-worker scratch: a matrix
    /// with planted zeros (exercising the `ga == 0` skip between rows
    /// that now share one accumulator) still matches the naive product,
    /// including when one worker computes many consecutive output rows.
    #[test]
    fn fim_scratch_reuse_across_rows_matches_naive() {
        let (n, k) = (29, 24); // k ≫ thread count: every worker gets several rows
        let mut rng = Pcg::new(12);
        let g: Vec<f32> = (0..n * k)
            .map(|_| {
                if rng.next_f32() < 0.5 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect();
        let fim = accumulate_fim(&g, n, k);
        for a in 0..k {
            for b in 0..k {
                let mut want = 0.0f64;
                for i in 0..n {
                    want += g[i * k + a] as f64 * g[i * k + b] as f64;
                }
                want /= n as f64;
                assert!(
                    (fim[a * k + b] as f64 - want).abs() < 1e-4,
                    "({a},{b}): {} vs {want}",
                    fim[a * k + b]
                );
            }
        }
    }

    #[test]
    fn streaming_accumulator_matches_batch() {
        let (n, k) = (23, 6);
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let batch = accumulate_fim(&g, n, k);
        let mut acc = FimAccumulator::new(k);
        acc.add_batch(&g);
        assert_eq!(acc.count(), n);
        let streamed = acc.finish();
        for i in 0..k * k {
            assert!((batch[i] - streamed[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn sparse_rows_accumulate_like_dense() {
        let (n, k) = (19, 12);
        let mut rng = Pcg::new(6);
        // ~10% dense rows with explicit index/value representation.
        let mut dense = vec![0.0f32; n * k];
        let mut acc_sparse = FimAccumulator::new(k);
        for i in 0..n {
            let mut idx = Vec::new();
            let mut vals = Vec::new();
            for j in 0..k {
                if rng.next_f32() < 0.15 {
                    let v = rng.next_gaussian();
                    dense[i * k + j] = v;
                    idx.push(j as u32);
                    vals.push(v);
                }
            }
            acc_sparse.add_row_sparse(&idx, &vals);
        }
        let mut acc_dense = FimAccumulator::new(k);
        acc_dense.add_batch(&dense);
        assert_eq!(acc_sparse.count(), n);
        let (fs, fd) = (acc_sparse.finish(), acc_dense.finish());
        for i in 0..k * k {
            assert!((fs[i] - fd[i]).abs() < 1e-6, "fim[{i}]: {} vs {}", fs[i], fd[i]);
        }
    }

    #[test]
    fn csr_batch_accumulates_like_dense() {
        use crate::sketch::sparse::SparseRows;
        let (n, k) = (15, 10);
        let mut rng = Pcg::new(8);
        let dense: Vec<f32> = (0..n * k)
            .map(|_| {
                if rng.next_f32() < 0.1 {
                    rng.next_gaussian()
                } else {
                    0.0
                }
            })
            .collect();
        let csr = SparseRows::from_dense_threshold(&dense, n, k, 0.0);
        let mut a = FimAccumulator::new(k);
        a.add_batch_sparse(&csr);
        let mut b = FimAccumulator::new(k);
        b.add_batch(&dense);
        assert_eq!(a.count(), n);
        let (fa, fb) = (a.finish(), b.finish());
        for i in 0..k * k {
            assert!((fa[i] - fb[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn merged_partial_accumulators_match_single() {
        let (n, k) = (19, 5);
        let mut rng = Pcg::new(7);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let mut whole = FimAccumulator::new(k);
        whole.add_batch(&g);
        let mut a = FimAccumulator::new(k);
        let mut b = FimAccumulator::new(k);
        a.add_batch(&g[..7 * k]);
        b.add_batch(&g[7 * k..]);
        a.merge(b);
        assert_eq!(a.count(), n);
        let (fa, fw) = (a.finish(), whole.finish());
        for i in 0..k * k {
            assert!((fa[i] - fw[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn fim_is_symmetric_psd() {
        let (n, k) = (40, 10);
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let fim = accumulate_fim(&g, n, k);
        for a in 0..k {
            for b in 0..k {
                assert!((fim[a * k + b] - fim[b * k + a]).abs() < 1e-4);
            }
        }
        // PSD: factorable with tiny damping
        assert!(crate::linalg::CholeskyFactor::factor_damped(&fim, k, 1e-6).is_ok());
    }
}
