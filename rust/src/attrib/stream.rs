//! Out-of-core streaming attribution — the shard-at-a-time ingest and
//! scoring passes behind [`Attributor::cache_stream`] — plus the shared
//! `DualCache` every scorer composes with a
//! [`Preconditioner`](super::precond::Preconditioner).
//!
//! The in-memory path materialises the full `n × k` compressed-gradient
//! matrix; at the ROADMAP's million-row scale that matrix is the largest
//! allocation in the process. The streaming path inverts the data-flow
//! contract — scorers become accumulators over shard streams instead of
//! consumers of a dense matrix:
//!
//! 1. **Ingest** ([`Attributor::cache_stream`]) — stream the selected row
//!    blocks, folding each into per-block FIM accumulators (when the
//!    engine's [`PrecondSpec`] needs one — skipped entirely when a
//!    persisted [`PrecondArtifact`] is supplied) and the eagerly computed
//!    self-influence diagonal. Only O(k²) solver state plus an O(n)
//!    diagonal stay resident.
//! 2. **Score** ([`Attributor::attribute`]) — re-stream the store:
//!    each worker preconditions its block in place
//!    ([`Preconditioner::apply_rows`](super::precond::Preconditioner::apply_rows))
//!    and scores it against the query matrix with the tiled GEMM, writing
//!    score columns incrementally. Workers never hold more than one block.
//!
//! [`StreamOpts::mem_budget`] bounds the resident streaming buffers:
//! `workers × chunk_rows × (k × 4 + row_bytes)` — each worker owns one
//! decoded `chunk_rows × k` f32 row buffer plus, per row, the encoded
//! payload bytes in flight (scratch for transformed copies and score
//! blocks reuses the same envelope). On an f32 store `row_bytes = 4k` and
//! the bound is the historical `workers × chunk_rows × k × 4 × 2`;
//! quantized payloads shrink `row_bytes` (2k for f16/bf16, 4+k for int8)
//! so the same `--mem-budget` streams proportionally larger blocks. The
//! query block (`m × k`) and the output score matrix (`m × out_cols`) sit
//! outside the budget — they are the caller's inputs and outputs, not
//! streaming state.
//!
//! Row-group selection ([`RowGroups`]) turns per-row score columns into
//! per-group columns (GGDA-style grouped attribution): every member row's
//! score is accumulated into its group's column, and the preconditioners
//! are fit on the selected rows only.
//!
//! [`Attributor::cache_stream`]: super::Attributor::cache_stream
//! [`Attributor::attribute`]: super::Attributor::attribute

use super::blockwise::BlockLayout;
use super::fim::FimAccumulator;
use super::precond::{apply_rows_parallel, PrecondArtifact, PrecondSpec, Preconditioner};
use crate::store::{PayloadDtype, ReadGuard, ReadLog, RetryPolicy, RowGroups, StoreReader};
use crate::util::par;
use anyhow::{anyhow, bail, ensure, Result};
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Default streaming buffer budget: 256 MiB.
pub const DEFAULT_MEM_BUDGET: usize = 256 << 20;

/// Tuning for the streamed cache/attribute passes.
#[derive(Debug, Clone)]
pub struct StreamOpts {
    /// Byte budget for the resident streaming buffers across all workers
    /// ([`StreamOpts::resident_bytes`] never exceeds it, down to the
    /// one-row-per-worker floor).
    pub mem_budget: usize,
    /// Streaming worker threads; 0 = available parallelism.
    pub workers: usize,
    /// Optional row-group selection: scores and self-influence aggregate
    /// into one column per group instead of one per train row.
    pub groups: Option<RowGroups>,
    /// Optional persisted solver artifact (`precond.bin`): when set and
    /// valid for the store, the FIM ingest pass is skipped entirely and
    /// the preconditioner is built from the artifact's fitted FIMs.
    pub artifact: Option<Arc<PrecondArtifact>>,
    /// Retry policy for shard reads: transient errors back off and retry;
    /// the default is fail-fast (no retries), matching the pre-retry
    /// behaviour exactly.
    pub retry: RetryPolicy,
    /// Degraded mode: quarantine corrupt shards and keep scoring the
    /// surviving rows (their score columns stay 0) instead of aborting.
    /// Inspect [`StreamedCache::coverage`] after a run to see what was
    /// lost.
    pub skip_corrupt: bool,
    /// Shared read log — quarantined shards and retry counts accumulate
    /// here across every pass (FIM fit, self-influence, score stream) so
    /// the final coverage report sees the union. Clones of these opts
    /// share the log through the `Arc`.
    pub log: Arc<ReadLog>,
    /// Circuit-breaker threshold armed on the shared log: a shard whose
    /// failed read attempts reach this count is force-quarantined so later
    /// passes degrade instantly instead of re-paying retry backoff. 0
    /// (default) leaves the breaker disarmed — the batch CLI behaviour.
    pub breaker: usize,
}

impl Default for StreamOpts {
    fn default() -> Self {
        Self {
            mem_budget: DEFAULT_MEM_BUDGET,
            workers: 0,
            groups: None,
            artifact: None,
            retry: RetryPolicy::none(),
            skip_corrupt: false,
            log: Arc::default(),
            breaker: 0,
        }
    }
}

impl StreamOpts {
    /// Default options under an explicit byte budget.
    pub fn with_budget(mem_budget: usize) -> Self {
        Self {
            mem_budget,
            ..Self::default()
        }
    }

    /// Arm the shared log's circuit breaker from these opts. Every
    /// streaming entry point calls this so a non-zero [`StreamOpts::breaker`]
    /// takes effect no matter which pass runs first; a zero threshold
    /// leaves whatever is already armed on the log untouched.
    pub(crate) fn arm_breaker(&self) {
        if self.breaker > 0 {
            self.log.set_breaker(self.breaker);
        }
    }

    /// Worker threads the streaming passes will actually use.
    pub fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            par::num_threads()
        } else {
            self.workers
        }
        .max(1)
    }

    /// Resident bytes one streamed row costs a worker under `dtype`: the
    /// decoded `k × 4` f32 buffer entry plus the row's encoded payload in
    /// flight. `4k + 4k = 8k` on f32 stores (the historical two-f32-buffer
    /// accounting), `6k` at 2 bytes/elem (f16/bf16), `5k + 4` for int8.
    fn per_row_bytes(k: usize, dtype: PayloadDtype) -> usize {
        4 * k.max(1) + dtype.row_bytes(k.max(1))
    }

    /// Rows per streamed block under the store's payload dtype: the
    /// largest count that keeps every worker's resident per-row bytes
    /// inside the budget (floored at one row). Quantized payloads cost
    /// fewer bytes per row, so the same budget streams larger blocks.
    pub fn chunk_rows_for(&self, k: usize, dtype: PayloadDtype) -> usize {
        let per_row = Self::per_row_bytes(k, dtype);
        (self.mem_budget / (self.effective_workers() * per_row)).max(1)
    }

    /// [`StreamOpts::chunk_rows_for`] on an f32 payload (the legacy
    /// accounting: two `chunk_rows × k` f32 buffers per worker).
    pub fn chunk_rows(&self, k: usize) -> usize {
        self.chunk_rows_for(k, PayloadDtype::F32)
    }

    /// The configured resident buffer allocation the budget bounds:
    /// `workers × chunk_rows × (k × 4 + row_bytes)`.
    pub fn resident_bytes_for(&self, k: usize, dtype: PayloadDtype) -> usize {
        self.effective_workers()
            * self.chunk_rows_for(k, dtype)
            * Self::per_row_bytes(k, dtype)
    }

    /// [`StreamOpts::resident_bytes_for`] on an f32 payload.
    pub fn resident_bytes(&self, k: usize) -> usize {
        self.resident_bytes_for(k, PayloadDtype::F32)
    }

    /// Selected row ranges (empty = the whole store).
    pub fn ranges(&self) -> Vec<Range<usize>> {
        self.groups
            .as_ref()
            .map(|g| g.ranges.clone())
            .unwrap_or_default()
    }

    /// Rows the selection covers in a store of `n` rows.
    pub fn selected_rows(&self, n: usize) -> usize {
        self.groups.as_ref().map(|g| g.total_rows()).unwrap_or(n)
    }

    /// Score columns the scorer emits: one per group, else one per row.
    pub fn out_cols(&self, n: usize) -> usize {
        self.groups.as_ref().map(|g| g.len()).unwrap_or(n)
    }
}

/// Row-wise `⟨raw_i, pre_i⟩` — the self-influence diagonal shared by every
/// engine's in-memory ingest (`pre == raw` for the identity family gives
/// the squared norms).
pub(crate) fn rowwise_dot(raw: &[f32], pre: &[f32], n: usize, k: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            raw[i * k..(i + 1) * k]
                .iter()
                .zip(&pre[i * k..(i + 1) * k])
                .map(|(a, b)| a * b)
                .sum()
        })
        .collect()
}

/// Precondition a row-major chunk in place; `None` is the identity (the
/// GradDot family scores raw rows).
pub(crate) fn precondition_chunk(
    buf: &mut [f32],
    rows: usize,
    k: usize,
    pre: Option<&dyn Preconditioner>,
) {
    if let Some(p) = pre {
        debug_assert_eq!(p.dim(), k);
        p.apply_rows(&mut buf[..rows * k], rows);
    }
}

/// Ingest pass of the preconditioned scorers: accumulate one
/// `k_l × k_l` FIM per layout block over the selected rows, shard-parallel
/// with per-worker [`FimAccumulator`]s merged at the end. Returns the
/// per-block FIMs plus the number of rows folded in.
///
/// Rows whose block slice is sparse enough
/// ([`crate::sketch::sparse::should_dispatch_sparse`]) take the
/// accumulator's O(nnz²) sparse fast path via a per-worker index/value
/// scratch — sparse caches (e.g. `grass cache --density`) fit their FIMs
/// in nnz-proportional time.
///
/// This owns its worker pool instead of going through
/// `StoreReader::par_for_each_block` because it needs long-lived
/// *per-worker* accumulator state: each `FimAccumulator` is `k² × 8`
/// bytes, so allocating/merging one per block (the closure-only
/// alternative) would thrash at large `k`, while one per worker amortises
/// to a single merge per worker at the end.
pub(crate) fn stream_block_fims(
    reader: &StoreReader,
    opts: &StreamOpts,
    layout: &BlockLayout,
) -> Result<(Vec<Vec<f32>>, usize)> {
    let k = reader.meta.k;
    ensure!(
        layout.total() == k,
        "stream layout totals {} but store rows have k = {k}",
        layout.total()
    );
    opts.arm_breaker();
    let ranges = opts.ranges();
    let blocks = reader.plan_blocks(opts.chunk_rows_for(k, reader.meta.dtype), &ranges);
    let max_rows = blocks.iter().map(|b| b.rows).max().unwrap_or(0);
    let workers = opts.effective_workers().min(blocks.len()).max(1);
    let next = AtomicUsize::new(0);
    let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let guard = ReadGuard {
        reader,
        retry: opts.retry.clone(),
        skip_corrupt: opts.skip_corrupt,
        log: &opts.log,
    };
    let parts: Vec<(Vec<FimAccumulator>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let error = &error;
                let blocks = &blocks;
                let guard = &guard;
                s.spawn(move || {
                    let mut accs: Vec<FimAccumulator> =
                        layout.dims.iter().map(|&d| FimAccumulator::new(d)).collect();
                    let mut buf = vec![0.0f32; max_rows * k];
                    let mut sidx: Vec<u32> = Vec::new();
                    let mut svals: Vec<f32> = Vec::new();
                    let mut seen = 0usize;
                    loop {
                        if error.lock().unwrap().is_some() {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= blocks.len() {
                            break;
                        }
                        let b = blocks[i];
                        match guard.read_block(b, &mut buf[..b.rows * k]) {
                            Ok(true) => {}
                            // Quarantined shard: the FIM simply sees fewer
                            // rows — surviving rows still fit a solver.
                            Ok(false) => continue,
                            Err(e) => {
                                let mut g = error.lock().unwrap();
                                if g.is_none() {
                                    *g = Some(e);
                                }
                                break;
                            }
                        }
                        for row in buf[..b.rows * k].chunks(k) {
                            for (l, acc) in accs.iter_mut().enumerate() {
                                let sl = layout.slice(row, l);
                                let (go_sparse, _, _) = crate::sketch::sparse::probe(sl);
                                if go_sparse {
                                    sidx.clear();
                                    svals.clear();
                                    for (j, &v) in sl.iter().enumerate() {
                                        if v != 0.0 {
                                            sidx.push(j as u32);
                                            svals.push(v);
                                        }
                                    }
                                    acc.add_row_sparse(&sidx, &svals);
                                } else {
                                    acc.add_row(sl);
                                }
                            }
                        }
                        seen += b.rows;
                    }
                    (accs, seen)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    let mut merged: Vec<FimAccumulator> =
        layout.dims.iter().map(|&d| FimAccumulator::new(d)).collect();
    let mut n_seen = 0usize;
    for (accs, seen) in parts {
        n_seen += seen;
        for (m, a) in merged.iter_mut().zip(accs) {
            m.merge(a);
        }
    }
    Ok((merged.iter().map(|a| a.finish()).collect(), n_seen))
}

/// The self-influence diagonal `τ(z_i, z_i) = ⟨g_i, g̃_i⟩` over the
/// selected rows, streamed: one entry per row, or per-group sums under
/// grouping. `pre = None` means `g̃ = g` (plain squared norms).
pub(crate) fn stream_self_influence(
    reader: &StoreReader,
    opts: &StreamOpts,
    pre: Option<&dyn Preconditioner>,
) -> Result<Vec<f32>> {
    let k = reader.meta.k;
    let out_len = opts.out_cols(reader.meta.n);
    // f64 for the same scheduling-stability reason as `stream_scores`;
    // per-row entries are written once, so that path stays lossless.
    let out = Mutex::new(vec![0.0f64; out_len]);
    opts.arm_breaker();
    let ranges = opts.ranges();
    reader.par_for_each_block_guarded(
        opts.chunk_rows_for(k, reader.meta.dtype),
        &ranges,
        opts.effective_workers(),
        &opts.retry,
        opts.skip_corrupt,
        &opts.log,
        |_, b, data, scratch| {
            if scratch.len() < data.len() {
                scratch.resize(data.len(), 0.0);
            }
            scratch[..data.len()].copy_from_slice(data);
            precondition_chunk(&mut scratch[..data.len()], b.rows, k, pre);
            let mut local = vec![0.0f32; b.rows];
            for (j, (raw, prow)) in data
                .chunks(k)
                .zip(scratch[..data.len()].chunks(k))
                .enumerate()
            {
                local[j] = raw.iter().zip(prow).map(|(a, p)| a * p).sum();
            }
            let gi = match &opts.groups {
                Some(groups) => Some(groups.group_of(b.start).ok_or_else(|| {
                    anyhow!("row {} falls outside every row group", b.start)
                })?),
                None => None,
            };
            let mut g = out.lock().unwrap();
            match gi {
                Some(gi) => g[gi] += local.iter().map(|&v| v as f64).sum::<f64>(),
                None => {
                    for (d, &v) in g[b.start..b.start + b.rows].iter_mut().zip(&local) {
                        *d = v as f64;
                    }
                }
            }
            Ok(())
        },
    )?;
    Ok(out
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect())
}

/// Score pass: stream the selected rows, precondition each worker-local
/// block in place, and score it against the `m × k` query matrix. Returns
/// row-major `m × out_cols` scores — per-row columns written incrementally
/// as blocks complete, or per-group accumulated columns under grouping.
pub(crate) fn stream_scores(
    reader: &StoreReader,
    opts: &StreamOpts,
    queries: &[f32],
    m: usize,
    pre: Option<&dyn Preconditioner>,
) -> Result<Vec<f32>> {
    let k = reader.meta.k;
    ensure!(
        queries.len() == m * k,
        "query block holds {} values, expected m = {m} × k = {k}",
        queries.len()
    );
    let out_cols = opts.out_cols(reader.meta.n);
    if m == 0 || out_cols == 0 {
        return Ok(vec![0.0f32; m * out_cols]);
    }
    // f64 accumulation: grouped columns sum many block partials whose
    // completion order varies across runs — f64 keeps the result stable to
    // f32 precision regardless of worker scheduling. Per-row columns are
    // written once (f32 → f64 → f32 is lossless), so the ungrouped path
    // stays bit-identical to the in-memory GEMM.
    let scores = Mutex::new(vec![0.0f64; m * out_cols]);
    opts.arm_breaker();
    let chunk_rows = opts.chunk_rows_for(k, reader.meta.dtype);
    // The GEMM scratch honours the same budget as the row buffer: score
    // the block in spans of at most ⌈chunk_rows·k / m⌉ rows, so worker
    // scratch never exceeds max(chunk_rows × k, m) floats.
    let span = (chunk_rows * k / m).max(1);
    let ranges = opts.ranges();
    reader.par_for_each_block_guarded(
        chunk_rows,
        &ranges,
        opts.effective_workers(),
        &opts.retry,
        opts.skip_corrupt,
        &opts.log,
        |_, b, data, scratch| {
            precondition_chunk(data, b.rows, k, pre);
            let gi = match &opts.groups {
                Some(groups) => Some(groups.group_of(b.start).ok_or_else(|| {
                    anyhow!("row {} falls outside every row group", b.start)
                })?),
                None => None,
            };
            let mut off = 0usize;
            while off < b.rows {
                let rows_here = (b.rows - off).min(span);
                let want = m * rows_here;
                if scratch.len() < want {
                    scratch.resize(want, 0.0);
                }
                crate::linalg::matmul::matmul_abt(
                    queries,
                    &data[off * k..(off + rows_here) * k],
                    &mut scratch[..want],
                    m,
                    k,
                    rows_here,
                );
                let mut g = scores.lock().unwrap();
                for q in 0..m {
                    let block_row = &scratch[q * rows_here..(q + 1) * rows_here];
                    match gi {
                        Some(gi) => {
                            g[q * out_cols + gi] +=
                                block_row.iter().map(|&v| v as f64).sum::<f64>();
                        }
                        None => {
                            let dst = q * out_cols + b.start + off;
                            for (d, &v) in g[dst..dst + rows_here].iter_mut().zip(block_row) {
                                *d = v as f64;
                            }
                        }
                    }
                }
                off += rows_here;
            }
            Ok(())
        },
    )?;
    Ok(scores
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v as f32)
        .collect())
}

/// How much of the train set a (possibly degraded) streaming run actually
/// scored. An undegraded run reports full coverage with no quarantined
/// shards; under `--skip-corrupt`, quarantined shards subtract their
/// selected rows from `rows_scored` and the run is
/// [`Coverage::is_degraded`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Coverage {
    /// Rows the run was asked to score (the row-group selection, or all
    /// store rows).
    pub rows_total: usize,
    /// Rows that actually contributed (total minus rows lost to
    /// quarantined shards).
    pub rows_scored: usize,
    /// Sorted indices of quarantined shards.
    pub quarantined: Vec<usize>,
    /// Shard-read retries attempted across every pass of the run.
    pub retries_attempted: u64,
}

impl Coverage {
    /// True when any selected row went unscored.
    pub fn is_degraded(&self) -> bool {
        self.rows_scored < self.rows_total || !self.quarantined.is_empty()
    }

    /// Fold another streaming pass's coverage in: row counts accumulate,
    /// quarantined shard sets union, retries accumulate. Used by
    /// multi-checkpoint scorers whose per-checkpoint caches each stream
    /// the store once.
    pub fn merge(&mut self, other: &Coverage) {
        self.rows_total += other.rows_total;
        self.rows_scored += other.rows_scored;
        for &s in &other.quarantined {
            if !self.quarantined.contains(&s) {
                self.quarantined.push(s);
            }
        }
        self.quarantined.sort_unstable();
        self.retries_attempted += other.retries_attempted;
    }

    /// One-line human summary, e.g.
    /// `"480/512 rows scored (93.8%) | quarantined shards: [2] | retries attempted: 0"`.
    pub fn describe(&self) -> String {
        let pct = if self.rows_total == 0 {
            100.0
        } else {
            100.0 * self.rows_scored as f64 / self.rows_total as f64
        };
        format!(
            "{}/{} rows scored ({pct:.1}%) | quarantined shards: {:?} | retries attempted: {}",
            self.rows_scored, self.rows_total, self.quarantined, self.retries_attempted
        )
    }
}

/// Length of the intersection of two half-open row ranges.
fn overlap(a: &Range<usize>, b: &Range<usize>) -> usize {
    a.end.min(b.end).saturating_sub(a.start.max(b.start))
}

/// Scoring state an engine retains after a streamed ingest: the store
/// handle (re-streamed at attribute time), the fitted preconditioner, and
/// the eagerly computed self-influence diagonal. At no point does more
/// than the budgeted buffer set of train rows sit in memory.
pub(crate) struct StreamedCache {
    /// Resident store handle: score passes reuse it (fault plans and any
    /// attached shard cache included) instead of re-opening the directory
    /// per pass — the hot state a long-lived serving daemon relies on.
    reader: StoreReader,
    opts: StreamOpts,
    k: usize,
    pre: Option<Box<dyn Preconditioner>>,
    self_inf: Vec<f32>,
    /// Rows the FIM ingest pass streamed (0 when a persisted artifact
    /// made the pass unnecessary, or the spec needs no FIM).
    fim_rows: usize,
    /// Store row count snapshot.
    n: usize,
    /// Shard row stride snapshot — maps quarantined shard indices back to
    /// row ranges for coverage accounting.
    shard_rows: usize,
    /// Score columns this cache produces (train rows, or groups).
    out_cols: usize,
}

impl StreamedCache {
    /// Stream-build the cache: a FIM pass per layout block when the spec
    /// needs one — skipped when [`StreamOpts::artifact`] supplies a
    /// validated, already-fitted artifact — then a self-influence pass.
    pub fn build(
        reader: &StoreReader,
        opts: &StreamOpts,
        layout: BlockLayout,
        spec: &PrecondSpec,
    ) -> Result<Self> {
        ensure!(
            layout.total() == reader.meta.k,
            "stream layout totals {} but store rows have k = {}",
            layout.total(),
            reader.meta.k
        );
        if let Some(g) = &opts.groups {
            g.validate(reader.meta.n)?;
        }
        let (pre, fim_rows) = if spec.needs_fim() {
            match &opts.artifact {
                Some(art) => {
                    ensure!(
                        opts.groups.is_none(),
                        "precond artifacts are fitted over the whole store; row-group \
                         selections refit on the selected rows — drop the artifact or the groups"
                    );
                    art.validate_store(&reader.meta)?;
                    art.validate_layout(&layout)?;
                    (Some(spec.build(&art.fims, &layout)?), 0)
                }
                None => {
                    let (fims, seen) = stream_block_fims(reader, opts, &layout)?;
                    (Some(spec.build(&fims, &layout)?), seen)
                }
            }
        } else {
            (None, 0)
        };
        let self_inf = stream_self_influence(reader, opts, pre.as_deref())?;
        Ok(Self {
            reader: reader.clone(),
            k: reader.meta.k,
            n: reader.meta.n,
            shard_rows: reader.meta.shard_rows,
            out_cols: opts.out_cols(reader.meta.n),
            opts: opts.clone(),
            pre,
            self_inf,
            fim_rows,
        })
    }

    /// Coverage of this cache's streaming passes so far: selected rows
    /// minus rows lost to quarantined shards, plus the retry count from
    /// the shared [`ReadLog`]. Call after a score pass — quarantines
    /// accumulate as passes touch bad shards.
    pub fn coverage(&self) -> Coverage {
        let rows_total = self.opts.selected_rows(self.n);
        let quarantined = self.opts.log.quarantined();
        let stride = self.shard_rows.max(1);
        let mut lost = 0usize;
        for &s in &quarantined {
            let shard_range = s * stride..((s + 1) * stride).min(self.n);
            lost += match &self.opts.groups {
                Some(g) => g
                    .ranges
                    .iter()
                    .map(|r| overlap(r, &shard_range))
                    .sum::<usize>(),
                None => shard_range.len(),
            };
        }
        Coverage {
            rows_total,
            rows_scored: rows_total.saturating_sub(lost),
            quarantined,
            retries_attempted: self.opts.log.retries_attempted(),
        }
    }

    /// Score columns (train rows, or groups under grouping).
    pub fn out_cols(&self) -> usize {
        self.out_cols
    }

    /// The cached self-influence diagonal.
    pub fn self_inf(&self) -> &[f32] {
        &self.self_inf
    }

    /// Rows the FIM ingest pass streamed (0 under artifact reuse).
    pub fn fim_rows(&self) -> usize {
        self.fim_rows
    }

    /// [`Preconditioner::describe`] of the fitted solver, if any.
    pub fn describe(&self) -> Option<String> {
        self.pre.as_ref().map(|p| p.describe())
    }

    /// Streamed attribute: re-stream the store and score `m` queries
    /// against it, one block of train rows per worker at a time. The
    /// resident reader is reused — no per-pass store re-open.
    pub fn scores(&self, queries: &[f32], m: usize) -> Result<Vec<f32>> {
        stream_scores(&self.reader, &self.opts, queries, m, self.pre.as_deref())
    }
}

/// The one dual-mode cache state every scorer composes with its
/// [`PrecondSpec`] — `preconditioner ∘ inner-product` behind two ingest
/// modes that produce identical scores:
///
/// - **Mem** — the preconditioned image of an in-memory train matrix plus
///   the eagerly computed self-influence diagonal (the raw gradients are
///   not retained: at store scale a second copy is the difference between
///   fitting in memory and not).
/// - **Streamed** — a [`StreamedCache`]: O(k²) solver state plus the O(n)
///   diagonal, rows re-streamed from the store at attribute time.
///
/// This replaces the five near-identical `enum … { Mem…, Streamed… }`
/// definitions the engines used to hand-roll.
pub(crate) enum DualCache {
    Empty,
    Mem {
        /// Preconditioned `n × k` matrix `g̃ = P ĝ` (the raw matrix when
        /// the spec is identity).
        pre_rows: Vec<f32>,
        self_inf: Vec<f32>,
        n: usize,
        fim_rows: usize,
        describe: Option<String>,
    },
    Streamed(StreamedCache),
}

impl DualCache {
    pub fn is_cached(&self) -> bool {
        !matches!(self, DualCache::Empty)
    }

    /// In-memory ingest: fit the spec's preconditioner over `layout`
    /// blocks of the `n × k` matrix, retain the preconditioned image and
    /// the self-influence diagonal.
    pub fn ingest_mem(
        grads: &[f32],
        n: usize,
        layout: &BlockLayout,
        spec: &PrecondSpec,
    ) -> Result<Self> {
        let k = layout.total();
        ensure!(
            grads.len() == n * k,
            "cache: got {} values for n = {n} rows × k = {k}",
            grads.len()
        );
        if spec.needs_fim() {
            let pre = spec.fit_mem(grads, n, layout)?;
            let mut img = grads.to_vec();
            apply_rows_parallel(pre.as_ref(), &mut img, n);
            let self_inf = rowwise_dot(grads, &img, n, k);
            Ok(DualCache::Mem {
                pre_rows: img,
                self_inf,
                n,
                fim_rows: n,
                describe: Some(pre.describe()),
            })
        } else {
            let self_inf = rowwise_dot(grads, grads, n, k);
            Ok(DualCache::Mem {
                pre_rows: grads.to_vec(),
                self_inf,
                n,
                fim_rows: 0,
                describe: None,
            })
        }
    }

    /// Streamed ingest from a finished store (see [`StreamedCache::build`]).
    pub fn ingest_stream(
        reader: &StoreReader,
        opts: &StreamOpts,
        layout: BlockLayout,
        spec: &PrecondSpec,
    ) -> Result<Self> {
        Ok(DualCache::Streamed(StreamedCache::build(
            reader, opts, layout, spec,
        )?))
    }

    /// Score columns this cache produces (0 when empty).
    pub fn out_cols(&self) -> usize {
        match self {
            DualCache::Empty => 0,
            DualCache::Mem { n, .. } => *n,
            DualCache::Streamed(sc) => sc.out_cols(),
        }
    }

    /// `m × out_cols` scores of an `m × k` query block against the cache.
    pub fn scores(&self, queries: &[f32], m: usize, k: usize) -> Result<Vec<f32>> {
        match self {
            DualCache::Empty => bail!("no cached train set; call cache() first"),
            DualCache::Mem { pre_rows, n, .. } => {
                ensure!(
                    queries.len() == m * k,
                    "query block holds {} values, expected m = {m} × k = {k}",
                    queries.len()
                );
                Ok(super::graddot::graddot_scores(pre_rows, *n, k, queries, m))
            }
            DualCache::Streamed(sc) => sc.scores(queries, m),
        }
    }

    /// The self-influence diagonal (per row, or per group).
    pub fn self_inf(&self) -> Result<&[f32]> {
        match self {
            DualCache::Empty => bail!("no cached train set; call cache() first"),
            DualCache::Mem { self_inf, .. } => Ok(self_inf),
            DualCache::Streamed(sc) => Ok(sc.self_inf()),
        }
    }

    /// Rows the FIM fit pass consumed (0 under artifact reuse or identity).
    pub fn fim_rows(&self) -> usize {
        match self {
            DualCache::Empty => 0,
            DualCache::Mem { fim_rows, .. } => *fim_rows,
            DualCache::Streamed(sc) => sc.fim_rows(),
        }
    }

    /// The fitted solver's description, if one was fitted.
    pub fn describe(&self) -> Option<String> {
        match self {
            DualCache::Empty => None,
            DualCache::Mem { describe, .. } => describe.clone(),
            DualCache::Streamed(sc) => sc.describe(),
        }
    }

    /// Coverage of the streaming passes, when this cache streams.
    /// In-memory caches never degrade — rows that made it into memory were
    /// read whole — so they report `None`.
    pub fn coverage(&self) -> Option<Coverage> {
        match self {
            DualCache::Streamed(sc) => Some(sc.coverage()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;
    use crate::store::StoreWriter;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "grass_stream_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn write_store(dir: &PathBuf, n: usize, k: usize, shard_rows: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let rows: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let mut w = StoreWriter::create(dir, k, "test", 0, shard_rows).unwrap();
        w.push_batch(&rows).unwrap();
        w.finish().unwrap();
        rows
    }

    #[test]
    fn chunk_rows_respects_budget_with_floor() {
        let o = StreamOpts {
            mem_budget: 2 * 2 * 4 * 8 * 2, // 2 workers × 2 rows × k=8 × 2 bufs
            workers: 2,
            ..StreamOpts::default()
        };
        assert_eq!(o.chunk_rows(8), 2);
        assert!(o.resident_bytes(8) <= o.mem_budget);
        // A budget below one row still streams, one row at a time.
        let tiny = StreamOpts {
            mem_budget: 1,
            workers: 1,
            ..StreamOpts::default()
        };
        assert_eq!(tiny.chunk_rows(1024), 1);
    }

    #[test]
    fn chunk_rows_are_dtype_aware_at_2_and_1_bytes_per_elem() {
        let k = 8;
        let o = StreamOpts {
            mem_budget: 2 * 2 * 4 * k * 2, // fits 2 rows/worker at f32
            workers: 2,
            ..StreamOpts::default()
        };
        // f32: per_row = 4k + 4k = 64 B → 2 rows, the legacy accounting.
        assert_eq!(o.chunk_rows_for(k, PayloadDtype::F32), o.chunk_rows(k));
        assert_eq!(o.chunk_rows_for(k, PayloadDtype::F32), 2);
        // 2 bytes/elem (f16/bf16): per_row = 4k + 2k = 48 B → 2 un-decoded
        // bytes per element come back as ⌊256/96⌋ = 2 rows… same floor, so
        // scale the budget to see the stretch: 6 rows vs 4 at f32.
        let bigger = StreamOpts {
            mem_budget: 2 * 48 * 6,
            workers: 2,
            ..StreamOpts::default()
        };
        assert_eq!(bigger.chunk_rows_for(k, PayloadDtype::F32), 4);
        assert_eq!(bigger.chunk_rows_for(k, PayloadDtype::F16), 6);
        assert_eq!(bigger.chunk_rows_for(k, PayloadDtype::Bf16), 6);
        // 1 byte/elem (int8): per_row = 4k + 4 + k = 44 B → ⌊288/44⌋ = 6.
        assert_eq!(bigger.chunk_rows_for(k, PayloadDtype::Int8), 6);
        let tighter = StreamOpts {
            mem_budget: 44 * 6,
            workers: 1,
            ..StreamOpts::default()
        };
        assert_eq!(tighter.chunk_rows_for(k, PayloadDtype::Int8), 6);
        assert_eq!(tighter.chunk_rows_for(k, PayloadDtype::F16), 5);
        assert_eq!(tighter.chunk_rows_for(k, PayloadDtype::F32), 4);
        // The configured residency never exceeds the budget under any dtype.
        for dt in [
            PayloadDtype::F32,
            PayloadDtype::F16,
            PayloadDtype::Bf16,
            PayloadDtype::Int8,
        ] {
            assert!(bigger.resident_bytes_for(k, dt) <= bigger.mem_budget, "{dt}");
            assert!(tighter.resident_bytes_for(k, dt) <= tighter.mem_budget, "{dt}");
        }
    }

    #[test]
    fn streamed_fims_match_in_memory_accumulation() {
        let dir = tmpdir("fim");
        let (n, k) = (37, 6);
        let rows = write_store(&dir, n, k, 5, 1);
        let r = StoreReader::open(&dir).unwrap();
        let layout = BlockLayout::new(vec![k]);
        let opts = StreamOpts {
            mem_budget: 3 * 2 * 4 * k * 2,
            workers: 3,
            ..StreamOpts::default()
        };
        let (fims, seen) = stream_block_fims(&r, &opts, &layout).unwrap();
        assert_eq!(seen, n);
        let want = crate::attrib::fim::accumulate_fim(&rows, n, k);
        for i in 0..k * k {
            assert!(
                (fims[0][i] - want[i]).abs() < 1e-5,
                "fim[{i}]: {} vs {}",
                fims[0][i],
                want[i]
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn streamed_fims_sparse_rows_take_fast_path_and_match() {
        // A store whose rows are ~5% dense: the per-row dispatch sends
        // them through add_row_sparse, and the result still matches the
        // dense in-memory accumulation.
        let dir = tmpdir("fim_sparse");
        let (n, k) = (41, 32);
        let mut rng = Pcg::new(9);
        let rows: Vec<f32> = (0..n * k)
            .map(|_| {
                if rng.next_f32() < 0.05 {
                    rng.next_gaussian()
                } else {
                    0.0
                }
            })
            .collect();
        let mut w = StoreWriter::create(&dir, k, "test", 0, 7).unwrap();
        w.push_batch(&rows).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&dir).unwrap();
        let layout = BlockLayout::new(vec![k]);
        let opts = StreamOpts::with_budget(2 * 3 * 4 * k * 2);
        let (fims, seen) = stream_block_fims(&r, &opts, &layout).unwrap();
        assert_eq!(seen, n);
        let want = crate::attrib::fim::accumulate_fim(&rows, n, k);
        for i in 0..k * k {
            assert!(
                (fims[0][i] - want[i]).abs() < 1e-5,
                "fim[{i}]: {} vs {}",
                fims[0][i],
                want[i]
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn streamed_scores_match_graddot_on_raw_rows() {
        let dir = tmpdir("scores");
        let (n, k, m) = (23, 5, 4);
        let rows = write_store(&dir, n, k, 4, 2);
        let r = StoreReader::open(&dir).unwrap();
        let mut rng = Pcg::new(3);
        let queries: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let opts = StreamOpts {
            mem_budget: 2 * 3 * 4 * k * 2,
            workers: 2,
            ..StreamOpts::default()
        };
        let got = stream_scores(&r, &opts, &queries, m, None).unwrap();
        let want = crate::attrib::graddot::graddot_scores(&rows, n, k, &queries, m);
        assert_eq!(got.len(), want.len());
        for i in 0..m * n {
            assert!(
                (got[i] - want[i]).abs() < 1e-5 * (1.0 + want[i].abs()),
                "score {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn artifact_backed_build_skips_the_fim_pass() {
        let dir = tmpdir("artifact");
        let (n, k) = (30, 6);
        let _rows = write_store(&dir, n, k, 7, 4);
        let r = StoreReader::open(&dir).unwrap();
        let layout = BlockLayout::new(vec![k]);
        let spec = PrecondSpec::Damped { lambda: 0.1 };
        let base = StreamOpts::with_budget(4096);
        let refit = StreamedCache::build(&r, &base, layout.clone(), &spec).unwrap();
        assert_eq!(refit.fim_rows(), n);

        let art = PrecondArtifact::fit(&r, &base, &layout).unwrap();
        let opts = StreamOpts {
            artifact: Some(Arc::new(art)),
            ..base
        };
        let reused = StreamedCache::build(&r, &opts, layout, &spec).unwrap();
        assert_eq!(reused.fim_rows(), 0);
        // Identical scoring state either way.
        let mut rng = Pcg::new(5);
        let q: Vec<f32> = (0..3 * k).map(|_| rng.next_gaussian()).collect();
        let (a, b) = (refit.scores(&q, 3).unwrap(), reused.scores(&q, 3).unwrap());
        for i in 0..3 * n {
            assert!((a[i] - b[i]).abs() <= 1e-6 * (1.0 + a[i].abs()), "at {i}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn degraded_scores_zero_quarantined_rows_and_match_elsewhere() {
        let dir = tmpdir("degraded");
        let (n, k, m) = (20, 4, 3);
        let rows = write_store(&dir, n, k, 5, 8); // 4 shards × 5 rows
        let r = StoreReader::open(&dir).unwrap();
        let mut rng = Pcg::new(11);
        let queries: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        // Truncate shard 2 (rows 10..15).
        let p = dir.join("shard_0002.bin");
        let len = std::fs::metadata(&p).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&p)
            .unwrap()
            .set_len(len - 8)
            .unwrap();
        // Without skip_corrupt the corruption is fatal.
        let strict = StreamOpts::with_budget(4096);
        assert!(stream_scores(&r, &strict, &queries, m, None).is_err());
        // With it, surviving rows match a clean run and dead rows score 0.
        let opts = StreamOpts {
            skip_corrupt: true,
            ..StreamOpts::with_budget(4096)
        };
        let got = stream_scores(&r, &opts, &queries, m, None).unwrap();
        let want = crate::attrib::graddot::graddot_scores(&rows, n, k, &queries, m);
        for q in 0..m {
            for i in 0..n {
                let v = got[q * n + i];
                if (10..15).contains(&i) {
                    assert_eq!(v, 0.0, "quarantined row {i} must stay zero");
                } else {
                    let w = want[q * n + i];
                    assert!(
                        (v - w).abs() < 1e-5 * (1.0 + w.abs()),
                        "row {i}: {v} vs {w}"
                    );
                }
            }
        }
        assert_eq!(opts.log.quarantined(), vec![2]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn transient_faults_retry_to_success() {
        let dir = tmpdir("transient");
        let (n, k, m) = (12, 3, 2);
        let rows = write_store(&dir, n, k, 4, 13);
        let mut r = StoreReader::open(&dir).unwrap();
        let plan = crate::store::FaultPlan::new();
        plan.fail_read(1, crate::store::FaultKind::Transient, 0, 2);
        r.inject_faults(plan);
        let mut rng = Pcg::new(4);
        let queries: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let opts = StreamOpts {
            retry: RetryPolicy {
                retries: 3,
                backoff: std::time::Duration::from_millis(1),
                seed: 0,
            },
            ..StreamOpts::with_budget(4096)
        };
        let got = stream_scores(&r, &opts, &queries, m, None).unwrap();
        let want = crate::attrib::graddot::graddot_scores(&rows, n, k, &queries, m);
        for i in 0..m * n {
            assert!(
                (got[i] - want[i]).abs() < 1e-5 * (1.0 + want[i].abs()),
                "score {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
        assert!(opts.log.retries_attempted() >= 2, "retries were recorded");
        assert!(opts.log.quarantined().is_empty(), "nothing was quarantined");
        // Without retries the same plan is fatal.
        let plan = crate::store::FaultPlan::new();
        plan.fail_read(1, crate::store::FaultKind::Transient, 0, 1);
        let mut r2 = StoreReader::open(&dir).unwrap();
        r2.inject_faults(plan);
        let fail_fast = StreamOpts::with_budget(4096);
        assert!(stream_scores(&r2, &fail_fast, &queries, m, None).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn coverage_accounts_quarantined_rows_and_describes() {
        let mut c = Coverage {
            rows_total: 512,
            rows_scored: 480,
            quarantined: vec![2],
            retries_attempted: 0,
        };
        assert!(c.is_degraded());
        let s = c.describe();
        assert!(s.contains("480/512"), "{s}");
        assert!(s.contains("93.8%"), "{s}");
        assert!(s.contains("[2]"), "{s}");
        c.merge(&Coverage {
            rows_total: 512,
            rows_scored: 512,
            quarantined: vec![2, 5],
            retries_attempted: 3,
        });
        assert_eq!(c.rows_total, 1024);
        assert_eq!(c.rows_scored, 992);
        assert_eq!(c.quarantined, vec![2, 5]);
        assert_eq!(c.retries_attempted, 3);
        assert!(!Coverage::default().is_degraded());
    }
}
