//! TRAK-style checkpoint ensembling (Park et al. 2023): attribution scores
//! are averaged over `C` independently trained checkpoints, each with its
//! own per-sample gradients, compression, and preconditioner. The paper
//! uses 10/10/5 checkpoints for MLP/ResNet9/MusicTransformer (App. B.2).

use super::blockwise::BlockLayout;
use super::influence::InfluenceEngine;
use super::stream::{StreamOpts, StreamedCache};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// One checkpoint's compressed gradients (train + query share a seed so
/// the projection matches).
pub struct CheckpointGrads {
    pub train: Vec<f32>,
    pub queries: Vec<f32>,
}

/// Ensemble attribution: mean over checkpoints of the per-checkpoint
/// influence scores. All checkpoints share `k` and `damping`.
pub fn trak_scores(
    checkpoints: &[CheckpointGrads],
    n: usize,
    m: usize,
    k: usize,
    damping: f64,
) -> Result<Vec<f32>> {
    assert!(!checkpoints.is_empty());
    let engine = InfluenceEngine::new(k, damping);
    let mut total = vec![0.0f64; m * n];
    for ck in checkpoints {
        let scores = engine.attribute(&ck.train, n, &ck.queries, m)?;
        for (t, &s) in total.iter_mut().zip(&scores) {
            *t += s as f64;
        }
    }
    let c = checkpoints.len() as f64;
    Ok(total.into_iter().map(|v| (v / c) as f32).collect())
}

/// One TRAK checkpoint's scoring state: the resident preconditioned
/// matrix, or the streamed handle (per-checkpoint FIM/preconditioner with
/// rows re-streamed from that checkpoint's store at attribute time).
enum TrakCk {
    Mem {
        pre: Vec<f32>,
        self_inf: Vec<f32>,
    },
    Streamed(StreamedCache),
}

impl TrakCk {
    fn self_inf(&self) -> &[f32] {
        match self {
            TrakCk::Mem { self_inf, .. } => self_inf,
            TrakCk::Streamed(sc) => sc.self_inf(),
        }
    }
}

/// TRAK as a stateful [`Attributor`]: every [`Attributor::cache`] /
/// [`Attributor::cache_stream`] call adds one checkpoint's compressed
/// train gradients (preconditioned on ingest), and
/// [`Attributor::attribute`] averages the per-checkpoint influence
/// scores. With a single cached checkpoint this reduces exactly to
/// [`InfluenceEngine`].
pub struct Trak {
    k: usize,
    damping: f64,
    /// Per-checkpoint state; the raw gradients are never retained —
    /// self-influence is computed on ingest while they are in hand.
    checkpoints: Vec<TrakCk>,
    n: usize,
}

impl Trak {
    pub fn new(k: usize, damping: f64) -> Self {
        Self {
            k,
            damping,
            checkpoints: vec![],
            n: 0,
        }
    }

    fn check_rows(&self, n: usize) -> Result<()> {
        if !self.checkpoints.is_empty() && n != self.n {
            bail!(
                "trak checkpoint has n = {n} train rows, previous checkpoints had {}",
                self.n
            );
        }
        Ok(())
    }
}

impl Attributor for Trak {
    fn name(&self) -> &'static str {
        "trak"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        self.check_rows(n)?;
        let engine = InfluenceEngine::new(self.k, self.damping);
        let pre = engine.precondition(grads, n)?;
        let self_inf = super::influence::rowwise_dot(grads, &pre, n, self.k);
        self.checkpoints.push(TrakCk::Mem { pre, self_inf });
        self.n = n;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        let sc = StreamedCache::build(
            reader,
            opts,
            BlockLayout::new(vec![self.k]),
            Some(self.damping),
        )?;
        self.check_rows(sc.out_cols())?;
        self.n = sc.out_cols();
        self.checkpoints.push(TrakCk::Streamed(sc));
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        if self.checkpoints.is_empty() {
            bail!("trak scorer has no cached checkpoints; call cache() first");
        }
        let n = self.n;
        let mut total = vec![0.0f64; m * n];
        for ck in &self.checkpoints {
            let s = match ck {
                TrakCk::Mem { pre, .. } => {
                    super::graddot::graddot_scores(pre, n, self.k, queries, m)
                }
                TrakCk::Streamed(sc) => sc.scores(queries, m)?,
            };
            for (t, &v) in total.iter_mut().zip(&s) {
                *t += v as f64;
            }
        }
        let c = self.checkpoints.len() as f64;
        Ok(ScoreMatrix::new(
            total.into_iter().map(|v| (v / c) as f32).collect(),
            m,
            n,
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        if self.checkpoints.is_empty() {
            bail!("trak scorer has no cached checkpoints; call cache() first");
        }
        let c = self.checkpoints.len() as f64;
        Ok((0..self.n)
            .map(|i| {
                let sum: f64 = self
                    .checkpoints
                    .iter()
                    .map(|ck| ck.self_inf()[i] as f64)
                    .sum();
                (sum / c) as f32
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn random_ck(n: usize, m: usize, k: usize, seed: u64) -> CheckpointGrads {
        let mut rng = Pcg::new(seed);
        CheckpointGrads {
            train: (0..n * k).map(|_| rng.next_gaussian()).collect(),
            queries: (0..m * k).map(|_| rng.next_gaussian()).collect(),
        }
    }

    #[test]
    fn single_checkpoint_equals_influence() {
        let (n, m, k) = (10, 3, 5);
        let ck = random_ck(n, m, k, 1);
        let ens = trak_scores(&[ck], n, m, k, 0.1).unwrap();
        let ck2 = random_ck(n, m, k, 1);
        let solo = InfluenceEngine::new(k, 0.1)
            .attribute(&ck2.train, n, &ck2.queries, m)
            .unwrap();
        for i in 0..m * n {
            assert!((ens[i] - solo[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn ensemble_is_mean() {
        let (n, m, k) = (8, 2, 4);
        let cks = vec![random_ck(n, m, k, 2), random_ck(n, m, k, 3)];
        let ens = trak_scores(&cks, n, m, k, 0.5).unwrap();
        let engine = InfluenceEngine::new(k, 0.5);
        let s1 = engine.attribute(&cks[0].train, n, &cks[0].queries, m).unwrap();
        let s2 = engine.attribute(&cks[1].train, n, &cks[1].queries, m).unwrap();
        for i in 0..m * n {
            let want = (s1[i] + s2[i]) / 2.0;
            assert!((ens[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn ensembling_reduces_variance() {
        // Scores from many checkpoints of pure noise shrink toward zero.
        let (n, m, k) = (20, 1, 8);
        let one = trak_scores(&[random_ck(n, m, k, 10)], n, m, k, 0.1).unwrap();
        let many: Vec<CheckpointGrads> = (0..16).map(|s| random_ck(n, m, k, 100 + s)).collect();
        let ens = trak_scores(&many, n, m, k, 0.1).unwrap();
        let var = |v: &[f32]| v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(var(&ens) < var(&one), "{} !< {}", var(&ens), var(&one));
    }
}
