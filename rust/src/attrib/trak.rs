//! TRAK-style checkpoint ensembling (Park et al. 2023): attribution scores
//! are averaged over `C` independently trained checkpoints, each with its
//! own per-sample gradients, compression, and preconditioner. The paper
//! uses 10/10/5 checkpoints for MLP/ResNet9/MusicTransformer (App. B.2).

use super::blockwise::BlockLayout;
use super::influence::InfluenceEngine;
use super::precond::{PrecondSpec, PrecondStats};
use super::stream::{DualCache, StreamOpts};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// One checkpoint's compressed gradients (train + query share a seed so
/// the projection matches).
pub struct CheckpointGrads {
    pub train: Vec<f32>,
    pub queries: Vec<f32>,
}

/// Ensemble attribution: mean over checkpoints of the per-checkpoint
/// influence scores. All checkpoints share `k` and `damping`.
pub fn trak_scores(
    checkpoints: &[CheckpointGrads],
    n: usize,
    m: usize,
    k: usize,
    damping: f64,
) -> Result<Vec<f32>> {
    assert!(!checkpoints.is_empty());
    let engine = InfluenceEngine::new(k, damping);
    let mut total = vec![0.0f64; m * n];
    for ck in checkpoints {
        let scores = engine.attribute(&ck.train, n, &ck.queries, m)?;
        for (t, &s) in total.iter_mut().zip(&scores) {
            *t += s as f64;
        }
    }
    let c = checkpoints.len() as f64;
    Ok(total.into_iter().map(|v| (v / c) as f32).collect())
}

/// TRAK as a stateful [`Attributor`]: every [`Attributor::cache`] /
/// [`Attributor::cache_stream`] call adds one checkpoint's compressed
/// train gradients (preconditioned on ingest — each checkpoint gets its
/// own fitted solver), and [`Attributor::attribute`] averages the
/// per-checkpoint influence scores. With a single cached checkpoint this
/// reduces exactly to [`InfluenceEngine`].
pub struct Trak {
    k: usize,
    precond: PrecondSpec,
    /// Per-checkpoint dual-mode caches; the raw gradients are never
    /// retained — self-influence is computed on ingest.
    checkpoints: Vec<DualCache>,
    n: usize,
}

impl Trak {
    pub fn new(k: usize, damping: f64) -> Self {
        Self::with_precond(k, PrecondSpec::Damped { lambda: damping })
    }

    /// TRAK with an explicit per-checkpoint preconditioner spec.
    pub fn with_precond(k: usize, precond: PrecondSpec) -> Self {
        Self {
            k,
            precond,
            checkpoints: vec![],
            n: 0,
        }
    }

    fn layout(&self) -> BlockLayout {
        BlockLayout::new(vec![self.k])
    }

    fn check_rows(&self, n: usize) -> Result<()> {
        if !self.checkpoints.is_empty() && n != self.n {
            bail!(
                "trak checkpoint has n = {n} train rows, previous checkpoints had {}",
                self.n
            );
        }
        Ok(())
    }
}

impl Attributor for Trak {
    fn name(&self) -> &'static str {
        "trak"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        self.check_rows(n)?;
        let ck = DualCache::ingest_mem(grads, n, &self.layout(), &self.precond)?;
        self.checkpoints.push(ck);
        self.n = n;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        let ck = DualCache::ingest_stream(reader, opts, self.layout(), &self.precond)?;
        self.check_rows(ck.out_cols())?;
        self.n = ck.out_cols();
        self.checkpoints.push(ck);
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        if self.checkpoints.is_empty() {
            bail!("trak scorer has no cached checkpoints; call cache() first");
        }
        let n = self.n;
        let mut total = vec![0.0f64; m * n];
        for ck in &self.checkpoints {
            let s = ck.scores(queries, m, self.k)?;
            for (t, &v) in total.iter_mut().zip(&s) {
                *t += v as f64;
            }
        }
        let c = self.checkpoints.len() as f64;
        Ok(ScoreMatrix::new(
            total.into_iter().map(|v| (v / c) as f32).collect(),
            m,
            n,
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        if self.checkpoints.is_empty() {
            bail!("trak scorer has no cached checkpoints; call cache() first");
        }
        let c = self.checkpoints.len() as f64;
        let mut out = vec![0.0f64; self.n];
        for ck in &self.checkpoints {
            for (o, &v) in out.iter_mut().zip(ck.self_inf()?) {
                *o += v as f64;
            }
        }
        Ok(out.into_iter().map(|v| (v / c) as f32).collect())
    }

    fn precond_stats(&self) -> PrecondStats {
        PrecondStats {
            fim_rows: self.checkpoints.iter().map(|c| c.fim_rows()).sum(),
            describe: self
                .checkpoints
                .first()
                .and_then(|c| c.describe())
                .unwrap_or_else(|| self.precond.spec_string()),
        }
    }

    fn coverage(&self) -> Option<super::Coverage> {
        let mut merged: Option<super::Coverage> = None;
        for ck in &self.checkpoints {
            if let Some(c) = ck.coverage() {
                match &mut merged {
                    Some(m) => m.merge(&c),
                    None => merged = Some(c),
                }
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn random_ck(n: usize, m: usize, k: usize, seed: u64) -> CheckpointGrads {
        let mut rng = Pcg::new(seed);
        CheckpointGrads {
            train: (0..n * k).map(|_| rng.next_gaussian()).collect(),
            queries: (0..m * k).map(|_| rng.next_gaussian()).collect(),
        }
    }

    #[test]
    fn single_checkpoint_equals_influence() {
        let (n, m, k) = (10, 3, 5);
        let ck = random_ck(n, m, k, 1);
        let ens = trak_scores(&[ck], n, m, k, 0.1).unwrap();
        let ck2 = random_ck(n, m, k, 1);
        let solo = InfluenceEngine::new(k, 0.1)
            .attribute(&ck2.train, n, &ck2.queries, m)
            .unwrap();
        for i in 0..m * n {
            assert!((ens[i] - solo[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn ensemble_is_mean() {
        let (n, m, k) = (8, 2, 4);
        let cks = vec![random_ck(n, m, k, 2), random_ck(n, m, k, 3)];
        let ens = trak_scores(&cks, n, m, k, 0.5).unwrap();
        let engine = InfluenceEngine::new(k, 0.5);
        let s1 = engine.attribute(&cks[0].train, n, &cks[0].queries, m).unwrap();
        let s2 = engine.attribute(&cks[1].train, n, &cks[1].queries, m).unwrap();
        for i in 0..m * n {
            let want = (s1[i] + s2[i]) / 2.0;
            assert!((ens[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn ensembling_reduces_variance() {
        // Scores from many checkpoints of pure noise shrink toward zero.
        let (n, m, k) = (20, 1, 8);
        let one = trak_scores(&[random_ck(n, m, k, 10)], n, m, k, 0.1).unwrap();
        let many: Vec<CheckpointGrads> = (0..16).map(|s| random_ck(n, m, k, 100 + s)).collect();
        let ens = trak_scores(&many, n, m, k, 0.1).unwrap();
        let var = |v: &[f32]| v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(var(&ens) < var(&one), "{} !< {}", var(&ens), var(&one));
    }

    #[test]
    fn stats_sum_fim_rows_over_checkpoints() {
        let (n, m, k) = (9, 2, 4);
        let c1 = random_ck(n, m, k, 20);
        let c2 = random_ck(n, m, k, 21);
        let mut t = Trak::new(k, 0.1);
        Attributor::cache(&mut t, &c1.train, n).unwrap();
        Attributor::cache(&mut t, &c2.train, n).unwrap();
        assert_eq!(Attributor::precond_stats(&t).fim_rows, 2 * n);
    }
}
