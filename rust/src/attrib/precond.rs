//! The preconditioner subsystem — every scorer's second-order machinery
//! behind one pluggable interface.
//!
//! §2.1's iFVP step `g̃ = (F̂ + λI)⁻¹ ĝ` used to be hand-rolled inside each
//! engine. This module factors it into three orthogonal pieces:
//!
//! - **[`PrecondSpec`]** — a parsed spec string
//!   (`identity | damped:λ | eig:r[,λ] | blockwise[:λ]`) naming which
//!   solver to fit. `identity` scores raw inner products (the GradDot
//!   family), `damped` is the monolithic damped-Cholesky iFVP, `eig:r` is
//!   the LoRIF-style eigen-truncated rank-`r` inverse (O(k·r) per row,
//!   exact at `r = k`), and `blockwise` is the per-layer block-diagonal
//!   family (§3.3.2).
//! - **[`Preconditioner`]** — the fitted solver: `apply_rows` transforms a
//!   row-major block in place (streaming-compatible: the out-of-core
//!   passes call it on worker-local blocks), `describe` reports what was
//!   fitted.
//! - **[`PrecondArtifact`]** — the persisted solver state (`precond.bin`
//!   in the store directory): the per-block FIMs plus provenance
//!   (method/seed/k/row-count). `grass fit` writes it once; every later
//!   `grass attribute` validates and reuses it, skipping the O(n·k) FIM
//!   re-stream entirely — any [`PrecondSpec`] (any λ, any rank) builds
//!   from the same artifact.
//!
//! [`select`] implements the paper's damping grid search (App. B.2):
//! every λ in [`select::DAMPING_GRID`] is fitted from the same FIMs and
//! scored by [`crate::eval::lds`] on held-out subsets.

use super::blockwise::BlockLayout;
use super::fim::accumulate_fim;
use super::stream::{stream_block_fims, StreamOpts};
use crate::linalg::{eigh, CholeskyFactor};
use crate::store::manifest::{file_crc32c, write_atomic};
use crate::store::{crc32c, Manifest, StoreMeta, StoreReader, PRECOND_FILE};
use crate::util::json::Json;
use crate::util::par;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::Read;
use std::path::{Path, PathBuf};

/// A fitted second-order solver: applies `g ↦ P g` (for some approximation
/// `P ≈ (F̂ + λI)⁻¹`) to row-major blocks in place.
///
/// `apply_rows` is deliberately **serial** — the callers own the
/// parallelism (streaming workers call it on their private blocks;
/// resident matrices go through [`apply_rows_parallel`]).
pub trait Preconditioner: Send + Sync {
    /// Row width `k` this solver operates on.
    fn dim(&self) -> usize;

    /// Transform the first `rows` rows of `buf` (row-major, width
    /// [`Preconditioner::dim`]) in place.
    fn apply_rows(&self, buf: &mut [f32], rows: usize);

    /// Human-readable description of the fitted solver (impl, dims, λ).
    fn describe(&self) -> String;
}

/// Precondition a resident `n × k` matrix in place, rows split across the
/// thread pool (each chunk runs the solver's serial `apply_rows`).
pub fn apply_rows_parallel(pre: &dyn Preconditioner, buf: &mut [f32], n: usize) {
    let k = pre.dim();
    assert_eq!(buf.len(), n * k, "apply_rows_parallel: buffer is not n × k");
    if n == 0 {
        return;
    }
    par::par_chunks_mut(buf, k, 8, |_, chunk| {
        pre.apply_rows(chunk, chunk.len() / k);
    });
}

// ---------------------------------------------------------------------------
// Spec
// ---------------------------------------------------------------------------

/// Parsed preconditioner spec: which solver to fit, with which damping.
#[derive(Debug, Clone, PartialEq)]
pub enum PrecondSpec {
    /// No preconditioning: scores are raw inner products.
    Identity,
    /// Damped-Cholesky iFVP: `(F̂ + λI)⁻¹`, O(k²) per row. Solves over
    /// the engine's block layout — monolithic for the flat scorers,
    /// per-layer (equivalent to [`PrecondSpec::Blockwise`]) when the
    /// blockwise scorer supplies a multi-block layout.
    Damped { lambda: f64 },
    /// Eigen-truncated rank-`r` inverse (LoRIF-style): keep the top-`r`
    /// eigenpairs of `F̂`, treat the tail as zero — O(k·r) per row, exact
    /// at `r = k`.
    Eig { rank: usize, lambda: f64 },
    /// Per-layer block-diagonal damped Cholesky (§3.3.2): one independent
    /// solve per layout block.
    Blockwise { lambda: f64 },
}

impl PrecondSpec {
    /// Damping used when a spec string omits λ.
    pub const DEFAULT_LAMBDA: f64 = 1e-3;

    /// Parse `identity | damped[:λ] | eig:r[,λ] | blockwise[:λ]`, filling
    /// omitted λ with [`PrecondSpec::DEFAULT_LAMBDA`].
    pub fn parse(s: &str) -> Result<Self> {
        Self::parse_with(s, Self::DEFAULT_LAMBDA)
    }

    /// [`PrecondSpec::parse`] with an explicit default λ for spec strings
    /// that omit it (the CLI passes `--damping` here).
    pub fn parse_with(s: &str, default_lambda: f64) -> Result<Self> {
        let s = s.trim();
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h.trim(), Some(r.trim())),
            None => (s, None),
        };
        let lambda_of = |r: Option<&str>| -> Result<f64> {
            match r {
                None | Some("") => Ok(default_lambda),
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|e| anyhow!("precond spec '{s}': bad damping '{v}': {e}")),
            }
        };
        match head {
            "identity" | "id" | "none" => {
                ensure!(
                    rest.is_none(),
                    "precond spec '{s}': identity takes no parameters"
                );
                Ok(Self::Identity)
            }
            "damped" | "chol" => Ok(Self::Damped {
                lambda: lambda_of(rest)?,
            }),
            "blockwise" | "bw" => Ok(Self::Blockwise {
                lambda: lambda_of(rest)?,
            }),
            "eig" => {
                let r = rest.ok_or_else(|| {
                    anyhow!("precond spec '{s}': eig needs a rank, e.g. 'eig:64' or 'eig:64,1e-3'")
                })?;
                let (rank_s, lam) = match r.split_once(',') {
                    Some((a, b)) => (a.trim(), Some(b.trim())),
                    None => (r, None),
                };
                let rank: usize = rank_s
                    .parse()
                    .map_err(|e| anyhow!("precond spec '{s}': bad rank '{rank_s}': {e}"))?;
                ensure!(rank >= 1, "precond spec '{s}': eig rank must be ≥ 1");
                Ok(Self::Eig {
                    rank,
                    lambda: lambda_of(lam)?,
                })
            }
            other => bail!(
                "unknown preconditioner '{other}' (expected identity|damped:λ|eig:r[,λ]|blockwise)"
            ),
        }
    }

    /// Canonical spec string; [`PrecondSpec::parse`] roundtrips it.
    pub fn spec_string(&self) -> String {
        match self {
            Self::Identity => "identity".to_string(),
            Self::Damped { lambda } => format!("damped:{lambda:e}"),
            Self::Eig { rank, lambda } => format!("eig:{rank},{lambda:e}"),
            Self::Blockwise { lambda } => format!("blockwise:{lambda:e}"),
        }
    }

    /// The damping λ this spec fits with (`None` for identity).
    pub fn lambda(&self) -> Option<f64> {
        match self {
            Self::Identity => None,
            Self::Damped { lambda } | Self::Eig { lambda, .. } | Self::Blockwise { lambda } => {
                Some(*lambda)
            }
        }
    }

    /// The same solver family with a different λ (identity is unchanged) —
    /// the damping grid search iterates this.
    pub fn with_lambda(&self, lambda: f64) -> Self {
        match self {
            Self::Identity => Self::Identity,
            Self::Damped { .. } => Self::Damped { lambda },
            Self::Eig { rank, .. } => Self::Eig {
                rank: *rank,
                lambda,
            },
            Self::Blockwise { .. } => Self::Blockwise { lambda },
        }
    }

    /// Whether fitting this spec requires a FIM pass over the train rows.
    pub fn needs_fim(&self) -> bool {
        !matches!(self, Self::Identity)
    }

    /// The preconditioner each scorer fits when no `--precond` is given:
    /// the FIM-preconditioned scorers keep their damped families, the
    /// GradDot family stays raw.
    pub fn default_for_scorer(scorer: &str, damping: f64) -> Self {
        match scorer {
            "if" | "influence" | "trak" => Self::Damped { lambda: damping },
            "blockwise" | "bw" => Self::Blockwise { lambda: damping },
            _ => Self::Identity,
        }
    }

    /// The FIM block layout this spec fits over: per-layer blocks for the
    /// blockwise family (when the geometry records layers), one monolithic
    /// `[k]` block otherwise.
    pub fn layout_for(&self, k: usize, layer_dims: &[usize]) -> BlockLayout {
        match self {
            Self::Blockwise { .. } if !layer_dims.is_empty() => {
                BlockLayout::new(layer_dims.to_vec())
            }
            _ => BlockLayout::new(vec![k]),
        }
    }

    /// Build the solver from already-accumulated per-block FIMs (one
    /// `k_l × k_l` matrix per layout block; ignored for identity).
    pub fn build(&self, fims: &[Vec<f32>], layout: &BlockLayout) -> Result<Box<dyn Preconditioner>> {
        let k = layout.total();
        match self {
            Self::Identity => Ok(Box::new(IdentityPrecond { k })),
            Self::Damped { lambda } | Self::Blockwise { lambda } => {
                ensure!(
                    fims.len() == layout.dims.len(),
                    "preconditioner fit: {} FIM block(s) for a {}-block layout",
                    fims.len(),
                    layout.dims.len()
                );
                let mut factors = Vec::with_capacity(fims.len());
                for (fim, &kl) in fims.iter().zip(&layout.dims) {
                    ensure!(
                        fim.len() == kl * kl,
                        "preconditioner fit: FIM block holds {} values, expected {kl}×{kl}",
                        fim.len()
                    );
                    factors.push(CholeskyFactor::factor_damped(fim, kl, *lambda)?);
                }
                // The label reports the *fitted structure*, not the spec
                // variant: damped on a multi-block layout performs (and
                // must report) per-block solves, and blockwise on a flat
                // [k] layout is a monolithic solve.
                let blockwise = factors.len() > 1;
                Ok(Box::new(CholeskyPrecond {
                    layout: layout.clone(),
                    factors,
                    lambda: *lambda,
                    blockwise,
                }))
            }
            Self::Eig { rank, lambda } => {
                ensure!(
                    fims.len() == 1 && layout.dims.len() == 1,
                    "the eig preconditioner is monolithic, but the layout has {} blocks \
                     (use --precond blockwise for per-layer solves)",
                    layout.dims.len()
                );
                Ok(Box::new(EigPrecond::fit(&fims[0], k, *rank, *lambda)?))
            }
        }
    }

    /// Fit from a resident `n × k` compressed gradient matrix (the
    /// in-memory cache path): accumulate the per-block FIMs, then
    /// [`PrecondSpec::build`].
    pub fn fit_mem(
        &self,
        grads: &[f32],
        n: usize,
        layout: &BlockLayout,
    ) -> Result<Box<dyn Preconditioner>> {
        if !self.needs_fim() {
            return self.build(&[], layout);
        }
        let fims = fit_fims_mem(grads, n, layout);
        self.build(&fims, layout)
    }
}

/// Accumulate one FIM per layout block over a resident `n × k` matrix
/// (the in-memory analogue of the streaming `stream_block_fims` pass).
pub fn fit_fims_mem(grads: &[f32], n: usize, layout: &BlockLayout) -> Vec<Vec<f32>> {
    let total = layout.total();
    assert_eq!(grads.len(), n * total, "fit_fims_mem: matrix is not n × k");
    if layout.dims.len() == 1 {
        return vec![accumulate_fim(grads, n, total)];
    }
    layout
        .dims
        .iter()
        .enumerate()
        .map(|(l, &kl)| {
            let off = layout.offsets[l];
            let mut block = vec![0.0f32; n * kl];
            for i in 0..n {
                block[i * kl..(i + 1) * kl]
                    .copy_from_slice(&grads[i * total + off..i * total + off + kl]);
            }
            accumulate_fim(&block, n, kl)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Implementations
// ---------------------------------------------------------------------------

/// No-op preconditioner: raw inner-product scoring (GradDot family).
pub struct IdentityPrecond {
    k: usize,
}

impl Preconditioner for IdentityPrecond {
    fn dim(&self) -> usize {
        self.k
    }

    fn apply_rows(&self, _buf: &mut [f32], _rows: usize) {}

    fn describe(&self) -> String {
        format!("identity(k={})", self.k)
    }
}

/// Damped-Cholesky iFVP, monolithic or per-layout-block: each row slice
/// `row[l]` becomes `(F_l + λI)⁻¹ row[l]` via one forward+backward solve.
pub struct CholeskyPrecond {
    layout: BlockLayout,
    factors: Vec<CholeskyFactor>,
    lambda: f64,
    blockwise: bool,
}

impl Preconditioner for CholeskyPrecond {
    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn apply_rows(&self, buf: &mut [f32], rows: usize) {
        let total = self.layout.total();
        assert!(buf.len() >= rows * total, "apply_rows: buffer too small");
        let max_k = self.layout.dims.iter().copied().max().unwrap_or(0);
        // One f64 work vector per call, reused across rows and blocks.
        let mut work = vec![0.0f64; max_k];
        for row in buf[..rows * total].chunks_mut(total) {
            for (l, factor) in self.factors.iter().enumerate() {
                let (s, e) = (self.layout.offsets[l], self.layout.offsets[l + 1]);
                let seg = &mut row[s..e];
                for (w, &v) in work.iter_mut().zip(seg.iter()) {
                    *w = v as f64;
                }
                factor.solve_into(&mut work[..e - s]);
                for (v, &w) in seg.iter_mut().zip(work.iter()) {
                    *v = w as f32;
                }
            }
        }
    }

    fn describe(&self) -> String {
        if self.blockwise {
            format!(
                "blockwise-cholesky(blocks={}, k={}, λ={:e})",
                self.factors.len(),
                self.layout.total(),
                self.lambda
            )
        } else {
            format!(
                "damped-cholesky(k={}, λ={:e})",
                self.layout.total(),
                self.lambda
            )
        }
    }
}

/// Eigen-truncated low-rank inverse: with `F̂ = Σ_j λ_j v_j v_jᵀ`,
///
/// `(F̂ + λI)⁻¹ g  ≈  g/λ + Σ_{r<rank} (1/(λ_r+λ) − 1/λ) v_r ⟨v_r, g⟩`
///
/// — exact when the dropped eigenvalues are zero (so exact at full rank),
/// O(k·rank) per row instead of O(k²). The LoRIF-style option for large k.
pub struct EigPrecond {
    k: usize,
    rank: usize,
    lambda: f64,
    /// Top-`rank` eigenvectors, row-major `rank × k` (f64 so the rank-`k`
    /// path matches the f64 Cholesky solve to f32 precision).
    vectors: Vec<f64>,
    /// `1/(λ_r + λ) − 1/λ` per kept eigenpair.
    weights: Vec<f64>,
}

impl EigPrecond {
    /// Eigendecompose a `k × k` FIM and keep the top `rank` pairs
    /// (clamped to `k`). Requires `λ > 0`: the truncated tail is scaled
    /// by `1/λ`.
    pub fn fit(fim: &[f32], k: usize, rank: usize, lambda: f64) -> Result<Self> {
        ensure!(k > 0, "eig preconditioner needs k > 0");
        ensure!(fim.len() == k * k, "eig fit: FIM is not k × k");
        ensure!(
            lambda > 0.0,
            "eig preconditioner needs damping λ > 0 (the truncated tail is scaled by 1/λ), got {lambda}"
        );
        ensure!(rank >= 1, "eig rank must be ≥ 1");
        let rank = rank.min(k);
        let e = eigh(fim, k);
        let vectors = e.vectors[..rank * k].to_vec();
        let weights = e.values[..rank]
            .iter()
            .map(|&l| 1.0 / (l.max(0.0) + lambda) - 1.0 / lambda)
            .collect();
        Ok(Self {
            k,
            rank,
            lambda,
            vectors,
            weights,
        })
    }
}

impl Preconditioner for EigPrecond {
    fn dim(&self) -> usize {
        self.k
    }

    fn apply_rows(&self, buf: &mut [f32], rows: usize) {
        let k = self.k;
        assert!(buf.len() >= rows * k, "apply_rows: buffer too small");
        let inv_l = 1.0 / self.lambda;
        // Per-call scratch, reused across rows.
        let mut coef = vec![0.0f64; self.rank];
        let mut work = vec![0.0f64; k];
        for row in buf[..rows * k].chunks_mut(k) {
            for (r, c) in coef.iter_mut().enumerate() {
                let vrow = &self.vectors[r * k..(r + 1) * k];
                let dot: f64 = vrow.iter().zip(row.iter()).map(|(a, &b)| a * b as f64).sum();
                *c = self.weights[r] * dot;
            }
            for (w, &v) in work.iter_mut().zip(row.iter()) {
                *w = v as f64 * inv_l;
            }
            for (r, &c) in coef.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                let vrow = &self.vectors[r * k..(r + 1) * k];
                for (w, &vv) in work.iter_mut().zip(vrow) {
                    *w += c * vv;
                }
            }
            for (v, &w) in row.iter_mut().zip(work.iter()) {
                *v = w as f32;
            }
        }
    }

    fn describe(&self) -> String {
        format!(
            "eig(r={}, k={}, λ={:e})",
            self.rank, self.k, self.lambda
        )
    }
}

// ---------------------------------------------------------------------------
// Persisted artifacts
// ---------------------------------------------------------------------------

/// Provenance + cost stats of an engine's fitted second-order state,
/// reported through [`super::Attributor::precond_stats`].
#[derive(Debug, Clone, Default)]
pub struct PrecondStats {
    /// Rows streamed (or scanned in memory) by the FIM fit pass — `0`
    /// when a persisted [`PrecondArtifact`] made the pass unnecessary.
    pub fim_rows: usize,
    /// [`Preconditioner::describe`] of the fitted solver(s).
    pub describe: String,
}

const ARTIFACT_MAGIC: &[u8; 8] = b"GRSPRE1\n";

/// The persisted solver artifact (`precond.bin` next to `store.json`): the
/// per-block FIMs a [`PrecondSpec`] fits from, plus the provenance needed
/// to reject a stale or mismatched reuse (method, seed, k, row count).
///
/// Persisting the *FIMs* rather than a single factorisation is deliberate:
/// one artifact serves every solver family and every damping — `damped:λ`,
/// `eig:r,λ`, and the whole `--damping grid` all build from the same file
/// without touching the train rows again.
#[derive(Debug, Clone)]
pub struct PrecondArtifact {
    /// Method spec string of the store the FIMs were fitted on.
    pub method: String,
    /// Projection seed of that store.
    pub seed: u64,
    /// Row width.
    pub k: usize,
    /// Per-block dims the FIMs were accumulated over.
    pub layout: Vec<usize>,
    /// Rows folded into the FIMs (must equal the store's `n` at reuse).
    pub rows: usize,
    /// One row-major `k_l × k_l` FIM per layout block.
    pub fims: Vec<Vec<f32>>,
}

impl PrecondArtifact {
    /// `precond.bin` path inside a store directory.
    pub fn path(dir: impl AsRef<Path>) -> PathBuf {
        dir.as_ref().join(PRECOND_FILE)
    }

    /// Fit the artifact by streaming the store's rows once (shard-parallel
    /// FIM accumulation under the opts' byte budget). Fits over the whole
    /// store — row-group selections refit at attribute time instead.
    pub fn fit(reader: &StoreReader, opts: &StreamOpts, layout: &BlockLayout) -> Result<Self> {
        ensure!(
            opts.groups.is_none(),
            "preconditioner artifacts are fitted over the whole store; \
             row-group selections refit on the selected rows at attribute time"
        );
        let (fims, rows) = stream_block_fims(reader, opts, layout)?;
        Ok(Self {
            method: reader.meta.method.clone(),
            seed: reader.meta.seed,
            k: reader.meta.k,
            layout: layout.dims.clone(),
            rows,
            fims,
        })
    }

    /// The block layout the FIMs were accumulated over.
    pub fn block_layout(&self) -> BlockLayout {
        BlockLayout::new(self.layout.clone())
    }

    /// Reject reuse against a store the artifact was not fitted on:
    /// method, seed, row-width, and row-count mismatches are descriptive
    /// errors naming both sides (`open_checked`-style).
    pub fn validate_store(&self, meta: &StoreMeta) -> Result<()> {
        if self.method != meta.method {
            bail!(
                "precond artifact was fitted on method '{}' but the store records '{}' — \
                 refit with `grass fit`",
                self.method,
                meta.method
            );
        }
        if self.seed != meta.seed {
            bail!(
                "precond artifact was fitted with seed {} but the store records seed {} — \
                 refit with `grass fit`",
                self.seed,
                meta.seed
            );
        }
        if self.k != meta.k {
            bail!(
                "precond artifact was fitted for k = {} but the store rows have k = {} — \
                 refit with `grass fit`",
                self.k,
                meta.k
            );
        }
        if self.rows != meta.n {
            bail!(
                "precond artifact was fitted over {} rows but the store now has {} — \
                 the FIM is stale; refit with `grass fit`",
                self.rows,
                meta.n
            );
        }
        Ok(())
    }

    /// Reject reuse under a different block layout (a monolithic artifact
    /// cannot serve per-layer solves, and vice versa).
    pub fn validate_layout(&self, layout: &BlockLayout) -> Result<()> {
        if self.layout != layout.dims {
            bail!(
                "precond artifact was fitted with block layout {:?} but this attribution \
                 needs {:?} — refit with `grass fit --precond …` or pass --no-artifact",
                self.layout,
                layout.dims
            );
        }
        Ok(())
    }

    /// Write `precond.bin` into a store directory; returns the path.
    ///
    /// Layout: 8-byte magic, u32 LE header length, JSON header (method,
    /// seed, k, rows, layout), then each block's FIM as little-endian f32.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<PathBuf> {
        let path = Self::path(&dir);
        let header = Json::obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("k", Json::Num(self.k as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("layout", Json::arr_usize(&self.layout)),
        ])
        .to_string_pretty();
        let payload_len: usize = self.fims.iter().map(|f| f.len() * 4).sum();
        let mut bytes = Vec::with_capacity(8 + 4 + header.len() + payload_len);
        bytes.extend_from_slice(ARTIFACT_MAGIC);
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for fim in &self.fims {
            for &v in fim {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        write_atomic(&path, &bytes)
            .with_context(|| format!("writing precond artifact {}", path.display()))?;
        // Record the artifact's whole-file checksum in the store manifest
        // (when the store has one) so `grass verify` and later loads can
        // detect bit rot in the fitted FIMs.
        if let Some(mut man) = Manifest::load(dir.as_ref())? {
            man.precond_crc = Some(crc32c(&bytes));
            man.save(dir.as_ref())?;
        }
        Ok(path)
    }

    /// Load `precond.bin` from a store directory, verifying the magic,
    /// header, and payload length. Every buffer below is sized by header
    /// fields, so each size is bounded against the actual file length
    /// *before* allocating — a corrupted header is a descriptive error,
    /// not a multi-gigabyte allocation attempt.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = Self::path(&dir);
        // Whole-file checksum against the store manifest, when recorded: a
        // bit-flipped FIM payload fails here even though the header still
        // parses cleanly and every length check passes.
        if let Some(man) = Manifest::load(dir.as_ref())? {
            if let Some(want) = man.precond_crc {
                let (_, got) = file_crc32c(&path).map_err(|e| {
                    anyhow!("reading precond artifact {}: {e}", path.display())
                })?;
                ensure!(
                    got == want,
                    "precond artifact at {} failed its checksum (manifest records 0x{want:08x}, \
                     file hashes to 0x{got:08x}) — the file is corrupt; refit with `grass fit` \
                     or pass --no-artifact",
                    path.display()
                );
            }
        }
        let mut f = std::fs::File::open(&path)
            .with_context(|| format!("opening precond artifact {}", path.display()))?;
        let file_len = f.metadata()?.len();
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)
            .with_context(|| format!("precond artifact {} is truncated", path.display()))?;
        ensure!(
            magic == *ARTIFACT_MAGIC,
            "{} is not a precond artifact (bad magic)",
            path.display()
        );
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as u64;
        ensure!(
            hlen <= file_len.saturating_sub(12),
            "precond artifact {}: header claims {hlen} bytes but the file holds {file_len}",
            path.display()
        );
        let mut hbytes = vec![0u8; hlen as usize];
        f.read_exact(&mut hbytes)
            .with_context(|| format!("precond artifact {}: truncated header", path.display()))?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)?;
        let layout: Vec<usize> = header
            .req("layout")?
            .as_arr()
            .ok_or_else(|| anyhow!("precond artifact: bad layout"))?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        ensure!(!layout.is_empty(), "precond artifact: empty layout");
        let k = header
            .req("k")?
            .as_usize()
            .ok_or_else(|| anyhow!("precond artifact: bad k"))?;
        let total: usize = layout.iter().sum();
        ensure!(
            total == k,
            "precond artifact {}: layout {layout:?} totals {total} but the header records k = {k}",
            path.display()
        );
        // Exact-length check (u128: immune to kl² overflow on hostile
        // headers) — also rejects trailing garbage.
        let payload: u128 = layout.iter().map(|&kl| (kl as u128) * (kl as u128) * 4).sum();
        let expected = 12u128 + hlen as u128 + payload;
        ensure!(
            file_len as u128 == expected,
            "precond artifact {}: {file_len} bytes on disk but the header implies {expected}",
            path.display()
        );
        let mut fims = Vec::with_capacity(layout.len());
        for &kl in &layout {
            let mut raw = vec![0u8; kl * kl * 4];
            f.read_exact(&mut raw).with_context(|| {
                format!(
                    "precond artifact {}: truncated FIM payload (block of {kl}×{kl})",
                    path.display()
                )
            })?;
            fims.push(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
        }
        Ok(Self {
            method: header.req("method")?.as_str().unwrap_or("").to_string(),
            seed: header.req("seed")?.as_u64().unwrap_or(0),
            k,
            rows: header
                .req("rows")?
                .as_usize()
                .ok_or_else(|| anyhow!("precond artifact: bad rows"))?,
            layout,
            fims,
        })
    }

    /// Load the artifact if `precond.bin` exists in `dir`; `Ok(None)` when
    /// absent, `Err` when present but unreadable.
    pub fn load_if_present(dir: impl AsRef<Path>) -> Result<Option<Self>> {
        if Self::path(&dir).exists() {
            Ok(Some(Self::load(dir)?))
        } else {
            Ok(None)
        }
    }
}

// ---------------------------------------------------------------------------
// Damping selection (App. B.2)
// ---------------------------------------------------------------------------

/// The paper's damping grid search, scored by LDS on held-out subsets:
/// every λ in [`select::DAMPING_GRID`] builds from the *same* fitted FIMs
/// (no re-streaming) and is evaluated by how well the resulting scores
/// rank counterfactual subset losses.
pub mod select {
    use super::*;
    use crate::attrib::graddot::graddot_scores;
    use crate::eval::lds::lds_score;
    use crate::sketch::rng::Pcg;

    /// Candidate damping grid from the paper:
    /// λ ∈ {1e-7, …, 1e-1, 1, 10, 100} (App. B.2).
    pub const DAMPING_GRID: &[f64] = &[
        1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0,
    ];

    /// One grid point: the λ and its held-out score (`None` when the
    /// solver failed to fit or the score was undefined at this λ).
    #[derive(Debug, Clone)]
    pub struct GridEntry {
        pub lambda: f64,
        pub lds: Option<f64>,
    }

    /// Full grid-search outcome, recorded in the run report.
    #[derive(Debug, Clone)]
    pub struct GridReport {
        pub entries: Vec<GridEntry>,
        pub best_lambda: f64,
        pub best_lds: f64,
    }

    /// Run `eval` for every grid λ on solvers built from the same FIMs;
    /// keep the best. Errors if no λ produced a finite score.
    pub fn grid_search(
        base: &PrecondSpec,
        fims: &[Vec<f32>],
        layout: &BlockLayout,
        mut eval: impl FnMut(&dyn Preconditioner) -> Option<f64>,
    ) -> Result<GridReport> {
        ensure!(
            base.needs_fim(),
            "the identity preconditioner has no damping to select"
        );
        let mut entries = Vec::with_capacity(DAMPING_GRID.len());
        let mut best = (f64::NAN, f64::NEG_INFINITY);
        for &lambda in DAMPING_GRID {
            let spec = base.with_lambda(lambda);
            let val = match spec.build(fims, layout) {
                Ok(pre) => eval(pre.as_ref()).filter(|v| v.is_finite()),
                Err(_) => None, // not PD at this λ
            };
            if let Some(v) = val {
                if v > best.1 {
                    best = (lambda, v);
                }
            }
            entries.push(GridEntry { lambda, lds: val });
        }
        ensure!(
            best.1.is_finite(),
            "damping grid search: no λ in the grid produced a valid preconditioner and score"
        );
        Ok(GridReport {
            entries,
            best_lambda: best.0,
            best_lds: best.1,
        })
    }

    /// Grid search scored by [`lds_score`]: for each λ the held-out
    /// queries are preconditioned query-side (the inverse is symmetric,
    /// so this matches cache-side preconditioning at O(m·k²) instead of
    /// O(n·k²) per λ) and scored against the held-out train rows.
    #[allow(clippy::too_many_arguments)]
    pub fn grid_by_lds(
        base: &PrecondSpec,
        fims: &[Vec<f32>],
        layout: &BlockLayout,
        train: &[f32],
        n: usize,
        queries: &[f32],
        m: usize,
        subsets: &[Vec<usize>],
        subset_losses: &[f32],
    ) -> Result<GridReport> {
        let k = layout.total();
        ensure!(train.len() == n * k, "grid_by_lds: train is not n × k");
        ensure!(queries.len() == m * k, "grid_by_lds: queries are not m × k");
        grid_search(base, fims, layout, |pre| {
            let mut q = queries.to_vec();
            pre.apply_rows(&mut q, m);
            let scores = graddot_scores(train, n, k, &q, m);
            let (lds, _) = lds_score(&scores, n, m, subsets, subset_losses);
            Some(lds)
        })
    }

    /// Counterfactual subset losses from the synthetic class datamodel:
    /// retraining on subset `S` lowers query `q`'s loss in proportion to
    /// the same-class mass of `S` (train row `i` belongs to class
    /// `i % n_classes`, the synthetic substrate's layout). A small
    /// deterministic jitter breaks rank ties between subsets of equal
    /// class mass.
    pub fn class_proxy_losses(
        subsets: &[Vec<usize>],
        n_classes: usize,
        query_classes: &[usize],
        jitter_seed: u64,
    ) -> Vec<f32> {
        let m = query_classes.len();
        let mut rng = Pcg::new(jitter_seed ^ 0x10d5);
        let mut out = vec![0.0f32; subsets.len() * m];
        for (s, subset) in subsets.iter().enumerate() {
            for (q, &cq) in query_classes.iter().enumerate() {
                let hits = subset.iter().filter(|&&i| i % n_classes == cq).count();
                out[s * m + q] = -(hits as f32) + 1e-3 * rng.next_gaussian();
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn gaussian(rows: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..rows * k).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn spec_parse_roundtrips_and_rejects_garbage() {
        for s in [
            "identity",
            "damped:1e-3",
            "damped:5e-1",
            "eig:8,1e-3",
            "eig:64,1e1",
            "blockwise:1e-2",
        ] {
            let spec = PrecondSpec::parse(s).unwrap();
            let canon = spec.spec_string();
            assert_eq!(PrecondSpec::parse(&canon).unwrap(), spec, "{s} vs {canon}");
        }
        // Omitted λ fills from the default.
        assert_eq!(
            PrecondSpec::parse_with("damped", 0.25).unwrap(),
            PrecondSpec::Damped { lambda: 0.25 }
        );
        assert_eq!(
            PrecondSpec::parse_with("eig:4", 0.5).unwrap(),
            PrecondSpec::Eig {
                rank: 4,
                lambda: 0.5
            }
        );
        assert!(PrecondSpec::parse("bogus").is_err());
        assert!(PrecondSpec::parse("eig").is_err());
        assert!(PrecondSpec::parse("eig:0").is_err());
        assert!(PrecondSpec::parse("damped:abc").is_err());
        assert!(PrecondSpec::parse("identity:1e-3").is_err());
    }

    #[test]
    fn identity_is_a_noop() {
        let layout = BlockLayout::new(vec![4]);
        let pre = PrecondSpec::Identity.build(&[], &layout).unwrap();
        let mut buf = vec![1.0f32, -2.0, 3.0, 4.0];
        let orig = buf.clone();
        pre.apply_rows(&mut buf, 1);
        assert_eq!(buf, orig);
        assert!(pre.describe().contains("identity"));
    }

    #[test]
    fn damped_matches_direct_cholesky_solve() {
        let (n, k) = (30, 8);
        let g = gaussian(n, k, 1);
        let layout = BlockLayout::new(vec![k]);
        let fims = fit_fims_mem(&g, n, &layout);
        let pre = PrecondSpec::Damped { lambda: 0.1 }
            .build(&fims, &layout)
            .unwrap();
        let f = CholeskyFactor::factor_damped(&fims[0], k, 0.1).unwrap();
        let mut rows = gaussian(3, k, 2);
        let orig = rows.clone();
        pre.apply_rows(&mut rows, 3);
        for i in 0..3 {
            let want = f.solve_f32(&orig[i * k..(i + 1) * k]);
            for j in 0..k {
                assert!((rows[i * k + j] - want[j]).abs() < 1e-6, "({i},{j})");
            }
        }
    }

    #[test]
    fn blockwise_blocks_solve_independently() {
        let (n, k) = (24, 10);
        let g = gaussian(n, k, 3);
        let layout = BlockLayout::new(vec![4, 6]);
        let fims = fit_fims_mem(&g, n, &layout);
        assert_eq!(fims[0].len(), 16);
        assert_eq!(fims[1].len(), 36);
        let pre = PrecondSpec::Blockwise { lambda: 0.2 }
            .build(&fims, &layout)
            .unwrap();
        // Zeroing block 2 of the input leaves block 1 of the output
        // unchanged (block-diagonal solves are independent).
        let mut a = gaussian(2, k, 4);
        let mut b = a.clone();
        for row in b.chunks_mut(k) {
            for v in &mut row[4..] {
                *v = 0.0;
            }
        }
        pre.apply_rows(&mut a, 2);
        pre.apply_rows(&mut b, 2);
        for i in 0..2 {
            for j in 0..4 {
                assert!((a[i * k + j] - b[i * k + j]).abs() < 1e-6, "({i},{j})");
            }
        }
        assert!(pre.describe().contains("blockwise"));
    }

    #[test]
    fn eig_full_rank_matches_damped_cholesky() {
        let (n, k) = (40, 12);
        let g = gaussian(n, k, 5);
        let layout = BlockLayout::new(vec![k]);
        let fims = fit_fims_mem(&g, n, &layout);
        let damped = PrecondSpec::Damped { lambda: 0.05 }
            .build(&fims, &layout)
            .unwrap();
        let eig = PrecondSpec::Eig {
            rank: k,
            lambda: 0.05,
        }
        .build(&fims, &layout)
        .unwrap();
        let rows = gaussian(5, k, 6);
        let mut a = rows.clone();
        let mut b = rows;
        damped.apply_rows(&mut a, 5);
        eig.apply_rows(&mut b, 5);
        for i in 0..5 * k {
            assert!(
                (a[i] - b[i]).abs() <= 1e-4 * (1.0 + a[i].abs()),
                "at {i}: damped {} vs eig {}",
                a[i],
                b[i]
            );
        }
    }

    #[test]
    fn eig_truncation_exact_on_low_rank_fim() {
        // A rank-1 FIM: the rank-1 eig inverse is *exact*, not approximate.
        let k = 6;
        let u: Vec<f32> = (0..k).map(|i| (i as f32 + 1.0) * 0.3).collect();
        let mut fim = vec![0.0f32; k * k];
        for i in 0..k {
            for j in 0..k {
                fim[i * k + j] = u[i] * u[j];
            }
        }
        let layout = BlockLayout::new(vec![k]);
        let damped = PrecondSpec::Damped { lambda: 0.5 }
            .build(&[fim.clone()], &layout)
            .unwrap();
        let eig1 = PrecondSpec::Eig {
            rank: 1,
            lambda: 0.5,
        }
        .build(&[fim], &layout)
        .unwrap();
        let rows = gaussian(4, k, 7);
        let mut a = rows.clone();
        let mut b = rows;
        damped.apply_rows(&mut a, 4);
        eig1.apply_rows(&mut b, 4);
        for i in 0..4 * k {
            assert!(
                (a[i] - b[i]).abs() <= 1e-4 * (1.0 + a[i].abs()),
                "at {i}: {} vs {}",
                a[i],
                b[i]
            );
        }
    }

    #[test]
    fn eig_requires_positive_damping_and_monolithic_layout() {
        let k = 4;
        let fim = vec![0.0f32; k * k];
        assert!(EigPrecond::fit(&fim, k, 2, 0.0).is_err());
        let layout = BlockLayout::new(vec![2, 2]);
        let err = PrecondSpec::Eig {
            rank: 2,
            lambda: 0.1,
        }
        .build(&[vec![0.0; 4], vec![0.0; 4]], &layout);
        assert!(err.is_err());
    }

    #[test]
    fn artifact_roundtrips_and_validates() {
        let dir = std::env::temp_dir().join(format!("grass_precond_art_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let art = PrecondArtifact {
            method: "rm:k=4".into(),
            seed: 7,
            k: 4,
            layout: vec![2, 2],
            rows: 99,
            fims: vec![vec![1.0, 0.5, 0.5, 2.0], vec![3.0, 0.0, 0.0, 4.0]],
        };
        let path = art.save(&dir).unwrap();
        assert!(path.ends_with(PRECOND_FILE));
        let back = PrecondArtifact::load(&dir).unwrap();
        assert_eq!(back.method, art.method);
        assert_eq!(back.seed, 7);
        assert_eq!(back.k, 4);
        assert_eq!(back.layout, vec![2, 2]);
        assert_eq!(back.rows, 99);
        assert_eq!(back.fims, art.fims);

        // Validation rejects every provenance mismatch descriptively.
        let meta = |method: &str, seed, k, n| StoreMeta {
            k,
            n,
            shard_rows: 8,
            method: method.into(),
            seed,
            model: String::new(),
            input_dim: 0,
            layer_dims: vec![],
            density: 1.0,
            dtype: crate::store::PayloadDtype::F32,
        };
        assert!(back.validate_store(&meta("rm:k=4", 7, 4, 99)).is_ok());
        let e = format!("{:#}", back.validate_store(&meta("sjlt:k=4,s=1", 7, 4, 99)).unwrap_err());
        assert!(e.contains("rm:k=4") && e.contains("sjlt:k=4,s=1"), "{e}");
        let e = format!("{:#}", back.validate_store(&meta("rm:k=4", 8, 4, 99)).unwrap_err());
        assert!(e.contains('7') && e.contains('8'), "{e}");
        let e = format!("{:#}", back.validate_store(&meta("rm:k=4", 7, 5, 99)).unwrap_err());
        assert!(e.contains("k = 4") && e.contains("k = 5"), "{e}");
        let e = format!("{:#}", back.validate_store(&meta("rm:k=4", 7, 4, 100)).unwrap_err());
        assert!(e.contains("99") && e.contains("100"), "{e}");
        assert!(back.validate_layout(&BlockLayout::new(vec![2, 2])).is_ok());
        assert!(back.validate_layout(&BlockLayout::new(vec![4])).is_err());

        // A non-artifact file is rejected on the magic.
        std::fs::write(PrecondArtifact::path(&dir), b"not an artifact").unwrap();
        assert!(PrecondArtifact::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn grid_search_records_every_lambda_and_picks_best() {
        let (n, k) = (30, 6);
        let g = gaussian(n, k, 11);
        let layout = BlockLayout::new(vec![k]);
        let fims = fit_fims_mem(&g, n, &layout);
        // Toy eval that peaks at λ = 1e-3 (the grid visits λ in order and
        // this FIM is PD at every grid damping, so the counter tracks λ).
        let mut idx = 0usize;
        let report = select::grid_search(
            &PrecondSpec::Damped { lambda: 1.0 },
            &fims,
            &layout,
            |_pre| {
                let lam = select::DAMPING_GRID[idx];
                idx += 1;
                Some(-(lam.log10() + 3.0).abs())
            },
        )
        .unwrap();
        assert_eq!(report.entries.len(), select::DAMPING_GRID.len());
        assert!((report.best_lambda - 1e-3).abs() < 1e-12);
        // Identity has nothing to select.
        assert!(select::grid_search(&PrecondSpec::Identity, &fims, &layout, |_| Some(0.0)).is_err());
    }

    #[test]
    fn class_proxy_losses_track_subset_class_mass() {
        let subsets = vec![vec![0, 4, 8], vec![1, 2, 3]]; // 3 vs 0 class-0 rows (4 classes)
        let losses = select::class_proxy_losses(&subsets, 4, &[0], 1);
        assert!(losses[0] < losses[1], "{losses:?}");
    }
}
