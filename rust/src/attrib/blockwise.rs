//! Layer-wise block-diagonal FIM influence (§3.3.2): the FIM is
//! approximated as `diag{F_1, …, F_L}` over per-layer compressed gradients,
//! so iFVP decomposes into `L` independent small solves and the score is a
//! sum of per-layer inner products. This is the attribution backbone for
//! the GPT-2/WikiText (Table 1d) and Llama (Table 2) experiments. The
//! per-layer solver family itself lives in [`super::precond`]
//! ([`PrecondSpec::Blockwise`]); this engine binds it to a layer layout.

use super::precond::{apply_rows_parallel, PrecondSpec, PrecondStats};
use super::stream::{DualCache, StreamOpts};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{ensure, Result};

/// Layout of concatenated per-layer compressed gradients.
#[derive(Debug, Clone)]
pub struct BlockLayout {
    /// Per-layer compressed dims `k_l`.
    pub dims: Vec<usize>,
    /// Prefix offsets into the concatenated vector (len = L + 1).
    pub offsets: Vec<usize>,
}

impl BlockLayout {
    pub fn new(dims: Vec<usize>) -> Self {
        let mut offsets = Vec::with_capacity(dims.len() + 1);
        let mut acc = 0;
        offsets.push(0);
        for &d in &dims {
            acc += d;
            offsets.push(acc);
        }
        Self { dims, offsets }
    }

    pub fn total(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn slice<'a>(&self, v: &'a [f32], l: usize) -> &'a [f32] {
        &v[self.offsets[l]..self.offsets[l + 1]]
    }
}

/// Block-diagonal influence engine over concatenated per-layer vectors.
pub struct BlockwiseEngine {
    pub layout: BlockLayout,
    /// Damping λ of the default per-block Cholesky (kept for the
    /// pre-refactor constructor signature).
    pub damping: f64,
    precond: PrecondSpec,
    cached: DualCache,
}

impl BlockwiseEngine {
    pub fn new(layout: BlockLayout, damping: f64) -> Self {
        Self::with_precond(layout, PrecondSpec::Blockwise { lambda: damping })
    }

    /// Build with an explicit preconditioner spec over this layout.
    pub fn with_precond(layout: BlockLayout, precond: PrecondSpec) -> Self {
        Self {
            damping: precond.lambda().unwrap_or(PrecondSpec::DEFAULT_LAMBDA),
            layout,
            precond,
            cached: DualCache::Empty,
        }
    }

    /// Precondition each layer block independently: for each `l`,
    /// `g̃[l] = (F_l + λI)⁻¹ g[l]` with `F_l` accumulated over the cache.
    pub fn precondition(&self, grads: &[f32], n: usize) -> Result<Vec<f32>> {
        let total = self.layout.total();
        ensure!(grads.len() == n * total, "precondition: matrix is not n × k");
        let pre = self.precond.fit_mem(grads, n, &self.layout)?;
        let mut out = grads.to_vec();
        apply_rows_parallel(pre.as_ref(), &mut out, n);
        Ok(out)
    }

    /// `scores[q][i] = Σ_l ⟨q[l], g̃[l]⟩` — after preconditioning this is a
    /// plain full-vector dot product.
    pub fn scores(&self, preconditioned: &[f32], n: usize, queries: &[f32], m: usize) -> Vec<f32> {
        super::graddot::graddot_scores(preconditioned, n, self.layout.total(), queries, m)
    }

    pub fn attribute(
        &self,
        grads: &[f32],
        n: usize,
        queries: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let pre = self.precondition(grads, n)?;
        Ok(self.scores(&pre, n, queries, m))
    }
}

impl Attributor for BlockwiseEngine {
    fn name(&self) -> &'static str {
        "blockwise"
    }

    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        self.cached = DualCache::ingest_mem(grads, n, &self.layout, &self.precond)?;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        self.cached =
            DualCache::ingest_stream(reader, opts, self.layout.clone(), &self.precond)?;
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        ensure!(
            self.cached.is_cached(),
            "blockwise engine has no cached train set; call cache() first"
        );
        Ok(ScoreMatrix::new(
            self.cached.scores(queries, m, self.layout.total())?,
            m,
            self.cached.out_cols(),
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        ensure!(
            self.cached.is_cached(),
            "blockwise engine has no cached train set; call cache() first"
        );
        Ok(self.cached.self_inf()?.to_vec())
    }

    fn precond_stats(&self) -> PrecondStats {
        PrecondStats {
            fim_rows: self.cached.fim_rows(),
            describe: self
                .cached
                .describe()
                .unwrap_or_else(|| self.precond.spec_string()),
        }
    }

    fn coverage(&self) -> Option<super::Coverage> {
        self.cached.coverage()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attrib::influence::InfluenceEngine;
    use crate::sketch::rng::Pcg;

    #[test]
    fn layout_offsets() {
        let l = BlockLayout::new(vec![4, 6, 2]);
        assert_eq!(l.total(), 12);
        assert_eq!(l.offsets, vec![0, 4, 10, 12]);
        let v: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(l.slice(&v, 1), &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn single_block_equals_monolithic() {
        let (n, m, k) = (14, 3, 6);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let block = BlockwiseEngine::new(BlockLayout::new(vec![k]), 0.05)
            .attribute(&g, n, &q, m)
            .unwrap();
        let mono = InfluenceEngine::new(k, 0.05).attribute(&g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((block[i] - mono[i]).abs() < 1e-4, "mismatch at {i}");
        }
    }

    #[test]
    fn independent_blocks_are_independent() {
        // If queries are zero on block 2, block 2 contributes nothing.
        let (n, m) = (10, 2);
        let layout = BlockLayout::new(vec![3, 4]);
        let total = layout.total();
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..n * total).map(|_| rng.next_gaussian()).collect();
        let mut q: Vec<f32> = (0..m * total).map(|_| rng.next_gaussian()).collect();
        for qi in 0..m {
            for j in 3..7 {
                q[qi * total + j] = 0.0;
            }
        }
        let engine = BlockwiseEngine::new(layout.clone(), 0.1);
        let full = engine.attribute(&g, n, &q, m).unwrap();
        // zero out block-2 train grads; scores must be unchanged
        let mut g2 = g.clone();
        for i in 0..n {
            for j in 3..7 {
                g2[i * total + j] = 0.0;
            }
        }
        let masked = engine.attribute(&g2, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((full[i] - masked[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn self_influence_positive() {
        let n = 12;
        let layout = BlockLayout::new(vec![4, 4]);
        let total = layout.total();
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..n * total).map(|_| rng.next_gaussian()).collect();
        let engine = BlockwiseEngine::new(layout, 0.1);
        let scores = engine.attribute(&g, n, &g, n).unwrap();
        for i in 0..n {
            assert!(scores[i * n + i] > 0.0);
        }
    }

    #[test]
    fn stats_name_the_blockwise_solver() {
        let n = 10;
        let layout = BlockLayout::new(vec![3, 5]);
        let total = layout.total();
        let mut rng = Pcg::new(4);
        let g: Vec<f32> = (0..n * total).map(|_| rng.next_gaussian()).collect();
        let mut engine = BlockwiseEngine::new(layout, 0.1);
        Attributor::cache(&mut engine, &g, n).unwrap();
        let stats = Attributor::precond_stats(&engine);
        assert_eq!(stats.fim_rows, n);
        assert!(stats.describe.contains("blockwise"), "{}", stats.describe);
    }
}
