//! Layer-wise block-diagonal FIM influence (§3.3.2): the FIM is
//! approximated as `diag{F_1, …, F_L}` over per-layer compressed gradients,
//! so iFVP decomposes into `L` independent small solves and the score is a
//! sum of per-layer inner products. This is the attribution backbone for
//! the GPT-2/WikiText (Table 1d) and Llama (Table 2) experiments.

use super::fim::{accumulate_fim, Preconditioner};
use super::stream::{StreamOpts, StreamedCache};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// Layout of concatenated per-layer compressed gradients.
#[derive(Debug, Clone)]
pub struct BlockLayout {
    /// Per-layer compressed dims `k_l`.
    pub dims: Vec<usize>,
    /// Prefix offsets into the concatenated vector (len = L + 1).
    pub offsets: Vec<usize>,
}

impl BlockLayout {
    pub fn new(dims: Vec<usize>) -> Self {
        let mut offsets = Vec::with_capacity(dims.len() + 1);
        let mut acc = 0;
        offsets.push(0);
        for &d in &dims {
            acc += d;
            offsets.push(acc);
        }
        Self { dims, offsets }
    }

    pub fn total(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn slice<'a>(&self, v: &'a [f32], l: usize) -> &'a [f32] {
        &v[self.offsets[l]..self.offsets[l + 1]]
    }
}

/// State installed by the [`Attributor::cache`] stage: the preconditioned
/// matrix plus the eagerly computed self-influence diagonal (the raw
/// gradients are not retained — see `influence::CachedTrainSet`).
struct CachedBlocks {
    pre: Vec<f32>,
    self_inf: Vec<f32>,
    n: usize,
}

/// Dual-mode cache: resident preconditioned blocks, or the streamed state
/// (per-block preconditioners; rows re-stream at attribute time).
enum BwCache {
    Mem(CachedBlocks),
    Streamed(StreamedCache),
}

/// Block-diagonal influence engine over concatenated per-layer vectors.
pub struct BlockwiseEngine {
    pub layout: BlockLayout,
    pub damping: f64,
    cached: Option<BwCache>,
}

impl BlockwiseEngine {
    pub fn new(layout: BlockLayout, damping: f64) -> Self {
        Self {
            layout,
            damping,
            cached: None,
        }
    }

    /// Precondition each layer block independently: for each `l`,
    /// `g̃[l] = (F_l + λI)⁻¹ g[l]` with `F_l` accumulated over the cache.
    pub fn precondition(&self, grads: &[f32], n: usize) -> Result<Vec<f32>> {
        let total = self.layout.total();
        assert_eq!(grads.len(), n * total);
        let mut out = grads.to_vec();
        for (l, &kl) in self.layout.dims.iter().enumerate() {
            let off = self.layout.offsets[l];
            // gather the layer column block
            let mut block = vec![0.0f32; n * kl];
            for i in 0..n {
                block[i * kl..(i + 1) * kl]
                    .copy_from_slice(&grads[i * total + off..i * total + off + kl]);
            }
            let fim = accumulate_fim(&block, n, kl);
            let pre = Preconditioner::new(&fim, kl, self.damping)?;
            pre.apply_all(&mut block, n);
            for i in 0..n {
                out[i * total + off..i * total + off + kl]
                    .copy_from_slice(&block[i * kl..(i + 1) * kl]);
            }
        }
        Ok(out)
    }

    /// `scores[q][i] = Σ_l ⟨q[l], g̃[l]⟩` — after preconditioning this is a
    /// plain full-vector dot product.
    pub fn scores(&self, preconditioned: &[f32], n: usize, queries: &[f32], m: usize) -> Vec<f32> {
        super::graddot::graddot_scores(preconditioned, n, self.layout.total(), queries, m)
    }

    pub fn attribute(
        &self,
        grads: &[f32],
        n: usize,
        queries: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let pre = self.precondition(grads, n)?;
        Ok(self.scores(&pre, n, queries, m))
    }
}

impl Attributor for BlockwiseEngine {
    fn name(&self) -> &'static str {
        "blockwise"
    }

    fn dim(&self) -> usize {
        self.layout.total()
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        let pre = self.precondition(grads, n)?;
        let self_inf = super::influence::rowwise_dot(grads, &pre, n, self.layout.total());
        self.cached = Some(BwCache::Mem(CachedBlocks { pre, self_inf, n }));
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        let sc = StreamedCache::build(reader, opts, self.layout.clone(), Some(self.damping))?;
        self.cached = Some(BwCache::Streamed(sc));
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        let Some(c) = &self.cached else {
            bail!("blockwise engine has no cached train set; call cache() first")
        };
        match c {
            BwCache::Mem(c) => Ok(ScoreMatrix::new(
                self.scores(&c.pre, c.n, queries, m),
                m,
                c.n,
            )),
            BwCache::Streamed(sc) => Ok(ScoreMatrix::new(
                sc.scores(queries, m)?,
                m,
                sc.out_cols(),
            )),
        }
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        let Some(c) = &self.cached else {
            bail!("blockwise engine has no cached train set; call cache() first")
        };
        Ok(match c {
            BwCache::Mem(c) => c.self_inf.clone(),
            BwCache::Streamed(sc) => sc.self_inf().to_vec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attrib::influence::InfluenceEngine;
    use crate::sketch::rng::Pcg;

    #[test]
    fn layout_offsets() {
        let l = BlockLayout::new(vec![4, 6, 2]);
        assert_eq!(l.total(), 12);
        assert_eq!(l.offsets, vec![0, 4, 10, 12]);
        let v: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(l.slice(&v, 1), &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn single_block_equals_monolithic() {
        let (n, m, k) = (14, 3, 6);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let block = BlockwiseEngine::new(BlockLayout::new(vec![k]), 0.05)
            .attribute(&g, n, &q, m)
            .unwrap();
        let mono = InfluenceEngine::new(k, 0.05).attribute(&g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((block[i] - mono[i]).abs() < 1e-4, "mismatch at {i}");
        }
    }

    #[test]
    fn independent_blocks_are_independent() {
        // If queries are zero on block 2, block 2 contributes nothing.
        let (n, m) = (10, 2);
        let layout = BlockLayout::new(vec![3, 4]);
        let total = layout.total();
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..n * total).map(|_| rng.next_gaussian()).collect();
        let mut q: Vec<f32> = (0..m * total).map(|_| rng.next_gaussian()).collect();
        for qi in 0..m {
            for j in 3..7 {
                q[qi * total + j] = 0.0;
            }
        }
        let engine = BlockwiseEngine::new(layout.clone(), 0.1);
        let full = engine.attribute(&g, n, &q, m).unwrap();
        // zero out block-2 train grads; scores must be unchanged
        let mut g2 = g.clone();
        for i in 0..n {
            for j in 3..7 {
                g2[i * total + j] = 0.0;
            }
        }
        let masked = engine.attribute(&g2, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((full[i] - masked[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn self_influence_positive() {
        let n = 12;
        let layout = BlockLayout::new(vec![4, 4]);
        let total = layout.total();
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..n * total).map(|_| rng.next_gaussian()).collect();
        let engine = BlockwiseEngine::new(layout, 0.1);
        let scores = engine.attribute(&g, n, &g, n).unwrap();
        for i in 0..n {
            assert!(scores[i * n + i] > 0.0);
        }
    }
}
