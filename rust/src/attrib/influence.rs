//! The monolithic-FIM influence engine: cache + attribute over a compressed
//! gradient matrix. The second-order solve is pluggable (any
//! [`PrecondSpec`]); the paper's damping grid search (App. B.2) lives in
//! [`super::precond::select`].

use super::blockwise::BlockLayout;
use super::precond::{apply_rows_parallel, PrecondSpec, PrecondStats};
use super::stream::{DualCache, StreamOpts};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{ensure, Result};

/// Candidate damping grid from the paper (re-exported from
/// [`super::precond::select`], the home of the grid search).
pub use super::precond::select::DAMPING_GRID;

/// Monolithic influence engine: `τ(z_i, z_q) = ⟨ĝ_q, P ĝ_i⟩` with
/// `P = (F̂ + λI)⁻¹` by default (any [`PrecondSpec`] via
/// [`InfluenceEngine::with_precond`]).
pub struct InfluenceEngine {
    pub k: usize,
    /// Damping λ of the default damped-Cholesky preconditioner (kept for
    /// the pre-refactor constructor signature; [`InfluenceEngine::precond`]
    /// is authoritative).
    pub damping: f64,
    precond: PrecondSpec,
    cached: DualCache,
}

impl InfluenceEngine {
    pub fn new(k: usize, damping: f64) -> Self {
        Self::with_precond(k, PrecondSpec::Damped { lambda: damping })
    }

    /// Build with an explicit preconditioner spec (identity, damped,
    /// eig-truncated, …). The engine is monolithic: blockwise specs act
    /// on one `[k]` block here — use
    /// [`super::blockwise::BlockwiseEngine`] for per-layer solves.
    pub fn with_precond(k: usize, precond: PrecondSpec) -> Self {
        Self {
            k,
            damping: precond.lambda().unwrap_or(PrecondSpec::DEFAULT_LAMBDA),
            precond,
            cached: DualCache::Empty,
        }
    }

    /// The engine's preconditioner spec.
    pub fn precond(&self) -> &PrecondSpec {
        &self.precond
    }

    fn layout(&self) -> BlockLayout {
        BlockLayout::new(vec![self.k])
    }

    /// Cache stage on an in-memory `n × k` compressed gradient matrix:
    /// fits the preconditioner and returns the preconditioned matrix
    /// (the `g̃̂_i`).
    pub fn precondition(&self, grads: &[f32], n: usize) -> Result<Vec<f32>> {
        ensure!(grads.len() == n * self.k, "precondition: matrix is not n × k");
        let pre = self.precond.fit_mem(grads, n, &self.layout())?;
        let mut out = grads.to_vec();
        apply_rows_parallel(pre.as_ref(), &mut out, n);
        Ok(out)
    }

    /// Attribute stage: `scores[q][i] = ⟨ĝ_q, g̃̂_i⟩` for an `m × k` query
    /// matrix against the preconditioned `n × k` cache. Returns `m × n`.
    /// Both matrices are row-major with shared inner dimension `k`, so this
    /// is one dense `Q · Gᵀ` — the same register-tiled parallel GEMM
    /// dispatch as [`super::graddot::graddot_scores`].
    pub fn scores(&self, preconditioned: &[f32], n: usize, queries: &[f32], m: usize) -> Vec<f32> {
        super::graddot::graddot_scores(preconditioned, n, self.k, queries, m)
    }

    /// Full pipeline: cache + attribute.
    pub fn attribute(
        &self,
        grads: &[f32],
        n: usize,
        queries: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let pre = self.precondition(grads, n)?;
        Ok(self.scores(&pre, n, queries, m))
    }
}

impl Attributor for InfluenceEngine {
    fn name(&self) -> &'static str {
        "if"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        self.cached = DualCache::ingest_mem(grads, n, &self.layout(), &self.precond)?;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        self.cached = DualCache::ingest_stream(reader, opts, self.layout(), &self.precond)?;
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        ensure!(
            self.cached.is_cached(),
            "influence engine has no cached train set; call cache() first"
        );
        Ok(ScoreMatrix::new(
            self.cached.scores(queries, m, self.k)?,
            m,
            self.cached.out_cols(),
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        ensure!(
            self.cached.is_cached(),
            "influence engine has no cached train set; call cache() first"
        );
        Ok(self.cached.self_inf()?.to_vec())
    }

    fn precond_stats(&self) -> PrecondStats {
        PrecondStats {
            fim_rows: self.cached.fim_rows(),
            describe: self
                .cached
                .describe()
                .unwrap_or_else(|| self.precond.spec_string()),
        }
    }

    fn coverage(&self) -> Option<super::Coverage> {
        self.cached.coverage()
    }
}

/// Query-side scoring: `τ[q][i] = ((F̂+λI)⁻¹ ĝ_q)ᵀ ĝ_i`. Mathematically
/// identical to preconditioning the cache (the inverse is symmetric) but
/// costs O(m·k²) instead of O(n·k²) per damping value — the right shape for
/// damping grid searches where m ≪ n and F̂ is reused.
pub fn scores_query_side(
    fim: &[f32],
    k: usize,
    damping: f64,
    train: &[f32],
    n: usize,
    queries: &[f32],
    m: usize,
) -> Result<Vec<f32>> {
    let layout = BlockLayout::new(vec![k]);
    let pre = PrecondSpec::Damped { lambda: damping }.build(&[fim.to_vec()], &layout)?;
    let mut q = queries.to_vec();
    apply_rows_parallel(pre.as_ref(), &mut q, m);
    Ok(super::graddot::graddot_scores(train, n, k, &q, m))
}

/// Pick the damping maximising `eval(scores)` over [`DAMPING_GRID`]
/// (the paper cross-validates LDS on 10% of test; the caller provides the
/// evaluation closure — see [`super::precond::select`] for the LDS-backed
/// selection used by `--damping grid`). Returns (best_damping, best_value).
pub fn grid_search_damping(
    grads: &[f32],
    n: usize,
    k: usize,
    queries: &[f32],
    m: usize,
    mut eval: impl FnMut(&[f32]) -> f64,
) -> Result<(f64, f64)> {
    let mut best = (DAMPING_GRID[0], f64::NEG_INFINITY);
    for &damping in DAMPING_GRID {
        let engine = InfluenceEngine::new(k, damping);
        let scores = match engine.attribute(grads, n, queries, m) {
            Ok(s) => s,
            Err(_) => continue, // not PD at this damping
        };
        let v = eval(&scores);
        if v > best.1 {
            best = (damping, v);
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn self_influence_is_positive() {
        // τ(z_i, z_i) = g_iᵀ (F+λ)⁻¹ g_i > 0 since (F+λI)⁻¹ is PD.
        let (n, k) = (20, 8);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 0.1);
        let scores = engine.attribute(&g, n, &g, n).unwrap();
        for i in 0..n {
            assert!(scores[i * n + i] > 0.0, "self-influence {i} not positive");
        }
    }

    #[test]
    fn large_damping_recovers_graddot_direction() {
        // As λ → ∞, (F+λI)⁻¹ ≈ I/λ so scores ∝ GradDot.
        let (n, m, k) = (15, 3, 6);
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 1e6);
        let scores = engine.attribute(&g, n, &q, m).unwrap();
        for qi in 0..m {
            for i in 0..n {
                let dot: f32 = q[qi * k..(qi + 1) * k]
                    .iter()
                    .zip(&g[i * k..(i + 1) * k])
                    .map(|(a, b)| a * b)
                    .sum();
                let want = dot / 1e6;
                assert!(
                    (scores[qi * n + i] - want).abs() < 1e-8 + want.abs() * 1e-2,
                    "({qi},{i}): {} vs {}",
                    scores[qi * n + i],
                    want
                );
            }
        }
    }

    #[test]
    fn scores_shape_and_determinism() {
        let (n, m, k) = (10, 4, 5);
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 1e-2);
        let s1 = engine.attribute(&g, n, &q, m).unwrap();
        let s2 = engine.attribute(&g, n, &q, m).unwrap();
        assert_eq!(s1.len(), m * n);
        assert_eq!(s1, s2);
    }

    #[test]
    fn query_side_matches_cache_side() {
        let (n, m, k) = (18, 4, 6);
        let mut rng = Pcg::new(9);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 0.2);
        let cache_side = engine.attribute(&g, n, &q, m).unwrap();
        let fim = crate::attrib::fim::accumulate_fim(&g, n, k);
        let query_side = scores_query_side(&fim, k, 0.2, &g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!(
                (cache_side[i] - query_side[i]).abs()
                    < 1e-3 * (1.0 + cache_side[i].abs()),
                "mismatch at {i}: {} vs {}",
                cache_side[i],
                query_side[i]
            );
        }
    }

    #[test]
    fn eig_precond_full_rank_matches_damped_engine() {
        // The acceptance bound: `eig:k` scores equal `damped:λ` scores to
        // ≤ 1e-4 relative at full rank.
        let (n, m, k) = (30, 5, 10);
        let mut rng = Pcg::new(14);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let damped = InfluenceEngine::new(k, 0.05).attribute(&g, n, &q, m).unwrap();
        let eig = InfluenceEngine::with_precond(
            k,
            PrecondSpec::Eig {
                rank: k,
                lambda: 0.05,
            },
        )
        .attribute(&g, n, &q, m)
        .unwrap();
        for i in 0..m * n {
            assert!(
                (damped[i] - eig[i]).abs() <= 1e-4 * (1.0 + damped[i].abs()),
                "at {i}: damped {} vs eig {}",
                damped[i],
                eig[i]
            );
        }
    }

    #[test]
    fn precond_stats_report_fit_rows_and_solver() {
        let (n, k) = (12, 4);
        let mut rng = Pcg::new(15);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let mut engine = InfluenceEngine::new(k, 0.1);
        assert_eq!(Attributor::precond_stats(&engine).fim_rows, 0);
        Attributor::cache(&mut engine, &g, n).unwrap();
        let stats = Attributor::precond_stats(&engine);
        assert_eq!(stats.fim_rows, n);
        assert!(stats.describe.contains("damped-cholesky"), "{}", stats.describe);
    }

    #[test]
    fn grid_search_finds_informative_damping() {
        let (n, m, k) = (30, 5, 8);
        let mut rng = Pcg::new(4);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        // toy eval: prefer score matrices with moderate norm (pretend-LDS)
        let (lambda, val) = grid_search_damping(&g, n, k, &q, m, |s| {
            let norm: f64 = s.iter().map(|&x| (x as f64).powi(2)).sum();
            -(norm.ln() - 2.0).abs()
        })
        .unwrap();
        assert!(DAMPING_GRID.contains(&lambda));
        assert!(val.is_finite());
    }
}
