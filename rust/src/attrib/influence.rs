//! The monolithic-FIM influence engine: cache + attribute over a compressed
//! gradient matrix, with the paper's damping grid search (App. B.2).

use super::blockwise::BlockLayout;
use super::fim::{accumulate_fim, Preconditioner};
use super::stream::{StreamOpts, StreamedCache};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// Candidate damping grid from the paper:
/// λ ∈ {1e-7, …, 1e-1, 1, 10, 100} (App. B.2).
pub const DAMPING_GRID: &[f64] = &[
    1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0,
];

/// State installed by the [`Attributor::cache`] stage. Self-influence is
/// computed eagerly while the raw gradients are still in hand, so only the
/// preconditioned matrix is retained — at the store module's target scale
/// (n·k·4 bytes in the hundreds of GB) a second full copy is the
/// difference between fitting in memory and not.
struct CachedTrainSet {
    /// Preconditioned `n × k` matrix `g̃̂ = (F̂+λI)⁻¹ ĝ`.
    pre: Vec<f32>,
    /// `τ(z_i, z_i) = ⟨ĝ_i, g̃̂_i⟩` per cached sample.
    self_inf: Vec<f32>,
    n: usize,
}

/// Dual-mode cache: the in-memory preconditioned matrix, or the streamed
/// state (O(k²) preconditioner + O(n) self-influence, rows re-streamed
/// from the store at attribute time).
enum TrainCache {
    Mem(CachedTrainSet),
    Streamed(StreamedCache),
}

/// Row-wise `⟨raw_i, pre_i⟩` — the self-influence diagonal (shared with
/// the blockwise and TRAK engines).
pub(super) fn rowwise_dot(raw: &[f32], pre: &[f32], n: usize, k: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            raw[i * k..(i + 1) * k]
                .iter()
                .zip(&pre[i * k..(i + 1) * k])
                .map(|(a, b)| a * b)
                .sum()
        })
        .collect()
}

pub struct InfluenceEngine {
    pub k: usize,
    pub damping: f64,
    cached: Option<TrainCache>,
}

impl InfluenceEngine {
    pub fn new(k: usize, damping: f64) -> Self {
        Self {
            k,
            damping,
            cached: None,
        }
    }

    /// Cache stage on an in-memory `n × k` compressed gradient matrix:
    /// builds `F̂`, preconditions all rows. Returns the preconditioned
    /// matrix (the `g̃̂_i`).
    pub fn precondition(&self, grads: &[f32], n: usize) -> Result<Vec<f32>> {
        let fim = accumulate_fim(grads, n, self.k);
        let pre = Preconditioner::new(&fim, self.k, self.damping)?;
        let mut out = grads.to_vec();
        pre.apply_all(&mut out, n);
        Ok(out)
    }

    /// Attribute stage: `scores[q][i] = ⟨ĝ_q, g̃̂_i⟩` for an `m × k` query
    /// matrix against the preconditioned `n × k` cache. Returns `m × n`.
    /// Both matrices are row-major with shared inner dimension `k`, so this
    /// is one dense `Q · Gᵀ` — the same register-tiled parallel GEMM
    /// dispatch as [`super::graddot::graddot_scores`].
    pub fn scores(&self, preconditioned: &[f32], n: usize, queries: &[f32], m: usize) -> Vec<f32> {
        super::graddot::graddot_scores(preconditioned, n, self.k, queries, m)
    }

    /// Full pipeline: cache + attribute.
    pub fn attribute(
        &self,
        grads: &[f32],
        n: usize,
        queries: &[f32],
        m: usize,
    ) -> Result<Vec<f32>> {
        let pre = self.precondition(grads, n)?;
        Ok(self.scores(&pre, n, queries, m))
    }
}

impl Attributor for InfluenceEngine {
    fn name(&self) -> &'static str {
        "if"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        let pre = self.precondition(grads, n)?;
        let self_inf = rowwise_dot(grads, &pre, n, self.k);
        self.cached = Some(TrainCache::Mem(CachedTrainSet { pre, self_inf, n }));
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        let sc = StreamedCache::build(
            reader,
            opts,
            BlockLayout::new(vec![self.k]),
            Some(self.damping),
        )?;
        self.cached = Some(TrainCache::Streamed(sc));
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        let Some(c) = &self.cached else {
            bail!("influence engine has no cached train set; call cache() first")
        };
        match c {
            TrainCache::Mem(c) => Ok(ScoreMatrix::new(
                self.scores(&c.pre, c.n, queries, m),
                m,
                c.n,
            )),
            TrainCache::Streamed(sc) => Ok(ScoreMatrix::new(
                sc.scores(queries, m)?,
                m,
                sc.out_cols(),
            )),
        }
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        let Some(c) = &self.cached else {
            bail!("influence engine has no cached train set; call cache() first")
        };
        Ok(match c {
            TrainCache::Mem(c) => c.self_inf.clone(),
            TrainCache::Streamed(sc) => sc.self_inf().to_vec(),
        })
    }
}

/// Query-side scoring: `τ[q][i] = ((F̂+λI)⁻¹ ĝ_q)ᵀ ĝ_i`. Mathematically
/// identical to preconditioning the cache (the inverse is symmetric) but
/// costs O(m·k²) instead of O(n·k²) per damping value — the right shape for
/// damping grid searches where m ≪ n and F̂ is reused.
pub fn scores_query_side(
    fim: &[f32],
    k: usize,
    damping: f64,
    train: &[f32],
    n: usize,
    queries: &[f32],
    m: usize,
) -> Result<Vec<f32>> {
    let pre = Preconditioner::new(fim, k, damping)?;
    let mut q = queries.to_vec();
    pre.apply_all(&mut q, m);
    Ok(super::graddot::graddot_scores(train, n, k, &q, m))
}

/// Pick the damping maximising `eval(scores)` over [`DAMPING_GRID`]
/// (the paper cross-validates LDS on 10% of test; the caller provides the
/// evaluation closure). Returns (best_damping, best_value).
pub fn grid_search_damping(
    grads: &[f32],
    n: usize,
    k: usize,
    queries: &[f32],
    m: usize,
    mut eval: impl FnMut(&[f32]) -> f64,
) -> Result<(f64, f64)> {
    let mut best = (DAMPING_GRID[0], f64::NEG_INFINITY);
    for &damping in DAMPING_GRID {
        let engine = InfluenceEngine::new(k, damping);
        let scores = match engine.attribute(grads, n, queries, m) {
            Ok(s) => s,
            Err(_) => continue, // not PD at this damping
        };
        let v = eval(&scores);
        if v > best.1 {
            best = (damping, v);
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn self_influence_is_positive() {
        // τ(z_i, z_i) = g_iᵀ (F+λ)⁻¹ g_i > 0 since (F+λI)⁻¹ is PD.
        let (n, k) = (20, 8);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 0.1);
        let scores = engine.attribute(&g, n, &g, n).unwrap();
        for i in 0..n {
            assert!(scores[i * n + i] > 0.0, "self-influence {i} not positive");
        }
    }

    #[test]
    fn large_damping_recovers_graddot_direction() {
        // As λ → ∞, (F+λI)⁻¹ ≈ I/λ so scores ∝ GradDot.
        let (n, m, k) = (15, 3, 6);
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 1e6);
        let scores = engine.attribute(&g, n, &q, m).unwrap();
        for qi in 0..m {
            for i in 0..n {
                let dot: f32 = q[qi * k..(qi + 1) * k]
                    .iter()
                    .zip(&g[i * k..(i + 1) * k])
                    .map(|(a, b)| a * b)
                    .sum();
                let want = dot / 1e6;
                assert!(
                    (scores[qi * n + i] - want).abs() < 1e-8 + want.abs() * 1e-2,
                    "({qi},{i}): {} vs {}",
                    scores[qi * n + i],
                    want
                );
            }
        }
    }

    #[test]
    fn scores_shape_and_determinism() {
        let (n, m, k) = (10, 4, 5);
        let mut rng = Pcg::new(3);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 1e-2);
        let s1 = engine.attribute(&g, n, &q, m).unwrap();
        let s2 = engine.attribute(&g, n, &q, m).unwrap();
        assert_eq!(s1.len(), m * n);
        assert_eq!(s1, s2);
    }

    #[test]
    fn query_side_matches_cache_side() {
        let (n, m, k) = (18, 4, 6);
        let mut rng = Pcg::new(9);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let engine = InfluenceEngine::new(k, 0.2);
        let cache_side = engine.attribute(&g, n, &q, m).unwrap();
        let fim = crate::attrib::fim::accumulate_fim(&g, n, k);
        let query_side = scores_query_side(&fim, k, 0.2, &g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!(
                (cache_side[i] - query_side[i]).abs()
                    < 1e-3 * (1.0 + cache_side[i].abs()),
                "mismatch at {i}: {} vs {}",
                cache_side[i],
                query_side[i]
            );
        }
    }

    #[test]
    fn grid_search_finds_informative_damping() {
        let (n, m, k) = (30, 5, 8);
        let mut rng = Pcg::new(4);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        // toy eval: prefer score matrices with moderate norm (pretend-LDS)
        let (lambda, val) = grid_search_damping(&g, n, k, &q, m, |s| {
            let norm: f64 = s.iter().map(|&x| (x as f64).powi(2)).sum();
            -(norm.ln() - 2.0).abs()
        })
        .unwrap();
        assert!(DAMPING_GRID.contains(&lambda));
        assert!(val.is_finite());
    }
}
