//! TracIn (Pruthi et al. 2020) — a training-dynamics attributor the paper
//! lists among the gradient-based methods GraSS accelerates (§2, App A.1.1):
//! `τ(z_i, z_q) = Σ_c η_c ⟨g_i^{(c)}, g_q^{(c)}⟩` over training checkpoints
//! `c` with learning rates `η_c`. Because it is a sum of GradDots, it
//! composes with any [`crate::sketch::Compressor`] exactly like TRAK does —
//! compressed checkpoint gradients drop in unchanged.

use super::blockwise::BlockLayout;
use super::graddot::graddot_scores;
use super::stream::{StreamOpts, StreamedCache};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// One checkpoint's compressed gradients plus its learning rate.
pub struct TracinCheckpoint {
    /// `n × k` compressed train gradients at this checkpoint.
    pub train: Vec<f32>,
    /// `m × k` compressed query gradients at this checkpoint.
    pub queries: Vec<f32>,
    /// Learning rate in effect at this checkpoint.
    pub lr: f32,
}

/// TracInCP over compressed gradients: returns `m × n` scores.
pub fn tracin_scores(
    checkpoints: &[TracinCheckpoint],
    n: usize,
    m: usize,
    k: usize,
) -> Vec<f32> {
    assert!(!checkpoints.is_empty());
    let mut total = vec![0.0f64; m * n];
    for ck in checkpoints {
        assert_eq!(ck.train.len(), n * k);
        assert_eq!(ck.queries.len(), m * k);
        let s = graddot_scores(&ck.train, n, k, &ck.queries, m);
        for (t, &v) in total.iter_mut().zip(&s) {
            *t += (ck.lr * v) as f64;
        }
    }
    total.into_iter().map(|v| v as f32).collect()
}

/// One TracIn checkpoint's gradients: resident, or streamed from a store.
enum TracinCk {
    Mem(Vec<f32>),
    Streamed(StreamedCache),
}

/// TracIn as a stateful [`Attributor`]: every [`Attributor::cache`] /
/// [`Attributor::cache_stream`] call adds one checkpoint's compressed
/// train gradients, consuming the next learning rate from the schedule
/// (1.0 once the schedule is exhausted), and [`Attributor::attribute`]
/// sums the lr-weighted GradDots.
pub struct TracIn {
    k: usize,
    /// Learning-rate schedule consumed checkpoint-by-checkpoint.
    lrs: Vec<f32>,
    checkpoints: Vec<(TracinCk, f32)>,
    n: usize,
}

impl TracIn {
    /// Uniform unit learning rates — a plain sum of checkpoint GradDots.
    pub fn new(k: usize) -> Self {
        Self::with_lrs(k, vec![])
    }

    /// Explicit learning-rate schedule (`lrs[c]` weights the c-th cached
    /// checkpoint; missing entries default to 1.0).
    pub fn with_lrs(k: usize, lrs: Vec<f32>) -> Self {
        Self {
            k,
            lrs,
            checkpoints: vec![],
            n: 0,
        }
    }
}

impl Attributor for TracIn {
    fn name(&self) -> &'static str {
        "tracin"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        if !self.checkpoints.is_empty() && n != self.n {
            bail!(
                "tracin checkpoint has n = {n} train rows, previous checkpoints had {}",
                self.n
            );
        }
        if grads.len() != n * self.k {
            bail!("tracin cache: got {} values for n = {n}, k = {}", grads.len(), self.k);
        }
        let lr = self.lrs.get(self.checkpoints.len()).copied().unwrap_or(1.0);
        self.checkpoints.push((TracinCk::Mem(grads.to_vec()), lr));
        self.n = n;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        // GradDot family: no preconditioning, raw rows score directly.
        let sc = StreamedCache::build(reader, opts, BlockLayout::new(vec![self.k]), None)?;
        if !self.checkpoints.is_empty() && sc.out_cols() != self.n {
            bail!(
                "tracin checkpoint has n = {} train rows, previous checkpoints had {}",
                sc.out_cols(),
                self.n
            );
        }
        let lr = self.lrs.get(self.checkpoints.len()).copied().unwrap_or(1.0);
        self.n = sc.out_cols();
        self.checkpoints.push((TracinCk::Streamed(sc), lr));
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        if self.checkpoints.is_empty() {
            bail!("tracin scorer has no cached checkpoints; call cache() first");
        }
        let n = self.n;
        let mut total = vec![0.0f64; m * n];
        for (ck, lr) in &self.checkpoints {
            let s = match ck {
                TracinCk::Mem(train) => graddot_scores(train, n, self.k, queries, m),
                TracinCk::Streamed(sc) => sc.scores(queries, m)?,
            };
            for (t, &v) in total.iter_mut().zip(&s) {
                *t += (*lr * v) as f64;
            }
        }
        Ok(ScoreMatrix::new(
            total.into_iter().map(|v| v as f32).collect(),
            m,
            n,
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        if self.checkpoints.is_empty() {
            bail!("tracin scorer has no cached checkpoints; call cache() first");
        }
        let k = self.k;
        Ok((0..self.n)
            .map(|i| {
                self.checkpoints
                    .iter()
                    .map(|(ck, lr)| {
                        lr * match ck {
                            TracinCk::Mem(train) => train[i * k..(i + 1) * k]
                                .iter()
                                .map(|v| v * v)
                                .sum::<f32>(),
                            TracinCk::Streamed(sc) => sc.self_inf()[i],
                        }
                    })
                    .sum()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn ck(n: usize, m: usize, k: usize, lr: f32, seed: u64) -> TracinCheckpoint {
        let mut rng = Pcg::new(seed);
        TracinCheckpoint {
            train: (0..n * k).map(|_| rng.next_gaussian()).collect(),
            queries: (0..m * k).map(|_| rng.next_gaussian()).collect(),
            lr,
        }
    }

    #[test]
    fn single_checkpoint_is_scaled_graddot() {
        let (n, m, k) = (6, 2, 4);
        let c = ck(n, m, k, 0.5, 1);
        let scores = tracin_scores(&[c], n, m, k);
        let c2 = ck(n, m, k, 0.5, 1);
        let plain = graddot_scores(&c2.train, n, k, &c2.queries, m);
        for i in 0..m * n {
            assert!((scores[i] - 0.5 * plain[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn sums_over_checkpoints_weighted_by_lr() {
        let (n, m, k) = (5, 1, 3);
        let c1 = ck(n, m, k, 1.0, 2);
        let c2 = ck(n, m, k, 0.1, 3);
        let both = tracin_scores(
            &[
                ck(n, m, k, 1.0, 2),
                ck(n, m, k, 0.1, 3),
            ],
            n,
            m,
            k,
        );
        let s1 = tracin_scores(&[c1], n, m, k);
        let s2 = tracin_scores(&[c2], n, m, k);
        for i in 0..m * n {
            assert!((both[i] - (s1[i] + s2[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_lr_checkpoint_contributes_nothing() {
        let (n, m, k) = (4, 1, 2);
        let a = tracin_scores(&[ck(n, m, k, 1.0, 5)], n, m, k);
        let b = tracin_scores(&[ck(n, m, k, 1.0, 5), ck(n, m, k, 0.0, 6)], n, m, k);
        for i in 0..m * n {
            assert!((a[i] - b[i]).abs() < 1e-6);
        }
    }
}
