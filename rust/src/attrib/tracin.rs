//! TracIn (Pruthi et al. 2020) — a training-dynamics attributor the paper
//! lists among the gradient-based methods GraSS accelerates (§2, App A.1.1):
//! `τ(z_i, z_q) = Σ_c η_c ⟨g_i^{(c)}, g_q^{(c)}⟩` over training checkpoints
//! `c` with learning rates `η_c`. Because it is a sum of GradDots, it
//! composes with any [`crate::sketch::Compressor`] exactly like TRAK does —
//! compressed checkpoint gradients drop in unchanged, and any
//! [`PrecondSpec`] turns each term into a preconditioned inner product.

use super::blockwise::BlockLayout;
use super::graddot::graddot_scores;
use super::precond::{PrecondSpec, PrecondStats};
use super::stream::{DualCache, StreamOpts};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// One checkpoint's compressed gradients plus its learning rate.
pub struct TracinCheckpoint {
    /// `n × k` compressed train gradients at this checkpoint.
    pub train: Vec<f32>,
    /// `m × k` compressed query gradients at this checkpoint.
    pub queries: Vec<f32>,
    /// Learning rate in effect at this checkpoint.
    pub lr: f32,
}

/// TracInCP over compressed gradients: returns `m × n` scores.
pub fn tracin_scores(
    checkpoints: &[TracinCheckpoint],
    n: usize,
    m: usize,
    k: usize,
) -> Vec<f32> {
    assert!(!checkpoints.is_empty());
    let mut total = vec![0.0f64; m * n];
    for ck in checkpoints {
        assert_eq!(ck.train.len(), n * k);
        assert_eq!(ck.queries.len(), m * k);
        let s = graddot_scores(&ck.train, n, k, &ck.queries, m);
        for (t, &v) in total.iter_mut().zip(&s) {
            *t += (ck.lr * v) as f64;
        }
    }
    total.into_iter().map(|v| v as f32).collect()
}

/// TracIn as a stateful [`Attributor`]: every [`Attributor::cache`] /
/// [`Attributor::cache_stream`] call adds one checkpoint's compressed
/// train gradients, consuming the next learning rate from the schedule
/// (1.0 once the schedule is exhausted), and [`Attributor::attribute`]
/// sums the lr-weighted GradDots.
pub struct TracIn {
    k: usize,
    precond: PrecondSpec,
    /// Learning-rate schedule consumed checkpoint-by-checkpoint.
    lrs: Vec<f32>,
    checkpoints: Vec<(DualCache, f32)>,
    n: usize,
}

impl TracIn {
    /// Uniform unit learning rates — a plain sum of checkpoint GradDots.
    pub fn new(k: usize) -> Self {
        Self::with_lrs(k, vec![])
    }

    /// Explicit learning-rate schedule (`lrs[c]` weights the c-th cached
    /// checkpoint; missing entries default to 1.0).
    pub fn with_lrs(k: usize, lrs: Vec<f32>) -> Self {
        Self::with_precond(k, lrs, PrecondSpec::Identity)
    }

    /// TracIn with an explicit per-checkpoint preconditioner spec.
    pub fn with_precond(k: usize, lrs: Vec<f32>, precond: PrecondSpec) -> Self {
        Self {
            k,
            precond,
            lrs,
            checkpoints: vec![],
            n: 0,
        }
    }

    fn layout(&self) -> BlockLayout {
        BlockLayout::new(vec![self.k])
    }

    fn check_rows(&self, n: usize) -> Result<()> {
        if !self.checkpoints.is_empty() && n != self.n {
            bail!(
                "tracin checkpoint has n = {n} train rows, previous checkpoints had {}",
                self.n
            );
        }
        Ok(())
    }

    fn next_lr(&self) -> f32 {
        self.lrs.get(self.checkpoints.len()).copied().unwrap_or(1.0)
    }
}

impl Attributor for TracIn {
    fn name(&self) -> &'static str {
        "tracin"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        self.check_rows(n)?;
        let ck = DualCache::ingest_mem(grads, n, &self.layout(), &self.precond)?;
        let lr = self.next_lr();
        self.checkpoints.push((ck, lr));
        self.n = n;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        let ck = DualCache::ingest_stream(reader, opts, self.layout(), &self.precond)?;
        self.check_rows(ck.out_cols())?;
        let lr = self.next_lr();
        self.n = ck.out_cols();
        self.checkpoints.push((ck, lr));
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        if self.checkpoints.is_empty() {
            bail!("tracin scorer has no cached checkpoints; call cache() first");
        }
        let n = self.n;
        let mut total = vec![0.0f64; m * n];
        for (ck, lr) in &self.checkpoints {
            let s = ck.scores(queries, m, self.k)?;
            for (t, &v) in total.iter_mut().zip(&s) {
                *t += (*lr * v) as f64;
            }
        }
        Ok(ScoreMatrix::new(
            total.into_iter().map(|v| v as f32).collect(),
            m,
            n,
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        if self.checkpoints.is_empty() {
            bail!("tracin scorer has no cached checkpoints; call cache() first");
        }
        let mut out = vec![0.0f64; self.n];
        for (ck, lr) in &self.checkpoints {
            for (o, &v) in out.iter_mut().zip(ck.self_inf()?) {
                *o += (*lr * v) as f64;
            }
        }
        Ok(out.into_iter().map(|v| v as f32).collect())
    }

    fn precond_stats(&self) -> PrecondStats {
        PrecondStats {
            fim_rows: self.checkpoints.iter().map(|(c, _)| c.fim_rows()).sum(),
            describe: self
                .checkpoints
                .first()
                .and_then(|(c, _)| c.describe())
                .unwrap_or_else(|| self.precond.spec_string()),
        }
    }

    fn coverage(&self) -> Option<super::Coverage> {
        let mut merged: Option<super::Coverage> = None;
        for (ck, _) in &self.checkpoints {
            if let Some(c) = ck.coverage() {
                match &mut merged {
                    Some(m) => m.merge(&c),
                    None => merged = Some(c),
                }
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn ck(n: usize, m: usize, k: usize, lr: f32, seed: u64) -> TracinCheckpoint {
        let mut rng = Pcg::new(seed);
        TracinCheckpoint {
            train: (0..n * k).map(|_| rng.next_gaussian()).collect(),
            queries: (0..m * k).map(|_| rng.next_gaussian()).collect(),
            lr,
        }
    }

    #[test]
    fn single_checkpoint_is_scaled_graddot() {
        let (n, m, k) = (6, 2, 4);
        let c = ck(n, m, k, 0.5, 1);
        let scores = tracin_scores(&[c], n, m, k);
        let c2 = ck(n, m, k, 0.5, 1);
        let plain = graddot_scores(&c2.train, n, k, &c2.queries, m);
        for i in 0..m * n {
            assert!((scores[i] - 0.5 * plain[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn sums_over_checkpoints_weighted_by_lr() {
        let (n, m, k) = (5, 1, 3);
        let c1 = ck(n, m, k, 1.0, 2);
        let c2 = ck(n, m, k, 0.1, 3);
        let both = tracin_scores(
            &[
                ck(n, m, k, 1.0, 2),
                ck(n, m, k, 0.1, 3),
            ],
            n,
            m,
            k,
        );
        let s1 = tracin_scores(&[c1], n, m, k);
        let s2 = tracin_scores(&[c2], n, m, k);
        for i in 0..m * n {
            assert!((both[i] - (s1[i] + s2[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_lr_checkpoint_contributes_nothing() {
        let (n, m, k) = (4, 1, 2);
        let a = tracin_scores(&[ck(n, m, k, 1.0, 5)], n, m, k);
        let b = tracin_scores(&[ck(n, m, k, 1.0, 5), ck(n, m, k, 0.0, 6)], n, m, k);
        for i in 0..m * n {
            assert!((a[i] - b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn stateful_self_influence_is_lr_weighted_norms() {
        let (n, k) = (5, 3);
        let c1 = ck(n, 1, k, 1.0, 7);
        let c2 = ck(n, 1, k, 0.5, 8);
        let mut t = TracIn::with_lrs(k, vec![1.0, 0.5]);
        Attributor::cache(&mut t, &c1.train, n).unwrap();
        Attributor::cache(&mut t, &c2.train, n).unwrap();
        let si = Attributor::self_influence(&t).unwrap();
        for i in 0..n {
            let n1: f32 = c1.train[i * k..(i + 1) * k].iter().map(|v| v * v).sum();
            let n2: f32 = c2.train[i * k..(i + 1) * k].iter().map(|v| v * v).sum();
            let want = n1 + 0.5 * n2;
            assert!((si[i] - want).abs() < 1e-4, "at {i}: {} vs {want}", si[i]);
        }
    }
}
