//! GradDot (Charpiat et al. 2019): `τ(z_i, z_q) = ⟨g_i, g_q⟩` — the cheap
//! surrogate the Selective Mask objective (Eq. 1) targets, and a baseline
//! attributor in its own right. As an [`Attributor`] it is the identity
//! point of the preconditioner family: the same
//! `preconditioner ∘ inner-product` composition every scorer uses, with
//! [`PrecondSpec::Identity`] plugged in.

use super::blockwise::BlockLayout;
use super::precond::{PrecondSpec, PrecondStats};
use super::stream::{DualCache, StreamOpts};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::linalg::matmul::matmul_abt;
use crate::store::{StoreMeta, StoreReader};
use anyhow::{ensure, Result};

/// `scores[q][i] = ⟨g_q, g_i⟩` over `n × k` train and `m × k` query
/// matrices; returns `m × n`. Both operands are row-major with shared inner
/// dimension `k`, so the whole score matrix is one `Q · Gᵀ` GEMM — the
/// register-tiled parallel kernel in [`crate::linalg::matmul`].
pub fn graddot_scores(grads: &[f32], n: usize, k: usize, queries: &[f32], m: usize) -> Vec<f32> {
    assert_eq!(grads.len(), n * k);
    assert_eq!(queries.len(), m * k);
    let mut scores = vec![0.0f32; m * n];
    matmul_abt(queries, grads, &mut scores, m, k, n);
    scores
}

/// The GradDot scorer as a stateful [`Attributor`]: `cache` keeps the
/// compressed train matrix (`cache_stream` keeps only the store handle),
/// `attribute` is one `Q · Gᵀ` GEMM — dense, or streamed block by block.
pub struct GradDot {
    k: usize,
    precond: PrecondSpec,
    cached: DualCache,
}

impl GradDot {
    pub fn new(k: usize) -> Self {
        Self::with_precond(k, PrecondSpec::Identity)
    }

    /// GradDot with a non-trivial preconditioner is simply a
    /// preconditioned inner-product scorer — exposed so `--precond`
    /// composes with every scorer uniformly.
    pub fn with_precond(k: usize, precond: PrecondSpec) -> Self {
        Self {
            k,
            precond,
            cached: DualCache::Empty,
        }
    }

    fn layout(&self) -> BlockLayout {
        BlockLayout::new(vec![self.k])
    }
}

impl Attributor for GradDot {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        self.cached = DualCache::ingest_mem(grads, n, &self.layout(), &self.precond)?;
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        self.cached = DualCache::ingest_stream(reader, opts, self.layout(), &self.precond)?;
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        ensure!(
            self.cached.is_cached(),
            "graddot scorer has no cached train set; call cache() first"
        );
        Ok(ScoreMatrix::new(
            self.cached.scores(queries, m, self.k)?,
            m,
            self.cached.out_cols(),
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        ensure!(
            self.cached.is_cached(),
            "graddot scorer has no cached train set; call cache() first"
        );
        Ok(self.cached.self_inf()?.to_vec())
    }

    fn precond_stats(&self) -> PrecondStats {
        PrecondStats {
            fim_rows: self.cached.fim_rows(),
            describe: self
                .cached
                .describe()
                .unwrap_or_else(|| self.precond.spec_string()),
        }
    }

    fn coverage(&self) -> Option<super::Coverage> {
        self.cached.coverage()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn matches_manual_dot() {
        let g = [1.0f32, 2.0, 3.0, 4.0]; // 2×2
        let q = [1.0f32, 1.0]; // 1×2
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![3.0, 7.0]);
    }

    #[test]
    fn orthogonal_gradients_score_zero() {
        let g = [1.0f32, 0.0, 0.0, 1.0];
        let q = [0.0f32, 1.0];
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![0.0, 1.0]);
    }

    #[test]
    fn gemm_path_matches_explicit_loop() {
        let (n, m, k) = (23, 6, 37);
        let mut rng = Pcg::new(11);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let s = graddot_scores(&g, n, k, &q, m);
        for qi in 0..m {
            for i in 0..n {
                let want: f32 = q[qi * k..(qi + 1) * k]
                    .iter()
                    .zip(&g[i * k..(i + 1) * k])
                    .map(|(a, b)| a * b)
                    .sum();
                assert!(
                    (s[qi * n + i] - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "({qi},{i}): {} vs {}",
                    s[qi * n + i],
                    want
                );
            }
        }
    }

    #[test]
    fn preconditioned_graddot_equals_influence() {
        // GradDot ∘ damped preconditioner is the influence composition —
        // the whole point of the shared DualCache.
        use crate::attrib::influence::InfluenceEngine;
        let (n, m, k) = (16, 3, 5);
        let mut rng = Pcg::new(12);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let mut gd = GradDot::with_precond(k, PrecondSpec::Damped { lambda: 0.2 });
        gd.cache(&g, n).unwrap();
        let s = Attributor::attribute(&gd, &q, m).unwrap();
        let want = InfluenceEngine::new(k, 0.2).attribute(&g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((s.scores[i] - want[i]).abs() < 1e-5, "at {i}");
        }
    }
}
