//! GradDot (Charpiat et al. 2019): `τ(z_i, z_q) = ⟨g_i, g_q⟩` — the cheap
//! surrogate the Selective Mask objective (Eq. 1) targets, and a baseline
//! attributor in its own right.

use crate::util::par;

/// `scores[q][i] = ⟨g_q, g_i⟩` over `n × k` train and `m × k` query
/// matrices; returns `m × n`.
pub fn graddot_scores(grads: &[f32], n: usize, k: usize, queries: &[f32], m: usize) -> Vec<f32> {
    assert_eq!(grads.len(), n * k);
    assert_eq!(queries.len(), m * k);
    let mut scores = vec![0.0f32; m * n];
    par::par_chunks_mut(&mut scores, n, 1, |q_start, chunk| {
        for (off, srow) in chunk.chunks_mut(n).enumerate() {
            let q = &queries[(q_start + off) * k..(q_start + off + 1) * k];
            for (i, s) in srow.iter_mut().enumerate() {
                let gi = &grads[i * k..(i + 1) * k];
                *s = q.iter().zip(gi).map(|(a, b)| a * b).sum();
            }
        }
    });
    scores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_manual_dot() {
        let g = [1.0f32, 2.0, 3.0, 4.0]; // 2×2
        let q = [1.0f32, 1.0]; // 1×2
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![3.0, 7.0]);
    }

    #[test]
    fn orthogonal_gradients_score_zero() {
        let g = [1.0f32, 0.0, 0.0, 1.0];
        let q = [0.0f32, 1.0];
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![0.0, 1.0]);
    }
}
