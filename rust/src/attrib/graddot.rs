//! GradDot (Charpiat et al. 2019): `τ(z_i, z_q) = ⟨g_i, g_q⟩` — the cheap
//! surrogate the Selective Mask objective (Eq. 1) targets, and a baseline
//! attributor in its own right.

use super::{Attributor, ScoreMatrix};
use crate::linalg::matmul::matmul_abt;
use anyhow::{bail, Result};

/// `scores[q][i] = ⟨g_q, g_i⟩` over `n × k` train and `m × k` query
/// matrices; returns `m × n`. Both operands are row-major with shared inner
/// dimension `k`, so the whole score matrix is one `Q · Gᵀ` GEMM — the
/// register-tiled parallel kernel in [`crate::linalg::matmul`].
pub fn graddot_scores(grads: &[f32], n: usize, k: usize, queries: &[f32], m: usize) -> Vec<f32> {
    assert_eq!(grads.len(), n * k);
    assert_eq!(queries.len(), m * k);
    let mut scores = vec![0.0f32; m * n];
    matmul_abt(queries, grads, &mut scores, m, k, n);
    scores
}

/// The GradDot scorer as a stateful [`Attributor`]: `cache` keeps the
/// compressed train matrix, `attribute` is one `Q · Gᵀ` GEMM.
pub struct GradDot {
    k: usize,
    train: Vec<f32>,
    n: usize,
}

impl GradDot {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            train: vec![],
            n: 0,
        }
    }
}

impl Attributor for GradDot {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        if grads.len() != n * self.k {
            bail!("graddot cache: got {} values for n = {n}, k = {}", grads.len(), self.k);
        }
        self.train = grads.to_vec();
        self.n = n;
        Ok(())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        if self.n == 0 {
            bail!("graddot scorer has no cached train set; call cache() first");
        }
        Ok(ScoreMatrix::new(
            graddot_scores(&self.train, self.n, self.k, queries, m),
            m,
            self.n,
        ))
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        if self.n == 0 {
            bail!("graddot scorer has no cached train set; call cache() first");
        }
        Ok(self
            .train
            .chunks(self.k)
            .map(|g| g.iter().map(|v| v * v).sum())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn matches_manual_dot() {
        let g = [1.0f32, 2.0, 3.0, 4.0]; // 2×2
        let q = [1.0f32, 1.0]; // 1×2
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![3.0, 7.0]);
    }

    #[test]
    fn orthogonal_gradients_score_zero() {
        let g = [1.0f32, 0.0, 0.0, 1.0];
        let q = [0.0f32, 1.0];
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![0.0, 1.0]);
    }

    #[test]
    fn gemm_path_matches_explicit_loop() {
        let (n, m, k) = (23, 6, 37);
        let mut rng = Pcg::new(11);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let s = graddot_scores(&g, n, k, &q, m);
        for qi in 0..m {
            for i in 0..n {
                let want: f32 = q[qi * k..(qi + 1) * k]
                    .iter()
                    .zip(&g[i * k..(i + 1) * k])
                    .map(|(a, b)| a * b)
                    .sum();
                assert!(
                    (s[qi * n + i] - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "({qi},{i}): {} vs {}",
                    s[qi * n + i],
                    want
                );
            }
        }
    }
}
