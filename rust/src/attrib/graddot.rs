//! GradDot (Charpiat et al. 2019): `τ(z_i, z_q) = ⟨g_i, g_q⟩` — the cheap
//! surrogate the Selective Mask objective (Eq. 1) targets, and a baseline
//! attributor in its own right.

use super::blockwise::BlockLayout;
use super::stream::{StreamOpts, StreamedCache};
use super::{check_store_width, Attributor, ScoreMatrix};
use crate::linalg::matmul::matmul_abt;
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, Result};

/// `scores[q][i] = ⟨g_q, g_i⟩` over `n × k` train and `m × k` query
/// matrices; returns `m × n`. Both operands are row-major with shared inner
/// dimension `k`, so the whole score matrix is one `Q · Gᵀ` GEMM — the
/// register-tiled parallel kernel in [`crate::linalg::matmul`].
pub fn graddot_scores(grads: &[f32], n: usize, k: usize, queries: &[f32], m: usize) -> Vec<f32> {
    assert_eq!(grads.len(), n * k);
    assert_eq!(queries.len(), m * k);
    let mut scores = vec![0.0f32; m * n];
    matmul_abt(queries, grads, &mut scores, m, k, n);
    scores
}

/// Dual-mode GradDot cache: the resident train matrix, or the streamed
/// state (store handle + self-influence diagonal; rows re-stream at
/// attribute time).
enum GdCache {
    Empty,
    Mem { train: Vec<f32>, n: usize },
    Streamed(StreamedCache),
}

/// The GradDot scorer as a stateful [`Attributor`]: `cache` keeps the
/// compressed train matrix (`cache_stream` keeps only the store handle),
/// `attribute` is one `Q · Gᵀ` GEMM — dense, or streamed block by block.
pub struct GradDot {
    k: usize,
    cached: GdCache,
}

impl GradDot {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            cached: GdCache::Empty,
        }
    }
}

impl Attributor for GradDot {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn dim(&self) -> usize {
        self.k
    }

    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()> {
        if grads.len() != n * self.k {
            bail!("graddot cache: got {} values for n = {n}, k = {}", grads.len(), self.k);
        }
        self.cached = GdCache::Mem {
            train: grads.to_vec(),
            n,
        };
        Ok(())
    }

    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        // No preconditioning (damping = None): raw rows score directly.
        let sc = StreamedCache::build(reader, opts, BlockLayout::new(vec![self.k]), None)?;
        self.cached = GdCache::Streamed(sc);
        Ok(reader.meta.clone())
    }

    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix> {
        match &self.cached {
            GdCache::Empty => {
                bail!("graddot scorer has no cached train set; call cache() first")
            }
            GdCache::Mem { train, n } => Ok(ScoreMatrix::new(
                graddot_scores(train, *n, self.k, queries, m),
                m,
                *n,
            )),
            GdCache::Streamed(sc) => Ok(ScoreMatrix::new(
                sc.scores(queries, m)?,
                m,
                sc.out_cols(),
            )),
        }
    }

    fn self_influence(&self) -> Result<Vec<f32>> {
        match &self.cached {
            GdCache::Empty => {
                bail!("graddot scorer has no cached train set; call cache() first")
            }
            GdCache::Mem { train, .. } => Ok(train
                .chunks(self.k)
                .map(|g| g.iter().map(|v| v * v).sum())
                .collect()),
            GdCache::Streamed(sc) => Ok(sc.self_inf().to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn matches_manual_dot() {
        let g = [1.0f32, 2.0, 3.0, 4.0]; // 2×2
        let q = [1.0f32, 1.0]; // 1×2
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![3.0, 7.0]);
    }

    #[test]
    fn orthogonal_gradients_score_zero() {
        let g = [1.0f32, 0.0, 0.0, 1.0];
        let q = [0.0f32, 1.0];
        let s = graddot_scores(&g, 2, 2, &q, 1);
        assert_eq!(s, vec![0.0, 1.0]);
    }

    #[test]
    fn gemm_path_matches_explicit_loop() {
        let (n, m, k) = (23, 6, 37);
        let mut rng = Pcg::new(11);
        let g: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
        let q: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
        let s = graddot_scores(&g, n, k, &q, m);
        for qi in 0..m {
            for i in 0..n {
                let want: f32 = q[qi * k..(qi + 1) * k]
                    .iter()
                    .zip(&g[i * k..(i + 1) * k])
                    .map(|(a, b)| a * b)
                    .sum();
                assert!(
                    (s[qi * n + i] - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "({qi},{i}): {} vs {}",
                    s[qi * n + i],
                    want
                );
            }
        }
    }
}
