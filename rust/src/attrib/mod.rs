//! Gradient-based data attribution on compressed gradients.
//!
//! The two-stage pipeline of §2.1:
//!   cache — per-sample gradients → compress → FIM `F̂ = Σ ĝ ĝᵀ/n` →
//!            precondition `g̃̂ = (F̂+λI)⁻¹ ĝ`;
//!   attribute — `τ(z_i, z_q) = ⟨ĝ_q, g̃̂_i⟩`.
//!
//! Every attribution engine implements the unified [`Attributor`] trait —
//! `cache` ingests an in-memory compressed train matrix, `cache_stream`
//! ingests a [`StoreReader`] out-of-core (shard-at-a-time accumulation
//! under a [`StreamOpts::mem_budget`] byte budget — see [`stream`]),
//! `attribute` scores compressed queries, and `self_influence` reports
//! `τ(z_i, z_i)`. [`from_spec`] is the registry: it dispatches an
//! [`AttributionSpec`]'s scorer string to the right engine, so the CLI,
//! coordinator, and experiment harnesses share one construction path.
//!
//! [`fim`] accumulates the compressed FIM; [`precond`] is the pluggable
//! second-order subsystem every scorer composes with — the
//! [`Preconditioner`] trait ([`precond::IdentityPrecond`], damped
//! Cholesky, eigen-truncated low-rank, per-layer blockwise), persisted
//! solver artifacts ([`PrecondArtifact`], `precond.bin`), and the paper's
//! damping grid search ([`precond::select`]). [`influence`] is the
//! monolithic-FIM engine (TRAK-style models); [`blockwise`] is the
//! layer-wise block-diagonal variant for LMs (§3.3.2); [`trak`] ensembles
//! checkpoints; [`tracin`] weights checkpoint GradDots by learning rate;
//! [`graddot`] is the cheap surrogate used by Selective Mask.

pub mod blockwise;
pub mod fim;
pub mod graddot;
pub mod influence;
pub mod precond;
pub mod stream;
pub mod tracin;
pub mod trak;

pub use influence::InfluenceEngine;
pub use precond::{PrecondArtifact, PrecondSpec, PrecondStats, Preconditioner};
pub use stream::{Coverage, StreamOpts, DEFAULT_MEM_BUDGET};

use crate::sketch::MethodSpec;
use crate::store::{StoreMeta, StoreReader};
use anyhow::{bail, ensure, Result};

/// An `m × n` (queries × train samples) attribution score matrix.
#[derive(Debug, Clone)]
pub struct ScoreMatrix {
    /// Row-major `m × n` scores.
    pub scores: Vec<f32>,
    /// Number of query rows.
    pub m: usize,
    /// Number of cached train samples.
    pub n: usize,
}

impl ScoreMatrix {
    pub fn new(scores: Vec<f32>, m: usize, n: usize) -> Self {
        debug_assert_eq!(scores.len(), m * n);
        Self { scores, m, n }
    }

    /// Scores of query `q` against every cached sample.
    pub fn row(&self, q: usize) -> &[f32] {
        &self.scores[q * self.n..(q + 1) * self.n]
    }

    /// The `top` most influential train indices for query `q`, best first.
    pub fn top_k(&self, q: usize, top: usize) -> Vec<(usize, f32)> {
        let row = self.row(q);
        let mut order: Vec<usize> = (0..self.n).collect();
        order.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal));
        order
            .into_iter()
            .take(top)
            .map(|i| (i, row[i]))
            .collect()
    }
}

/// Declarative description of one attribution task: which scorer runs on
/// gradients compressed by which method — the spec the registry
/// ([`from_spec`]), the `grass attribute` CLI, and the store validation all
/// consume.
#[derive(Debug, Clone)]
pub struct AttributionSpec {
    /// Scorer id: `"if"` (influence), `"graddot"`, `"trak"`, `"tracin"`,
    /// or `"blockwise"`.
    pub scorer: String,
    /// Gradient compression method (defines the projection and `k`).
    pub method: MethodSpec,
    /// Projection seed (must match the cache stage).
    pub seed: u64,
    /// FIM damping λ for the preconditioned scorers.
    pub damping: f64,
    /// Per-layer compressed dims for the blockwise scorer; empty means the
    /// monolithic layout `[total_dim]`.
    pub layout: Vec<usize>,
    /// Explicit preconditioner spec (`--precond`); `None` picks each
    /// scorer's default family
    /// ([`PrecondSpec::default_for_scorer`]) at `damping`.
    pub precond: Option<PrecondSpec>,
}

impl AttributionSpec {
    pub fn new(scorer: &str, method: MethodSpec, seed: u64) -> Self {
        Self {
            scorer: scorer.to_string(),
            method,
            seed,
            damping: 1e-3,
            layout: vec![],
            precond: None,
        }
    }

    /// Total compressed row width the scorer operates on: the blockwise
    /// layout sum when present, otherwise the method's nominal dim.
    pub fn total_dim(&self) -> usize {
        if self.layout.is_empty() {
            self.method.output_dim()
        } else {
            self.layout.iter().sum()
        }
    }
}

/// A unified attribution engine over compressed gradients (§2.1's
/// cache→attribute stages behind one object-safe interface).
///
/// The contract: call [`Attributor::cache`] / [`Attributor::cache_stream`]
/// (one or more times — ensemble scorers like TRAK/TracIn treat each call
/// as one checkpoint) and then [`Attributor::attribute`] /
/// [`Attributor::self_influence`] any number of times. All matrices are
/// row-major with the engine's fixed inner dimension [`Attributor::dim`].
///
/// Ingest is dual-mode: [`Attributor::cache`] holds the train matrix (or
/// its preconditioned image) in memory, while [`Attributor::cache_stream`]
/// accumulates only O(k²) Gram state plus the self-influence diagonal and
/// re-streams the store at attribute time under a byte budget — the two
/// produce identical scores.
///
/// # Examples
///
/// ```
/// use grass::attrib::{from_spec, AttributionSpec};
/// use grass::sketch::MethodSpec;
///
/// let spec = AttributionSpec::new("graddot", MethodSpec::RandomMask { k: 2 }, 0);
/// let mut scorer = from_spec(&spec).unwrap();
/// scorer.cache(&[1.0, 0.0, 0.0, 1.0], 2).unwrap(); // two train rows
/// let scores = scorer.attribute(&[1.0, 0.0], 1).unwrap(); // one query
/// assert_eq!(scores.row(0), &[1.0, 0.0]);
/// assert_eq!(scorer.self_influence().unwrap(), vec![1.0, 1.0]);
/// ```
pub trait Attributor: Send + Sync {
    /// Registry id of this scorer (`"if"`, `"graddot"`, …).
    fn name(&self) -> &'static str;

    /// Compressed row width `k` this engine expects.
    fn dim(&self) -> usize;

    /// Cache stage: ingest an `n × k` compressed train-gradient matrix and
    /// build whatever state scoring needs (FIM, preconditioned cache).
    fn cache(&mut self, grads: &[f32], n: usize) -> Result<()>;

    /// Cache stage streamed out-of-core from a finished gradient store:
    /// the engine folds shard-at-a-time row blocks into its Gram /
    /// precondition state under [`StreamOpts::mem_budget`], retains a
    /// handle to the store, and re-streams it at attribute time instead of
    /// materialising the `n × k` matrix. With [`StreamOpts::groups`] set,
    /// scores aggregate per row group (GGDA-style).
    ///
    /// The default implementation falls back to the in-memory ingest for
    /// engines without a streaming accumulator; all built-in scorers
    /// override it with true streaming.
    fn cache_stream(&mut self, reader: &StoreReader, opts: &StreamOpts) -> Result<StoreMeta> {
        check_store_width(self.name(), self.dim(), reader)?;
        ensure!(
            opts.groups.is_none(),
            "the {} scorer has no streaming implementation, which grouped scoring requires",
            self.name()
        );
        let grads = reader.read_all()?;
        self.cache(&grads, reader.meta.n)?;
        Ok(reader.meta.clone())
    }

    /// Cache stage from a finished gradient store; streams with default
    /// options (see [`Attributor::cache_stream`]) and returns the store's
    /// (self-describing) metadata.
    fn cache_store(&mut self, reader: &StoreReader) -> Result<StoreMeta> {
        self.cache_stream(reader, &StreamOpts::default())
    }

    /// Attribute stage: score an `m × k` compressed query matrix against
    /// the cached train set.
    fn attribute(&self, queries: &[f32], m: usize) -> Result<ScoreMatrix>;

    /// Self-influence `τ(z_i, z_i)` of every cached train sample.
    fn self_influence(&self) -> Result<Vec<f32>>;

    /// Provenance + cost of the engine's fitted second-order state: how
    /// many rows the FIM fit pass consumed (`0` when a persisted
    /// [`PrecondArtifact`] made the pass unnecessary, or the scorer is
    /// identity-preconditioned) and which solver was fitted. Engines
    /// without second-order state keep the default.
    fn precond_stats(&self) -> PrecondStats {
        PrecondStats::default()
    }

    /// Coverage of a streamed cache's degraded-mode run: how many selected
    /// rows were actually scored, which shards were quarantined, and how
    /// many shard-read retries were attempted. `None` for in-memory caches
    /// (they cannot degrade) and for engines without streaming state; the
    /// built-in scorers override it to report their [`stream::Coverage`].
    fn coverage(&self) -> Option<Coverage> {
        None
    }
}

/// Shared open-time width check: a store whose rows are not the scorer's
/// `k` is rejected before any shard is read.
pub fn check_store_width(name: &str, dim: usize, reader: &StoreReader) -> Result<()> {
    if reader.meta.k != dim {
        bail!(
            "store rows have k = {} but the {name} scorer was built for k = {dim}",
            reader.meta.k
        );
    }
    Ok(())
}

/// Registry: build the [`Attributor`] an [`AttributionSpec`] asks for,
/// dispatching on the scorer string.
///
/// Factorized methods require `layout` (the per-layer compressed dims,
/// e.g. `CompressorBank::layer_dims()`) — a factorized bank's total width
/// depends on the hooked-layer count, which the method spec alone cannot
/// know, so building without it would silently size the scorer to one
/// layer's `k_l`.
pub fn from_spec(spec: &AttributionSpec) -> Result<Box<dyn Attributor>> {
    if spec.method.is_factorized() && spec.layout.is_empty() {
        bail!(
            "factorized method '{}' needs AttributionSpec::layout (per-layer dims, \
             e.g. CompressorBank::layer_dims()) to size the scorer",
            spec.method.spec_string()
        );
    }
    let k = spec.total_dim();
    // Explicit `--precond` wins; otherwise each scorer keeps its default
    // solver family at the spec's damping.
    let pspec = spec
        .precond
        .clone()
        .unwrap_or_else(|| PrecondSpec::default_for_scorer(&spec.scorer, spec.damping));
    Ok(match spec.scorer.as_str() {
        "if" | "influence" => Box::new(InfluenceEngine::with_precond(k, pspec)),
        "graddot" | "dot" => Box::new(graddot::GradDot::with_precond(k, pspec)),
        "trak" => Box::new(trak::Trak::with_precond(k, pspec)),
        "tracin" => Box::new(tracin::TracIn::with_precond(k, vec![], pspec)),
        "blockwise" | "bw" => {
            let layout = if spec.layout.is_empty() {
                vec![k]
            } else {
                spec.layout.clone()
            };
            Box::new(blockwise::BlockwiseEngine::with_precond(
                blockwise::BlockLayout::new(layout),
                pspec,
            ))
        }
        other => bail!(
            "unknown scorer '{other}' (expected if|graddot|trak|tracin|blockwise)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn gaussian(rows: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..rows * k).map(|_| rng.next_gaussian()).collect()
    }

    fn spec(scorer: &str, k: usize) -> AttributionSpec {
        let mut s = AttributionSpec::new(scorer, MethodSpec::RandomMask { k }, 0);
        s.damping = 0.1;
        s
    }

    #[test]
    fn registry_builds_every_scorer_and_rejects_unknown() {
        for scorer in ["if", "graddot", "trak", "tracin", "blockwise"] {
            let a = from_spec(&spec(scorer, 6)).unwrap();
            assert_eq!(a.dim(), 6, "{scorer}");
        }
        assert!(from_spec(&spec("bogus", 6)).is_err());
    }

    #[test]
    fn explicit_precond_spec_routes_through_every_scorer() {
        // An explicit eig spec overrides the scorer's default family; at
        // full rank the influence scores match the damped default ≤ 1e-4.
        let (n, m, k) = (24, 3, 6);
        let g = gaussian(n, k, 40);
        let q = gaussian(m, k, 41);
        let mut base = spec("if", k);
        base.damping = 0.1;
        let mut eig = base.clone();
        eig.precond = Some(PrecondSpec::Eig {
            rank: k,
            lambda: 0.1,
        });
        let mut a = from_spec(&base).unwrap();
        let mut b = from_spec(&eig).unwrap();
        a.cache(&g, n).unwrap();
        b.cache(&g, n).unwrap();
        let (sa, sb) = (a.attribute(&q, m).unwrap(), b.attribute(&q, m).unwrap());
        for i in 0..m * n {
            assert!(
                (sa.scores[i] - sb.scores[i]).abs() <= 1e-4 * (1.0 + sa.scores[i].abs()),
                "at {i}"
            );
        }
        assert!(b.precond_stats().describe.contains("eig"), "{}", b.precond_stats().describe);
        // Identity on the influence scorer reduces to GradDot.
        let mut ident = base.clone();
        ident.precond = Some(PrecondSpec::Identity);
        let mut c = from_spec(&ident).unwrap();
        c.cache(&g, n).unwrap();
        let sc = c.attribute(&q, m).unwrap();
        let want = graddot::graddot_scores(&g, n, k, &q, m);
        for i in 0..m * n {
            assert!((sc.scores[i] - want[i]).abs() < 1e-5, "at {i}");
        }
        assert_eq!(c.precond_stats().fim_rows, 0);
    }

    #[test]
    fn factorized_spec_requires_layout() {
        // A factorized method's total width depends on the layer count, so
        // the registry refuses to guess it from the per-layer k_l.
        let fspec = AttributionSpec::new(
            "if",
            MethodSpec::FactGrass {
                k: 16,
                k_in: 8,
                k_out: 8,
                mask: crate::sketch::MaskKind::Random,
            },
            0,
        );
        assert!(from_spec(&fspec).is_err());
        let mut ok = fspec.clone();
        ok.layout = vec![16, 16];
        assert_eq!(from_spec(&ok).unwrap().dim(), 32);
    }

    #[test]
    fn trait_influence_matches_inherent_engine() {
        let (n, m, k) = (20, 4, 6);
        let g = gaussian(n, k, 1);
        let q = gaussian(m, k, 2);
        let mut a = from_spec(&spec("if", k)).unwrap();
        a.cache(&g, n).unwrap();
        let s = a.attribute(&q, m).unwrap();
        assert_eq!((s.m, s.n), (m, n));
        let want = InfluenceEngine::new(k, 0.1).attribute(&g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((s.scores[i] - want[i]).abs() < 1e-5, "at {i}");
        }
        // self-influence of a PD preconditioner is positive
        let si = a.self_influence().unwrap();
        assert_eq!(si.len(), n);
        assert!(si.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn trait_graddot_matches_free_function() {
        let (n, m, k) = (12, 3, 5);
        let g = gaussian(n, k, 3);
        let q = gaussian(m, k, 4);
        let mut a = from_spec(&spec("graddot", k)).unwrap();
        a.cache(&g, n).unwrap();
        let s = a.attribute(&q, m).unwrap();
        let want = graddot::graddot_scores(&g, n, k, &q, m);
        assert_eq!(s.scores, want);
        let si = a.self_influence().unwrap();
        for i in 0..n {
            let norm2: f32 = g[i * k..(i + 1) * k].iter().map(|v| v * v).sum();
            assert!((si[i] - norm2).abs() < 1e-4);
        }
    }

    #[test]
    fn trait_trak_averages_checkpoints() {
        let (n, m, k) = (10, 2, 4);
        let g1 = gaussian(n, k, 5);
        let g2 = gaussian(n, k, 6);
        let q = gaussian(m, k, 7);
        let mut ens = from_spec(&spec("trak", k)).unwrap();
        ens.cache(&g1, n).unwrap();
        ens.cache(&g2, n).unwrap();
        let s = ens.attribute(&q, m).unwrap();
        let engine = InfluenceEngine::new(k, 0.1);
        let s1 = engine.attribute(&g1, n, &q, m).unwrap();
        let s2 = engine.attribute(&g2, n, &q, m).unwrap();
        for i in 0..m * n {
            let want = (s1[i] + s2[i]) / 2.0;
            assert!((s.scores[i] - want).abs() < 1e-4, "at {i}");
        }
    }

    #[test]
    fn trait_tracin_sums_checkpoint_graddots() {
        let (n, m, k) = (8, 2, 3);
        let g1 = gaussian(n, k, 8);
        let g2 = gaussian(n, k, 9);
        let q = gaussian(m, k, 10);
        let mut t = tracin::TracIn::with_lrs(k, vec![1.0, 0.5]);
        Attributor::cache(&mut t, &g1, n).unwrap();
        Attributor::cache(&mut t, &g2, n).unwrap();
        let s = Attributor::attribute(&t, &q, m).unwrap();
        let s1 = graddot::graddot_scores(&g1, n, k, &q, m);
        let s2 = graddot::graddot_scores(&g2, n, k, &q, m);
        for i in 0..m * n {
            let want = s1[i] + 0.5 * s2[i];
            assert!((s.scores[i] - want).abs() < 1e-4, "at {i}");
        }
    }

    #[test]
    fn trait_blockwise_single_block_matches_influence() {
        let (n, m, k) = (14, 3, 6);
        let g = gaussian(n, k, 11);
        let q = gaussian(m, k, 12);
        let mut bw = from_spec(&spec("blockwise", k)).unwrap();
        bw.cache(&g, n).unwrap();
        let s = bw.attribute(&q, m).unwrap();
        let want = InfluenceEngine::new(k, 0.1).attribute(&g, n, &q, m).unwrap();
        for i in 0..m * n {
            assert!((s.scores[i] - want[i]).abs() < 1e-4, "at {i}");
        }
        assert!(bw.self_influence().unwrap().iter().all(|&v| v > 0.0));
    }

    #[test]
    fn attribute_before_cache_is_a_descriptive_error() {
        for scorer in ["if", "graddot", "trak", "tracin", "blockwise"] {
            let a = from_spec(&spec(scorer, 4)).unwrap();
            let err = a.attribute(&[0.0; 4], 1);
            assert!(err.is_err(), "{scorer} scored with an empty cache");
            assert!(a.self_influence().is_err(), "{scorer}");
        }
    }

    #[test]
    fn score_matrix_top_k_orders_descending() {
        let s = ScoreMatrix::new(vec![0.1, 3.0, -1.0, 2.0], 1, 4);
        let top = s.top_k(0, 2);
        assert_eq!(top[0].0, 1);
        assert_eq!(top[1].0, 3);
        assert_eq!(s.row(0).len(), 4);
    }

    #[test]
    fn cache_store_roundtrip_and_width_check() {
        let dir = std::env::temp_dir().join(format!("grass_attrib_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (n, k) = (6, 4);
        let g = gaussian(n, k, 13);
        let mut w = crate::store::StoreWriter::create(&dir, k, "rm:k=4", 0, 100).unwrap();
        w.push_batch(&g).unwrap();
        w.finish().unwrap();
        let reader = crate::store::StoreReader::open(&dir).unwrap();
        let mut a = from_spec(&spec("graddot", k)).unwrap();
        let meta = a.cache_store(&reader).unwrap();
        assert_eq!(meta.n, n);
        let s = a.attribute(&g, n).unwrap();
        // self-scores on the diagonal equal the norms
        let si = a.self_influence().unwrap();
        for i in 0..n {
            assert!((s.scores[i * n + i] - si[i]).abs() < 1e-4);
        }
        // wrong-width scorer is rejected before reading shards
        let mut wrong = from_spec(&spec("graddot", k + 1)).unwrap();
        assert!(wrong.cache_store(&reader).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
