//! Gradient-based data attribution on compressed gradients.
//!
//! The two-stage pipeline of §2.1:
//!   cache — per-sample gradients → compress → FIM `F̂ = Σ ĝ ĝᵀ/n` →
//!            precondition `g̃̂ = (F̂+λI)⁻¹ ĝ`;
//!   attribute — `τ(z_i, z_q) = ⟨ĝ_q, g̃̂_i⟩`.
//!
//! [`fim`] builds and inverts the compressed FIM; [`influence`] is the
//! monolithic-FIM engine (TRAK-style models); [`blockwise`] is the
//! layer-wise block-diagonal variant for LMs (§3.3.2); [`trak`] ensembles
//! checkpoints; [`graddot`] is the cheap surrogate used by Selective Mask.

pub mod blockwise;
pub mod tracin;
pub mod fim;
pub mod graddot;
pub mod influence;
pub mod trak;

pub use fim::Preconditioner;
pub use influence::InfluenceEngine;
