//! Typed store I/O errors, so retry and quarantine logic can match on
//! *kind* instead of parsing message strings.
//!
//! The vendored `anyhow` flattens wrapped errors into a string chain (no
//! `downcast_ref`), so [`StoreError`] is the direct return type of
//! [`super::StoreReader::read_rows`] / [`super::StoreReader::read_shard`];
//! callers that don't care about the kind keep using `?` — the blanket
//! `From<E: std::error::Error>` converts it into `anyhow::Error` with the
//! same descriptive message.

use std::fmt;

/// What went wrong, coarsely: drives retry-vs-quarantine decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreErrorKind {
    /// The bytes on disk are wrong (truncated / checksum-failed / short
    /// read mid-file). Retrying will not help; quarantine or abort.
    Corrupt,
    /// The operation failed in a way that may succeed on retry (generic
    /// I/O error: interrupted syscall, flaky network filesystem, …).
    Transient,
    /// The target does not exist (shard file missing, row out of range).
    Missing,
}

impl StoreErrorKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            StoreErrorKind::Corrupt => "corrupt",
            StoreErrorKind::Transient => "transient",
            StoreErrorKind::Missing => "missing",
        }
    }
}

/// A classified store read/write failure: the kind, the shard it hit
/// (when one is identifiable), and a message as descriptive as the old
/// stringly errors — `Display` is unchanged from the pre-typed era, so
/// existing regression tests on message content keep passing.
#[derive(Debug, Clone)]
pub struct StoreError {
    kind: StoreErrorKind,
    shard: Option<usize>,
    message: String,
}

impl StoreError {
    pub fn corrupt(shard: Option<usize>, message: impl fmt::Display) -> Self {
        Self {
            kind: StoreErrorKind::Corrupt,
            shard,
            message: message.to_string(),
        }
    }

    pub fn transient(shard: Option<usize>, message: impl fmt::Display) -> Self {
        Self {
            kind: StoreErrorKind::Transient,
            shard,
            message: message.to_string(),
        }
    }

    pub fn missing(shard: Option<usize>, message: impl fmt::Display) -> Self {
        Self {
            kind: StoreErrorKind::Missing,
            shard,
            message: message.to_string(),
        }
    }

    /// Classify an `std::io::Error`: `NotFound` → Missing, `UnexpectedEof`
    /// → Corrupt (the file ended where data was promised), everything else
    /// → Transient (worth a retry).
    pub fn from_io(shard: Option<usize>, context: impl fmt::Display, e: std::io::Error) -> Self {
        let kind = match e.kind() {
            std::io::ErrorKind::NotFound => StoreErrorKind::Missing,
            std::io::ErrorKind::UnexpectedEof => StoreErrorKind::Corrupt,
            _ => StoreErrorKind::Transient,
        };
        Self {
            kind,
            shard,
            message: format!("{context}: {e}"),
        }
    }

    pub fn kind(&self) -> StoreErrorKind {
        self.kind
    }

    /// The shard index this error is attributable to, when known.
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for StoreError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_classification() {
        use std::io::{Error, ErrorKind};
        let miss = StoreError::from_io(Some(3), "shard 3", Error::new(ErrorKind::NotFound, "gone"));
        assert_eq!(miss.kind(), StoreErrorKind::Missing);
        assert_eq!(miss.shard(), Some(3));
        let eof = StoreError::from_io(None, "read", Error::new(ErrorKind::UnexpectedEof, "eof"));
        assert_eq!(eof.kind(), StoreErrorKind::Corrupt);
        let other = StoreError::from_io(None, "read", Error::new(ErrorKind::Other, "flaky"));
        assert_eq!(other.kind(), StoreErrorKind::Transient);
    }

    #[test]
    fn display_keeps_context_and_cause() {
        use std::io::{Error, ErrorKind};
        let e = StoreError::from_io(Some(1), "shard 1 at /x", Error::new(ErrorKind::Other, "boom"));
        let s = e.to_string();
        assert!(s.contains("shard 1 at /x"), "{s}");
        assert!(s.contains("boom"), "{s}");
    }

    #[test]
    fn converts_into_anyhow_via_question_mark() {
        fn f() -> anyhow::Result<()> {
            Err(StoreError::corrupt(Some(2), "shard 2 failed its checksum"))?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("shard 2"), "{e}");
    }
}
