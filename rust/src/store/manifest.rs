//! The store integrity manifest (`manifest.json`): a versioned record of
//! every committed shard's row count, byte length, and CRC32C, plus the
//! checksum of `precond.bin` when an artifact has been fitted.
//!
//! **On-disk invariant: only manifest-listed shards are real.** The writer
//! commits each shard atomically — tmpfile → fsync → rename → manifest
//! rewrite (itself write-temp-then-rename) — so after a crash the manifest
//! names exactly the shards whose bytes are durable, and anything else in
//! the directory (`*.bin.tmp`, a shard past the manifest tail) is garbage
//! that resume/cleanup may delete.

use super::checksum::crc32c;
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Context, Result};
use std::io::Write;
use std::path::Path;

/// Manifest file name inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Current manifest schema version.
pub const MANIFEST_VERSION: u64 = 1;

/// One committed shard: its row count, exact byte length, and CRC32C.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    pub rows: usize,
    pub bytes: u64,
    pub crc32c: u32,
}

/// The parsed manifest. `precond_crc` is recorded by `grass fit` when it
/// writes `precond.bin`, so artifact loads verify end-to-end integrity.
/// `dtype` names the payload codec the recorded byte lengths and CRC32C
/// values were computed over (absent on legacy manifests, meaning raw
/// f32 rows), so integrity tooling can size-check shards without
/// consulting `store.json`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Manifest {
    pub shards: Vec<ShardEntry>,
    pub precond_crc: Option<u32>,
    pub dtype: Option<String>,
}

impl Manifest {
    /// Total rows across committed shards.
    pub fn committed_rows(&self) -> usize {
        self.shards.iter().map(|s| s.rows).sum()
    }

    /// Load `manifest.json` from a store directory. `Ok(None)` when the
    /// file is absent (a legacy, pre-manifest store); `Err` when present
    /// but unreadable or from an unknown schema version.
    pub fn load(dir: impl AsRef<Path>) -> Result<Option<Self>> {
        let path = dir.as_ref().join(MANIFEST_FILE);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", path.display()));
            }
        };
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let version = j.req("version")?.as_u64().unwrap_or(0);
        ensure!(
            version == MANIFEST_VERSION,
            "{} is manifest version {version}, this build reads version {MANIFEST_VERSION}",
            path.display()
        );
        let mut shards = Vec::new();
        let listed = j
            .req("shards")?
            .as_arr()
            .ok_or_else(|| anyhow!("{}: shards is not an array", path.display()))?;
        for (i, entry) in listed.iter().enumerate() {
            let rows = entry
                .req("rows")?
                .as_usize()
                .ok_or_else(|| anyhow!("{}: shard {i} has a bad row count", path.display()))?;
            let bytes = entry
                .req("bytes")?
                .as_u64()
                .ok_or_else(|| anyhow!("{}: shard {i} has a bad byte count", path.display()))?;
            let crc = entry
                .req("crc32c")?
                .as_u64()
                .ok_or_else(|| anyhow!("{}: shard {i} has a bad crc32c", path.display()))?;
            shards.push(ShardEntry {
                rows,
                bytes,
                crc32c: crc as u32,
            });
        }
        let precond_crc = j.get("precond_crc").and_then(|v| v.as_u64()).map(|v| v as u32);
        let dtype = j
            .get("dtype")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string());
        Ok(Some(Self {
            shards,
            precond_crc,
            dtype,
        }))
    }

    fn to_json(&self) -> Json {
        // CRC32C values fit a u32, exactly representable as f64 — the
        // in-repo Json numeric type — well below the 2^53 integer limit.
        let shards = self
            .shards
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("rows", Json::Num(s.rows as f64)),
                    ("bytes", Json::Num(s.bytes as f64)),
                    ("crc32c", Json::Num(s.crc32c as f64)),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("shards", Json::Arr(shards)),
        ];
        if let Some(crc) = self.precond_crc {
            pairs.push(("precond_crc", Json::Num(crc as f64)));
        }
        if let Some(dtype) = &self.dtype {
            pairs.push(("dtype", Json::Str(dtype.clone())));
        }
        Json::obj(pairs)
    }

    /// Atomically (re)write `manifest.json` into a store directory.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let path = dir.as_ref().join(MANIFEST_FILE);
        write_atomic(&path, self.to_json().to_string_pretty().as_bytes())
    }
}

/// Write `bytes` to `path` via the atomic sequence: write a `.tmp`
/// sibling, fsync it, rename over the target, fsync the parent directory.
/// A reader never observes a half-written file — it sees the old content
/// or the new, nothing in between.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension(match path.extension().and_then(|e| e.to_str()) {
        Some(ext) => format!("{ext}.tmp"),
        None => "tmp".to_string(),
    });
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    if let Some(parent) = path.parent() {
        sync_dir(parent);
    }
    Ok(())
}

/// Fsync a directory so a just-renamed entry is durable. Best-effort and
/// Unix-only: directory fsync is not portable, and a failure here only
/// weakens durability (not atomicity), so errors are ignored.
pub fn sync_dir(dir: &Path) {
    #[cfg(unix)]
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    #[cfg(not(unix))]
    let _ = dir;
}

/// CRC32C of a whole file (for verify scans and manifest upgrades).
pub fn file_crc32c(path: &Path) -> std::io::Result<(u64, u32)> {
    let bytes = std::fs::read(path)?;
    Ok((bytes.len() as u64, crc32c(&bytes)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("grass_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmpdir("roundtrip");
        let m = Manifest {
            shards: vec![
                ShardEntry { rows: 4, bytes: 64, crc32c: 0xDEAD_BEEF },
                ShardEntry { rows: 2, bytes: 32, crc32c: 7 },
            ],
            precond_crc: Some(0xFFFF_FFFF),
            dtype: Some("f16".to_string()),
        };
        m.save(&dir).unwrap();
        let back = Manifest::load(&dir).unwrap().unwrap();
        assert_eq!(back, m);
        assert_eq!(back.committed_rows(), 6);
        // Legacy manifests without the dtype key read back as None.
        std::fs::write(
            dir.join(MANIFEST_FILE),
            r#"{"version": 1, "shards": []}"#,
        )
        .unwrap();
        let legacy = Manifest::load(&dir).unwrap().unwrap();
        assert_eq!(legacy.dtype, None);
        // No stray tmp file survives the atomic rewrite.
        assert!(!dir.join("manifest.json.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn absent_manifest_is_none_not_an_error() {
        let dir = tmpdir("absent");
        assert!(Manifest::load(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_version_is_rejected() {
        let dir = tmpdir("version");
        std::fs::write(
            dir.join(MANIFEST_FILE),
            r#"{"version": 99, "shards": []}"#,
        )
        .unwrap();
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("version 99"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_atomic_replaces_existing_content() {
        let dir = tmpdir("atomic");
        let path = dir.join("target.json");
        write_atomic(&path, b"old").unwrap();
        write_atomic(&path, b"new").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"new");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_crc_matches_slice_crc() {
        let dir = tmpdir("filecrc");
        let path = dir.join("blob.bin");
        std::fs::write(&path, b"123456789").unwrap();
        let (len, crc) = file_crc32c(&path).unwrap();
        assert_eq!(len, 9);
        assert_eq!(crc, 0xE306_9283);
        std::fs::remove_dir_all(&dir).ok();
    }
}
