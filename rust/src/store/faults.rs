//! Fault injection for store I/O — the test shim behind
//! `cfg(any(test, feature = "fault-injection"))`.
//!
//! A [`FaultPlan`] scripts failures deterministically: "fail shard `i` on
//! its `n`-th read, `t` times, with a transient / corrupt error" for the
//! reader, and "tear the write of shard `i`" for the writer (the commit
//! truncates the tmpfile and errors before the rename, simulating a crash
//! mid-`write`). `tests/fault_tolerance.rs` and the pipeline_e2e recovery
//! stage drive kill-and-resume, retry-recovery, and degraded-scoring
//! proofs through this shim; release builds never compile it.

use super::error::StoreError;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// What kind of failure to inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A retryable I/O error (classified `StoreErrorKind::Transient`).
    Transient,
    /// A non-retryable data error (classified `StoreErrorKind::Corrupt`).
    Corrupt,
    /// Writer-side: truncate the shard tmpfile and fail before the rename.
    TornWrite,
}

#[derive(Debug)]
struct Rule {
    shard: usize,
    kind: FaultKind,
    /// Fire only after this many successful reads of the shard.
    after_reads: usize,
    /// How many times the rule still fires.
    remaining: usize,
}

/// A scripted set of failures, shared (via `Arc`) between the test and
/// the reader/writer it is injected into.
#[derive(Debug, Default)]
pub struct FaultPlan {
    rules: Mutex<Vec<Rule>>,
    reads: Mutex<BTreeMap<usize, usize>>,
}

impl FaultPlan {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Script: reads of `shard` fail with `kind`, starting after
    /// `after_reads` successful reads, for `times` occurrences.
    pub fn fail_read(&self, shard: usize, kind: FaultKind, after_reads: usize, times: usize) {
        self.rules.lock().unwrap().push(Rule {
            shard,
            kind,
            after_reads,
            remaining: times,
        });
    }

    /// Script: the next commit of `shard` is torn (truncated tmpfile +
    /// error before rename).
    pub fn fail_write(&self, shard: usize) {
        self.rules.lock().unwrap().push(Rule {
            shard,
            kind: FaultKind::TornWrite,
            after_reads: 0,
            remaining: 1,
        });
    }

    /// Reader hook: called once per `read_rows` touching `shard`.
    pub fn check_read(&self, shard: usize) -> Result<(), StoreError> {
        let seen = {
            let mut reads = self.reads.lock().unwrap();
            let c = reads.entry(shard).or_insert(0);
            *c += 1;
            *c
        };
        let mut rules = self.rules.lock().unwrap();
        for r in rules.iter_mut() {
            if r.shard == shard
                && r.kind != FaultKind::TornWrite
                && r.remaining > 0
                && seen > r.after_reads
            {
                r.remaining -= 1;
                return match r.kind {
                    FaultKind::Transient => Err(StoreError::transient(
                        Some(shard),
                        format!("injected transient fault on shard {shard} (read {seen})"),
                    )),
                    FaultKind::Corrupt => Err(StoreError::corrupt(
                        Some(shard),
                        format!("injected corrupt fault on shard {shard} (read {seen})"),
                    )),
                    FaultKind::TornWrite => unreachable!(),
                };
            }
        }
        Ok(())
    }

    /// Writer hook: `true` exactly when a torn-write rule for `shard` is
    /// armed (consumes one firing).
    pub fn take_torn_write(&self, shard: usize) -> bool {
        let mut rules = self.rules.lock().unwrap();
        for r in rules.iter_mut() {
            if r.shard == shard && r.kind == FaultKind::TornWrite && r.remaining > 0 {
                r.remaining -= 1;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreErrorKind;

    #[test]
    fn rules_fire_after_threshold_then_exhaust() {
        let plan = FaultPlan::new();
        plan.fail_read(1, FaultKind::Transient, 1, 2);
        assert!(plan.check_read(1).is_ok(), "read 1 is under the threshold");
        let e = plan.check_read(1).unwrap_err();
        assert_eq!(e.kind(), StoreErrorKind::Transient);
        assert_eq!(e.shard(), Some(1));
        assert!(plan.check_read(1).is_err(), "second firing");
        assert!(plan.check_read(1).is_ok(), "rule exhausted");
        assert!(plan.check_read(0).is_ok(), "other shards untouched");
    }

    #[test]
    fn corrupt_rules_classify_as_corrupt() {
        let plan = FaultPlan::new();
        plan.fail_read(0, FaultKind::Corrupt, 0, 1);
        assert_eq!(plan.check_read(0).unwrap_err().kind(), StoreErrorKind::Corrupt);
    }

    #[test]
    fn torn_write_is_consumed_once() {
        let plan = FaultPlan::new();
        plan.fail_write(2);
        assert!(!plan.take_torn_write(1));
        assert!(plan.take_torn_write(2));
        assert!(!plan.take_torn_write(2));
    }
}
