//! CRC32C (Castagnoli) — the shard checksum recorded in `manifest.json`.
//!
//! Implemented in-crate (table-driven, reflected polynomial `0x82F63B78`)
//! because the build environment is offline: no external crc crate. The
//! Castagnoli polynomial is the standard choice for storage integrity
//! (iSCSI, ext4, btrfs) — better burst-error detection than CRC32/IEEE and
//! hardware-accelerated on most platforms, though this implementation is
//! plain table lookups.

const POLY: u32 = 0x82F6_3B78;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC32C state: feed bytes as they are written, finalize once.
/// `Crc32c::new().update(a).update(b)` equals `crc32c(a ++ b)`.
#[derive(Debug, Clone)]
pub struct Crc32c {
    state: u32,
}

impl Default for Crc32c {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32c {
    pub fn new() -> Self {
        Self { state: !0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = (s >> 8) ^ TABLE[((s ^ b as u32) & 0xFF) as usize];
        }
        self.state = s;
    }

    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC32C of a byte slice.
pub fn crc32c(bytes: &[u8]) -> u32 {
    let mut c = Crc32c::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_test_vector() {
        // The canonical CRC32C check value: "123456789" → 0xE3069283.
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(crc32c(b""), 0);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let mut c = Crc32c::new();
        for chunk in data.chunks(37) {
            c.update(chunk);
        }
        assert_eq!(c.finalize(), crc32c(&data));
    }

    #[test]
    fn single_bit_flip_changes_the_checksum() {
        let mut data = vec![0u8; 64];
        let base = crc32c(&data);
        data[40] ^= 0x10;
        assert_ne!(crc32c(&data), base);
    }
}
