//! Payload dtype codecs: how a shard's `rows × k` f32 block is laid out
//! on disk. The dtype is a first-class store property — recorded in
//! `store.json` and `manifest.json`, encoded by [`crate::store::StoreWriter`]
//! at commit time, and decoded on read *inside* the streaming visitors so
//! scorers consume f32 tiles without a second materialized copy of the
//! shard. Checksums always cover the encoded bytes.
//!
//! | dtype  | bytes/row   | codec                                      |
//! |--------|-------------|--------------------------------------------|
//! | `f32`  | `4k`        | raw little-endian f32 (legacy default)     |
//! | `f16`  | `2k`        | IEEE binary16, round-to-nearest-even       |
//! | `bf16` | `2k`        | bfloat16 (top f32 bits), round-to-nearest-even |
//! | `int8` | `4 + k`     | per-row absmax scale (f32 LE header) + k symmetric int8 codes |
//!
//! Error model: f16 keeps ≤ 2⁻¹¹ relative error per element in its normal
//! range, bf16 ≤ 2⁻⁸, and int8 ≤ absmax/254 absolute per element (the
//! per-row scale makes this ≤ 1/254 of the row's largest magnitude).
//! All three are exact at 0.0, so ReLU-induced gradient sparsity survives
//! quantization bit-for-bit. The per-element conversion math lives in
//! [`crate::linalg::quantize`]; the decode loops dispatch through the
//! [`crate::linalg::simd`] kernel layer (`vcvtph2ps` f16 widening, bf16
//! shift-widening, int8 sign-extend + scale multiply on AVX2), exact on
//! every ISA.

use crate::linalg::quantize::{f32_to_bf16_bits, f32_to_f16_bits, i8_row_scale, quantize_i8};
use crate::linalg::simd;
use anyhow::{bail, Result};

/// On-disk payload element type of a shard store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PayloadDtype {
    /// Raw little-endian f32 rows — the legacy (and default) layout.
    #[default]
    F32,
    /// IEEE binary16: half the bytes, ≤ 2⁻¹¹ relative error.
    F16,
    /// bfloat16: half the bytes, f32's exponent range, ≤ 2⁻⁸ relative error.
    Bf16,
    /// Symmetric int8 against a per-row absmax scale: ~quarter the bytes.
    Int8,
}

impl PayloadDtype {
    /// Parse a CLI/JSON dtype name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(PayloadDtype::F32),
            "f16" => Ok(PayloadDtype::F16),
            "bf16" => Ok(PayloadDtype::Bf16),
            "int8" | "i8" => Ok(PayloadDtype::Int8),
            other => bail!(
                "unknown payload dtype '{other}' — expected one of f32, f16, bf16, int8"
            ),
        }
    }

    /// Canonical name (what `store.json` / `manifest.json` record).
    pub fn as_str(self) -> &'static str {
        match self {
            PayloadDtype::F32 => "f32",
            PayloadDtype::F16 => "f16",
            PayloadDtype::Bf16 => "bf16",
            PayloadDtype::Int8 => "int8",
        }
    }

    /// Encoded bytes of one `k`-column row.
    pub fn row_bytes(self, k: usize) -> usize {
        match self {
            PayloadDtype::F32 => 4 * k,
            PayloadDtype::F16 | PayloadDtype::Bf16 => 2 * k,
            // A 4-byte f32 scale header precedes the row's codes.
            PayloadDtype::Int8 => 4 + k,
        }
    }

    /// Encoded bytes per element for uniform-width dtypes; `None` for
    /// int8, whose per-row scale header makes the payload row-framed.
    pub fn elem_bytes(self) -> Option<usize> {
        match self {
            PayloadDtype::F32 => Some(4),
            PayloadDtype::F16 | PayloadDtype::Bf16 => Some(2),
            PayloadDtype::Int8 => None,
        }
    }

    /// Whether decode(encode(x)) == x for every finite input.
    pub fn is_lossless(self) -> bool {
        matches!(self, PayloadDtype::F32)
    }

    /// Encode one row, appending exactly [`PayloadDtype::row_bytes`] bytes.
    pub fn encode_row(self, row: &[f32], out: &mut Vec<u8>) {
        match self {
            PayloadDtype::F32 => {
                for &v in row {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PayloadDtype::F16 => {
                for &v in row {
                    out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                }
            }
            PayloadDtype::Bf16 => {
                for &v in row {
                    out.extend_from_slice(&f32_to_bf16_bits(v).to_le_bytes());
                }
            }
            PayloadDtype::Int8 => {
                let scale = i8_row_scale(row);
                out.extend_from_slice(&scale.to_le_bytes());
                quantize_i8(row, scale, out);
            }
        }
    }

    /// Decode a contiguous run of elements of a uniform-width dtype
    /// (`bytes.len() == out.len() × elem_bytes`). The disk read path
    /// streams through a fixed staging buffer and decodes chunk by chunk
    /// with this, fusing dequantization into the read itself.
    ///
    /// # Panics
    /// On int8, which is row-framed — use [`PayloadDtype::decode_rows`].
    #[inline]
    pub fn decode_elems(self, bytes: &[u8], out: &mut [f32]) {
        match self {
            PayloadDtype::F32 => {
                for (dst, ch) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                    *dst = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
                }
            }
            PayloadDtype::F16 => simd::decode_f16(bytes, out),
            PayloadDtype::Bf16 => simd::decode_bf16(bytes, out),
            PayloadDtype::Int8 => row_framed_int8(),
        }
    }

    /// Decode `rows` whole rows (`bytes.len() == rows × row_bytes(k)`)
    /// into `out[..rows × k]`. This is the warm-cache read path: resident
    /// shards stay encoded and each requested block decodes straight into
    /// the caller's f32 buffer. The int8 arm walks exact-length row
    /// frames so the per-row scale is loaded once per frame (broadcast
    /// into a vector register by the SIMD kernel), not re-read per
    /// element.
    #[inline]
    pub fn decode_rows(self, bytes: &[u8], k: usize, rows: usize, out: &mut [f32]) {
        debug_assert_eq!(bytes.len(), rows * self.row_bytes(k));
        debug_assert!(out.len() >= rows * k);
        match self {
            PayloadDtype::Int8 => {
                let rb = self.row_bytes(k);
                for (row, orow) in bytes.chunks_exact(rb).zip(out.chunks_exact_mut(k)) {
                    let scale = f32::from_le_bytes([row[0], row[1], row[2], row[3]]);
                    simd::dequant_i8(&row[4..], scale, orow);
                }
            }
            _ => self.decode_elems(&bytes[..rows * self.row_bytes(k)], &mut out[..rows * k]),
        }
    }
}

/// int8 is the only row-framed dtype; reaching it through the uniform
/// element decoder is a framing bug in the caller. Kept out of line so
/// the panic machinery stays off the hot decode dispatch.
#[cold]
#[inline(never)]
fn row_framed_int8() -> ! {
    panic!("int8 payloads are row-framed; decode_rows must be used")
}

impl std::fmt::Display for PayloadDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn parse_display_and_row_bytes() {
        for (name, dt, rb) in [
            ("f32", PayloadDtype::F32, 4 * 16),
            ("f16", PayloadDtype::F16, 2 * 16),
            ("bf16", PayloadDtype::Bf16, 2 * 16),
            ("int8", PayloadDtype::Int8, 4 + 16),
        ] {
            assert_eq!(PayloadDtype::parse(name).unwrap(), dt);
            assert_eq!(dt.as_str(), name);
            assert_eq!(dt.to_string(), name);
            assert_eq!(dt.row_bytes(16), rb);
        }
        assert_eq!(PayloadDtype::parse("i8").unwrap(), PayloadDtype::Int8);
        assert_eq!(PayloadDtype::default(), PayloadDtype::F32);
        let err = format!("{:#}", PayloadDtype::parse("f64").unwrap_err());
        assert!(err.contains("f64") && err.contains("bf16"), "{err}");
    }

    #[test]
    fn encode_decode_roundtrip_per_dtype() {
        let k = 24;
        let rows = 5;
        let data = gaussian(rows * k, 13);
        for dt in [
            PayloadDtype::F32,
            PayloadDtype::F16,
            PayloadDtype::Bf16,
            PayloadDtype::Int8,
        ] {
            let mut enc = Vec::new();
            for row in data.chunks(k) {
                dt.encode_row(row, &mut enc);
            }
            assert_eq!(enc.len(), rows * dt.row_bytes(k), "{dt}");
            let mut dec = vec![0.0f32; rows * k];
            dt.decode_rows(&enc, k, rows, &mut dec);
            for (i, (&v, &d)) in data.iter().zip(&dec).enumerate() {
                let tol = match dt {
                    PayloadDtype::F32 => 0.0,
                    PayloadDtype::F16 => 1e-3 * v.abs() + 1e-7,
                    PayloadDtype::Bf16 => 4e-3 * v.abs() + 1e-7,
                    // Per-row scale: error bounded by the row's absmax.
                    PayloadDtype::Int8 => {
                        let row = &data[(i / k) * k..(i / k + 1) * k];
                        row.iter().fold(0.0f32, |m, x| m.max(x.abs())) / 254.0 + 1e-7
                    }
                };
                assert!((v - d).abs() <= tol, "{dt} elem {i}: {v} vs {d}");
            }
        }
    }

    #[test]
    fn zero_rows_are_exact_under_every_dtype() {
        let k = 8;
        let zeros = vec![0.0f32; k];
        for dt in [
            PayloadDtype::F32,
            PayloadDtype::F16,
            PayloadDtype::Bf16,
            PayloadDtype::Int8,
        ] {
            let mut enc = Vec::new();
            dt.encode_row(&zeros, &mut enc);
            let mut dec = vec![1.0f32; k];
            dt.decode_rows(&enc, k, 1, &mut dec);
            assert!(dec.iter().all(|&v| v == 0.0), "{dt}: {dec:?}");
        }
    }

    #[test]
    fn decode_elems_matches_decode_rows_for_uniform_dtypes() {
        let k = 6;
        let data = gaussian(3 * k, 29);
        for dt in [PayloadDtype::F32, PayloadDtype::F16, PayloadDtype::Bf16] {
            let mut enc = Vec::new();
            for row in data.chunks(k) {
                dt.encode_row(row, &mut enc);
            }
            let mut a = vec![0.0f32; 3 * k];
            let mut b = vec![0.0f32; 3 * k];
            dt.decode_rows(&enc, k, 3, &mut a);
            // Element-wise decode over an arbitrary chunking agrees.
            let eb = dt.elem_bytes().unwrap();
            let split = 7 * eb;
            dt.decode_elems(&enc[..split], &mut b[..7]);
            dt.decode_elems(&enc[split..], &mut b[7..]);
            assert_eq!(a, b, "{dt}");
        }
    }
}
