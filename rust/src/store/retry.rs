//! Retry policy and degraded-mode read bookkeeping for streaming reads.
//!
//! [`RetryPolicy`] is bounded exponential backoff with deterministic
//! jitter (seeded [`Pcg`] — no wall-clock entropy, so tests are
//! reproducible). [`ReadGuard`] wraps [`StoreReader::read_rows`] with the
//! policy: transient errors are retried with jittered sleeps; corruption
//! (or exhausted retries) either aborts or — in `skip_corrupt` mode —
//! quarantines the shard in a shared [`ReadLog`] so every later block of
//! the same shard is skipped without re-touching the bad file. The log
//! also counts attempted retries for coverage reports and bench records.
//!
//! The log additionally carries an optional **circuit breaker** for
//! long-lived processes (the serving daemon): when armed via
//! [`ReadLog::set_breaker`], every failed read *attempt* of a shard is
//! counted, and a shard that accumulates the threshold is promoted to the
//! quarantine set even when retries would still be available — later
//! requests degrade instantly instead of re-paying backoff sleeps against
//! a persistently bad file. Batch runs leave the breaker disarmed
//! (threshold 0) and keep the exact pre-breaker behaviour.

use super::error::StoreErrorKind;
use super::{RowBlock, StoreReader};
use crate::sketch::rng::{splitmix64, Pcg};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Recover a mutex guard even if a holder panicked — the log's state is
/// plain counters, always valid.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Bounded exponential backoff with deterministic jitter.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Max retries per block after the first attempt (0 = fail fast).
    pub retries: usize,
    /// Base backoff before the first retry; doubles per attempt.
    pub backoff: Duration,
    /// Jitter seed — the sleep for (block, attempt) is a pure function of
    /// this seed, so runs are reproducible.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            retries: 2,
            backoff: Duration::from_millis(50),
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// No retries: every error surfaces on the first attempt.
    pub fn none() -> Self {
        Self {
            retries: 0,
            backoff: Duration::ZERO,
            seed: 0,
        }
    }

    /// Sleep before retry number `attempt` (1-based) of the block salted
    /// by `salt`: `backoff × 2^(attempt−1) × U[0.5, 1.5)`, capped at 2 s.
    pub fn delay(&self, attempt: usize, salt: u64) -> Duration {
        let exp = 1u32 << (attempt.clamp(1, 6) - 1) as u32;
        let mut rng = Pcg::new(self.seed ^ splitmix64(salt.wrapping_add(attempt as u64)));
        let jitter = 0.5 + rng.next_f64();
        let secs = self.backoff.as_secs_f64() * exp as f64 * jitter;
        Duration::from_secs_f64(secs.min(2.0))
    }
}

/// Shared bookkeeping of one streaming run: which shards were
/// quarantined, and how many retries were attempted. One log is shared by
/// every pass of a scorer (FIM fit, self-influence, score stream), so the
/// final coverage report sees the union.
#[derive(Debug, Default)]
pub struct ReadLog {
    quarantined: Mutex<BTreeSet<usize>>,
    retries: AtomicU64,
    /// Failed read attempts per shard (feeds the circuit breaker).
    failures: Mutex<BTreeMap<usize, u64>>,
    /// Breaker threshold: failed attempts per shard before it is
    /// force-quarantined. 0 = breaker disarmed.
    breaker: AtomicUsize,
    /// How many shards the breaker has promoted to quarantine.
    trips: AtomicU64,
}

impl ReadLog {
    pub fn is_quarantined(&self, shard: usize) -> bool {
        lock_unpoisoned(&self.quarantined).contains(&shard)
    }

    /// Mark a shard quarantined; returns `true` if it was newly added
    /// (callers warn exactly once per shard).
    pub fn quarantine(&self, shard: usize) -> bool {
        lock_unpoisoned(&self.quarantined).insert(shard)
    }

    /// Sorted quarantined shard indices.
    pub fn quarantined(&self) -> Vec<usize> {
        lock_unpoisoned(&self.quarantined).iter().copied().collect()
    }

    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn retries_attempted(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Arm (or, with 0, disarm) the circuit breaker: a shard whose failed
    /// read attempts reach `threshold` is promoted straight to quarantine.
    pub fn set_breaker(&self, threshold: usize) {
        self.breaker.store(threshold, Ordering::Relaxed);
    }

    /// The armed breaker threshold (0 = disarmed).
    pub fn breaker_threshold(&self) -> usize {
        self.breaker.load(Ordering::Relaxed)
    }

    /// Shards the breaker has force-quarantined so far.
    pub fn breaker_trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Sorted `(shard, failed_attempts)` pairs seen by this log.
    pub fn failure_counts(&self) -> Vec<(usize, u64)> {
        lock_unpoisoned(&self.failures)
            .iter()
            .map(|(&s, &c)| (s, c))
            .collect()
    }

    /// Record one failed read attempt of `shard`. Returns `true` when the
    /// breaker is armed and the shard just reached (or is past) the
    /// threshold — the caller must stop retrying and degrade; the shard is
    /// quarantined here so every later read skips it outright.
    pub fn note_failure(&self, shard: usize) -> bool {
        let count = {
            let mut f = lock_unpoisoned(&self.failures);
            let c = f.entry(shard).or_insert(0);
            *c += 1;
            *c
        };
        let threshold = self.breaker.load(Ordering::Relaxed) as u64;
        if threshold == 0 || count < threshold {
            return false;
        }
        if self.quarantine(shard) {
            self.trips.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "warning: circuit breaker tripped — quarantining shard {shard} after \
                 {count} failed read attempts"
            );
        }
        true
    }
}

/// A retrying, optionally-degrading view over one reader's block reads.
pub struct ReadGuard<'a> {
    pub reader: &'a StoreReader,
    pub retry: RetryPolicy,
    pub skip_corrupt: bool,
    pub log: &'a ReadLog,
}

impl<'a> ReadGuard<'a> {
    /// Read one block into `buf[..b.rows * k]`.
    ///
    /// Returns `Ok(true)` when the rows were read, `Ok(false)` when the
    /// owning shard is (or just became) quarantined — the caller must skip
    /// the block, leaving its output columns at their zero default — and
    /// `Err` when the failure is fatal (`skip_corrupt` off, or an error
    /// with no shard to quarantine).
    ///
    /// Every failed attempt (including transient ones that would retry) is
    /// reported to the log's circuit breaker; a tripped breaker quarantines
    /// the shard and degrades immediately, even mid-backoff and even
    /// without `skip_corrupt` — the breaker is an explicit serving policy.
    pub fn read_block(&self, b: RowBlock, buf: &mut [f32]) -> Result<bool> {
        let shard = b.start / self.reader.meta.shard_rows.max(1);
        if self.log.is_quarantined(shard) {
            return Ok(false);
        }
        let mut attempt = 0usize;
        loop {
            match self.reader.read_rows(b.start, b.rows, buf) {
                Ok(()) => return Ok(true),
                Err(e)
                    if e.kind() == StoreErrorKind::Transient && attempt < self.retry.retries =>
                {
                    if self.log.note_failure(shard) {
                        return Ok(false); // breaker tripped: stop retrying
                    }
                    attempt += 1;
                    self.log.note_retry();
                    std::thread::sleep(self.retry.delay(attempt, b.start as u64));
                }
                Err(e) => {
                    if self.log.note_failure(shard) {
                        return Ok(false);
                    }
                    if self.skip_corrupt {
                        if self.log.quarantine(shard) {
                            eprintln!(
                                "warning: quarantining shard {shard} ({} error): {e}",
                                e.kind().as_str()
                            );
                        }
                        return Ok(false);
                    }
                    return Err(e.into());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_is_deterministic_bounded_and_grows() {
        let p = RetryPolicy {
            retries: 5,
            backoff: Duration::from_millis(40),
            seed: 9,
        };
        let d1 = p.delay(1, 123);
        assert_eq!(d1, p.delay(1, 123), "jitter must be seed-deterministic");
        // Jitter range: [0.5, 1.5) × base × 2^(attempt−1).
        assert!(d1 >= Duration::from_millis(20) && d1 < Duration::from_millis(60), "{d1:?}");
        let d3 = p.delay(3, 123);
        assert!(d3 >= Duration::from_millis(80) && d3 < Duration::from_millis(240), "{d3:?}");
        // Deep attempts saturate at the 2 s cap.
        let huge = RetryPolicy {
            retries: 10,
            backoff: Duration::from_secs(5),
            seed: 0,
        };
        assert_eq!(huge.delay(6, 0), Duration::from_secs(2));
    }

    #[test]
    fn log_tracks_quarantine_and_retries() {
        let log = ReadLog::default();
        assert!(!log.is_quarantined(2));
        assert!(log.quarantine(2), "first quarantine is new");
        assert!(!log.quarantine(2), "second is not");
        assert!(log.quarantine(0));
        assert_eq!(log.quarantined(), vec![0, 2]);
        log.note_retry();
        log.note_retry();
        assert_eq!(log.retries_attempted(), 2);
    }

    #[test]
    fn disarmed_breaker_only_counts() {
        let log = ReadLog::default();
        assert_eq!(log.breaker_threshold(), 0);
        for _ in 0..10 {
            assert!(!log.note_failure(3), "disarmed breaker never trips");
        }
        assert_eq!(log.failure_counts(), vec![(3, 10)]);
        assert_eq!(log.breaker_trips(), 0);
        assert!(!log.is_quarantined(3));
    }

    #[test]
    fn armed_breaker_trips_at_threshold_and_quarantines() {
        let log = ReadLog::default();
        log.set_breaker(3);
        assert!(!log.note_failure(5));
        assert!(!log.note_failure(5));
        assert!(log.note_failure(5), "third failure reaches the threshold");
        assert!(log.is_quarantined(5), "tripping quarantines the shard");
        assert_eq!(log.breaker_trips(), 1);
        // Further failures keep reporting tripped but don't re-count trips.
        assert!(log.note_failure(5));
        assert_eq!(log.breaker_trips(), 1);
        // Other shards are independent.
        assert!(!log.note_failure(6));
        assert_eq!(log.failure_counts(), vec![(5, 4), (6, 1)]);
    }
}
