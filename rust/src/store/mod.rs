//! Sharded on-disk compressed-gradient store — the cache-stage output.
//!
//! Layout: a store directory holds `store.json` (metadata: k, n, shard
//! size, method spec, payload dtype) plus `shard_NNNN.bin` files of rows
//! encoded per the store's [`PayloadDtype`] (little-endian f32 by default;
//! f16/bf16/int8 codecs halve or quarter the bytes — see [`quant`]), a
//! checksummed integrity [`manifest`] (`manifest.json`), and
//! optionally a fitted-preconditioner artifact ([`PRECOND_FILE`], written
//! by `grass fit`). The writer streams rows in order with a bounded
//! in-memory buffer (backpressure comes from the coordinator's bounded
//! channels) and commits each shard atomically — tmpfile → fsync → rename
//! → manifest append — so a killed cache run loses at most the shard in
//! flight and `grass cache --resume` restarts from the first missing row.
//! The reader iterates shard-by-shard so attribution never needs the whole
//! cache in memory — at Llama scale the cache is hundreds of GB
//! (n · row_bytes, where row_bytes is 4k for f32 down to 4+k for int8) and
//! this layout is what makes the attribute stage streamable. Decoding is
//! fused into the read itself: quantized payloads dequantize straight into
//! the caller's f32 block buffer, never materializing a second copy of the
//! shard.
//! Streaming reads can go through a [`retry`] guard for transient-error
//! backoff and degraded-mode (quarantine-and-continue) scoring.

pub mod checksum;
pub mod error;
#[cfg(any(test, feature = "fault-injection"))]
pub mod faults;
pub mod manifest;
pub mod quant;
pub mod retry;

pub use checksum::{crc32c, Crc32c};
pub use error::{StoreError, StoreErrorKind};
#[cfg(any(test, feature = "fault-injection"))]
pub use faults::{FaultKind, FaultPlan};
pub use manifest::{Manifest, ShardEntry, MANIFEST_FILE};
pub use quant::PayloadDtype;
pub use retry::{ReadGuard, ReadLog, RetryPolicy};

use crate::models::shapes::ModelShapes;
use crate::sketch::MethodSpec;
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Rows per shard file.
pub const DEFAULT_SHARD_ROWS: usize = 4096;

/// Name of the in-progress marker written while a cache run is under way:
/// the full [`StoreMeta`] minus the final row count. Its presence means
/// the store is *resumable*, not readable; [`StoreWriter::finish`]
/// replaces it with the real `store.json`.
pub const PARTIAL_FILE: &str = "store.partial.json";

/// File name of the persisted fitted-preconditioner artifact inside a
/// store directory (written by `grass fit` /
/// [`crate::attrib::PrecondArtifact::save`], reused by `grass attribute`
/// so repeat query sets skip the FIM re-stream).
pub const PRECOND_FILE: &str = "precond.bin";

/// Self-describing store metadata: everything the attribute stage needs to
/// reconstruct the exact compressor bank (method spec, seed, gradient
/// geometry) plus the shard layout. [`StoreReader::open_checked`] validates
/// a requesting spec against it so a mismatched projection is rejected at
/// open time instead of silently mis-scoring.
#[derive(Debug, Clone)]
pub struct StoreMeta {
    /// Compressed dimension per row (factorized: `Σ_l k_l`).
    pub k: usize,
    /// Total rows written.
    pub n: usize,
    pub shard_rows: usize,
    /// Compression method spec string (see
    /// [`crate::sketch::MethodSpec::spec_string`]).
    pub method: String,
    /// Seed used for the projection (must match at attribute time).
    pub seed: u64,
    /// Model the gradients came from (`""` when unknown).
    pub model: String,
    /// Flat gradient dimension `p` (0 when factorized or unknown —
    /// pre-redesign stores did not record geometry).
    pub input_dim: usize,
    /// Hooked-layer `(d_in, d_out)` pairs (empty when flat or unknown).
    pub layer_dims: Vec<(usize, usize)>,
    /// Gradient-source density knob the cache ran with (synthetic sparse
    /// caches record their `--density` here so attribute-time queries
    /// regenerate from the same sparse substrate; 1.0 = dense).
    pub density: f64,
    /// On-disk payload codec (see [`quant`]). Legacy stores carry no
    /// `dtype` key and default to [`PayloadDtype::F32`].
    pub dtype: PayloadDtype,
}

impl StoreMeta {
    /// A fresh (zero-row) meta for a store about to be written.
    pub fn describe(
        spec: &MethodSpec,
        seed: u64,
        model: &str,
        shapes: &ModelShapes,
        shard_rows: usize,
    ) -> Result<Self> {
        Ok(Self {
            k: spec.bank_output_dim(shapes)?,
            n: 0,
            shard_rows,
            method: spec.spec_string(),
            seed,
            model: model.to_string(),
            input_dim: if spec.is_factorized() { 0 } else { shapes.p },
            layer_dims: if spec.is_factorized() {
                shapes.layers.clone()
            } else {
                vec![]
            },
            density: 1.0,
            dtype: PayloadDtype::F32,
        })
    }

    /// Encoded bytes of one row under this store's payload dtype.
    pub fn row_bytes(&self) -> usize {
        self.dtype.row_bytes(self.k)
    }

    /// Parse the stored method string back into a [`MethodSpec`].
    pub fn spec(&self) -> Result<MethodSpec> {
        MethodSpec::parse(&self.method)
            .with_context(|| format!("store method string '{}' is not a valid spec", self.method))
    }

    /// The gradient geometry the cache stage recorded (for rebuilding the
    /// bank at attribute time).
    pub fn shapes(&self) -> ModelShapes {
        if self.layer_dims.is_empty() {
            ModelShapes::flat(self.input_dim)
        } else {
            ModelShapes::factored(self.layer_dims.clone())
        }
    }

    /// Validate a requesting spec + seed against this store. Errors are
    /// descriptive: they name the stored and requested values.
    pub fn check(&self, spec: &MethodSpec, seed: u64) -> Result<()> {
        let stored = self.spec()?;
        if stored != *spec {
            bail!(
                "store was cached with method '{}' but attribution requested '{}' — \
                 scores would use mismatched projections",
                stored.spec_string(),
                spec.spec_string()
            );
        }
        if self.seed != seed {
            bail!(
                "store was cached with seed {} but attribution requested seed {seed} — \
                 the projections would not match",
                self.seed
            );
        }
        // Dimension check against the recorded geometry (skipped for
        // pre-redesign stores that carry no geometry).
        let shapes = self.shapes();
        if shapes.p > 0 || !shapes.layers.is_empty() {
            let expected = spec.bank_output_dim(&shapes)?;
            if expected != self.k {
                bail!(
                    "store row width k = {} does not match the {} columns spec '{}' \
                     produces on the recorded geometry",
                    self.k,
                    expected,
                    spec.spec_string()
                );
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let layers = self
            .layer_dims
            .iter()
            .map(|&(i, o)| Json::Arr(vec![Json::Num(i as f64), Json::Num(o as f64)]))
            .collect();
        Json::obj(vec![
            ("k", Json::Num(self.k as f64)),
            ("n", Json::Num(self.n as f64)),
            ("shard_rows", Json::Num(self.shard_rows as f64)),
            ("method", Json::Str(self.method.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("model", Json::Str(self.model.clone())),
            ("input_dim", Json::Num(self.input_dim as f64)),
            ("layer_dims", Json::Arr(layers)),
            ("density", Json::Num(self.density)),
            ("dtype", Json::Str(self.dtype.as_str().to_string())),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let layer_dims = j
            .get("layer_dims")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|pair| {
                        let p = pair.as_arr()?;
                        Some((p.first()?.as_usize()?, p.get(1)?.as_usize()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(Self {
            k: j.req("k")?.as_usize().ok_or_else(|| anyhow!("bad k"))?,
            n: j.req("n")?.as_usize().ok_or_else(|| anyhow!("bad n"))?,
            shard_rows: j
                .req("shard_rows")?
                .as_usize()
                .ok_or_else(|| anyhow!("bad shard_rows"))?,
            method: j.req("method")?.as_str().unwrap_or("").to_string(),
            seed: j.req("seed")?.as_u64().unwrap_or(0),
            model: j
                .get("model")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            input_dim: j.get("input_dim").and_then(|v| v.as_usize()).unwrap_or(0),
            layer_dims,
            // Pre-sparsity stores carry no density field: treat as dense.
            density: j.get("density").and_then(|v| v.as_f64()).unwrap_or(1.0),
            // Pre-quantization stores carry no dtype field: raw f32 rows.
            dtype: match j.get("dtype").and_then(|v| v.as_str()) {
                Some(s) => PayloadDtype::parse(s)
                    .context("store.json records an unreadable payload dtype")?,
                None => PayloadDtype::F32,
            },
        })
    }
}

fn shard_path(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("shard_{idx:04}.bin"))
}

fn shard_tmp_path(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("shard_{idx:04}.bin.tmp"))
}

/// Remove leftover `shard_*.bin.tmp` staging files — uncommitted writes
/// that the on-disk invariant (only manifest-listed shards are real)
/// declares garbage. Best-effort: cleanup failures only leave clutter.
fn remove_tmp_shards(dir: &Path) {
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("shard_") && name.ends_with(".bin.tmp") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
}

/// The shard currently being written, staged in a `.bin.tmp` sibling with
/// a running CRC32C until [`StoreWriter`] commits it.
struct ShardInFlight {
    file: BufWriter<std::fs::File>,
    crc: Crc32c,
    rows: usize,
    bytes: u64,
    tmp: PathBuf,
}

/// Streaming writer: rows arrive in order, shards roll automatically, and
/// every full shard is committed atomically — staged tmpfile → fsync →
/// rename → `manifest.json` append (itself an atomic rewrite) — so a crash
/// at any instant loses at most the shard in flight.
///
/// **On-disk invariant: only manifest-listed shards are real.** Anything
/// else in the directory (`*.bin.tmp` staging files, a renamed shard the
/// manifest never recorded) is garbage that [`StoreWriter::resume`] and
/// `Drop` delete.
pub struct StoreWriter {
    dir: PathBuf,
    meta: StoreMeta,
    current: Option<ShardInFlight>,
    shard_idx: usize,
    manifest: Manifest,
    finished: bool,
    /// Set when an injected torn write fired: `Drop` then leaves the torn
    /// tmpfile in place so crash-recovery tests can observe it.
    torn: bool,
    #[cfg(any(test, feature = "fault-injection"))]
    faults: Option<std::sync::Arc<FaultPlan>>,
}

impl StoreWriter {
    /// Minimal creation (benches, free-form method strings). Prefer
    /// [`StoreWriter::create_described`] so the store records the full
    /// geometry and [`StoreReader::open_checked`] can validate readers.
    pub fn create(
        dir: impl AsRef<Path>,
        k: usize,
        method: &str,
        seed: u64,
        shard_rows: usize,
    ) -> Result<Self> {
        Self::create_described(
            dir,
            StoreMeta {
                k,
                n: 0,
                shard_rows,
                method: method.to_string(),
                seed,
                model: String::new(),
                input_dim: 0,
                layer_dims: vec![],
                density: 1.0,
                dtype: PayloadDtype::F32,
            },
        )
    }

    /// Create from a fully described [`StoreMeta`] (see
    /// [`StoreMeta::describe`]); the row count restarts at zero.
    pub fn create_described(dir: impl AsRef<Path>, mut meta: StoreMeta) -> Result<Self> {
        ensure!(
            meta.shard_rows > 0,
            "store shard_rows must be positive (got 0)"
        );
        ensure!(meta.k > 0, "store row width k must be positive (got 0)");
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        // A fresh cache restarts from row zero: drop any previous store's
        // metadata and shards up front so a crash mid-recache can never
        // leave a stale store.json pointing at new shards. A fitted
        // `precond.bin` is deliberately kept — attribute-time validation
        // rejects a stale artifact with a descriptive error.
        let _ = std::fs::remove_file(dir.join("store.json"));
        if let Ok(entries) = std::fs::read_dir(&dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.starts_with("shard_")
                    && (name.ends_with(".bin") || name.ends_with(".bin.tmp"))
                {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        meta.n = 0;
        // The in-progress marker records the run's full identity so
        // `--resume` can refuse a mismatched restart.
        manifest::write_atomic(
            &dir.join(PARTIAL_FILE),
            meta.to_json().to_string_pretty().as_bytes(),
        )?;
        let man = Manifest {
            dtype: Some(meta.dtype.as_str().to_string()),
            ..Manifest::default()
        };
        man.save(&dir)?;
        Ok(Self {
            dir,
            meta,
            current: None,
            shard_idx: 0,
            manifest: man,
            finished: false,
            torn: false,
            #[cfg(any(test, feature = "fault-injection"))]
            faults: None,
        })
    }

    /// Reopen an interrupted cache run: validate every manifest-listed
    /// shard on disk (exact length + CRC32C), discard anything broken or
    /// unlisted, and return the writer positioned after the last good
    /// shard plus the number of rows already committed — the caller
    /// restarts compression from that row. `expect` guards against
    /// resuming with a different method/seed/geometry than the run being
    /// resumed (`n` is ignored: the marker records 0).
    pub fn resume(dir: impl AsRef<Path>, expect: &StoreMeta) -> Result<(Self, usize)> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join(PARTIAL_FILE)).with_context(|| {
            format!(
                "no in-progress cache to resume at {} (missing {PARTIAL_FILE} — a \
                 finished store has store.json; re-run without --resume to recache)",
                dir.display()
            )
        })?;
        let stored = StoreMeta::from_json(&Json::parse(&text)?)?;
        let same = stored.k == expect.k
            && stored.shard_rows == expect.shard_rows
            && stored.method == expect.method
            && stored.seed == expect.seed
            && stored.model == expect.model
            && stored.input_dim == expect.input_dim
            && stored.layer_dims == expect.layer_dims
            && (stored.density - expect.density).abs() < 1e-12
            && stored.dtype == expect.dtype;
        ensure!(
            same,
            "cannot resume at {}: the interrupted run used method '{}' seed {} k {} \
             shard_rows {} but this run wants method '{}' seed {} k {} shard_rows {} — \
             delete the directory to start over",
            dir.display(),
            stored.method,
            stored.seed,
            stored.k,
            stored.shard_rows,
            expect.method,
            expect.seed,
            expect.k,
            expect.shard_rows
        );
        let mut man = Manifest::load(&dir)?.unwrap_or_default();
        if let Some(md) = &man.dtype {
            ensure!(
                md == stored.dtype.as_str(),
                "cannot resume at {}: manifest.json records payload dtype '{md}' but the \
                 interrupted run used '{}' — delete the directory to start over",
                dir.display(),
                stored.dtype
            );
        }
        man.dtype = Some(stored.dtype.as_str().to_string());
        // Validate committed shards in order; the first invalid one (and
        // everything after it) is discarded and rewritten.
        let mut keep = 0usize;
        for (i, entry) in man.shards.iter().enumerate() {
            let path = shard_path(&dir, i);
            let good = match std::fs::read(&path) {
                Ok(bytes) => {
                    bytes.len() as u64 == entry.bytes
                        && entry.bytes == (entry.rows * stored.row_bytes()) as u64
                        && crc32c(&bytes) == entry.crc32c
                }
                Err(_) => false,
            };
            if !good {
                eprintln!(
                    "warning: resume found committed shard {i} invalid on disk — \
                     discarding it and every later shard"
                );
                break;
            }
            keep = i + 1;
        }
        // A ragged last shard is only committed by `finish`, which also
        // writes store.json — but a crash between the two can leave one.
        // Appending after it would misplace later rows, so rewrite it.
        if keep > 0 && man.shards[keep - 1].rows < stored.shard_rows {
            keep -= 1;
        }
        man.shards.truncate(keep);
        let mut idx = keep;
        while shard_path(&dir, idx).exists() {
            let p = shard_path(&dir, idx);
            std::fs::remove_file(&p).with_context(|| format!("removing {}", p.display()))?;
            idx += 1;
        }
        remove_tmp_shards(&dir);
        man.save(&dir)?;
        let committed = man.committed_rows();
        let mut meta = stored;
        meta.n = committed;
        Ok((
            Self {
                dir,
                meta,
                current: None,
                shard_idx: keep,
                manifest: man,
                finished: false,
                torn: false,
                #[cfg(any(test, feature = "fault-injection"))]
                faults: None,
            },
            committed,
        ))
    }

    /// Attach a fault plan: shard commits consult it for scripted torn
    /// writes (test / `fault-injection` builds only).
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn inject_faults(&mut self, plan: std::sync::Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Append one compressed row.
    pub fn push(&mut self, row: &[f32]) -> Result<()> {
        if row.len() != self.meta.k {
            bail!("row len {} != k {}", row.len(), self.meta.k);
        }
        let full = match &self.current {
            None => true,
            Some(s) => s.rows == self.meta.shard_rows,
        };
        if full {
            self.roll()?;
        }
        let dtype = self.meta.dtype;
        let s = self.current.as_mut().unwrap();
        // Encode per the store's payload dtype (raw little-endian f32 by
        // default). The encoded bytes feed the shard's running CRC32C as
        // they are written, so checksums always cover what's on disk.
        let mut buf = Vec::with_capacity(dtype.row_bytes(row.len()));
        dtype.encode_row(row, &mut buf);
        s.file.write_all(&buf)?;
        s.crc.update(&buf);
        s.rows += 1;
        s.bytes += buf.len() as u64;
        self.meta.n += 1;
        Ok(())
    }

    /// Append a batch of rows packed contiguously (`rows × k`).
    pub fn push_batch(&mut self, rows: &[f32]) -> Result<()> {
        for row in rows.chunks(self.meta.k) {
            self.push(row)?;
        }
        Ok(())
    }

    fn roll(&mut self) -> Result<()> {
        self.commit_current()?;
        let tmp = shard_tmp_path(&self.dir, self.shard_idx);
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        self.current = Some(ShardInFlight {
            file: BufWriter::new(file),
            crc: Crc32c::new(),
            rows: 0,
            bytes: 0,
            tmp,
        });
        Ok(())
    }

    /// Commit the in-flight shard: flush + fsync the tmpfile, rename it to
    /// its final `shard_NNNN.bin` name, fsync the directory, and append
    /// the shard's entry (rows, bytes, CRC32C) to `manifest.json` — itself
    /// an atomic rewrite. A crash at any point in this sequence leaves the
    /// manifest naming exactly the durable shards.
    fn commit_current(&mut self) -> Result<()> {
        let Some(mut s) = self.current.take() else {
            return Ok(());
        };
        if s.rows == 0 {
            drop(s.file);
            let _ = std::fs::remove_file(&s.tmp);
            return Ok(());
        }
        s.file
            .flush()
            .with_context(|| format!("flushing {}", s.tmp.display()))?;
        let file = s
            .file
            .into_inner()
            .map_err(|e| anyhow!("flushing {}: {e}", s.tmp.display()))?;
        #[cfg(any(test, feature = "fault-injection"))]
        if let Some(plan) = &self.faults {
            if plan.take_torn_write(self.shard_idx) {
                // Simulate a crash mid-write: half the payload is durable
                // in the tmpfile, nothing was renamed, no manifest entry
                // exists. `torn` keeps Drop from tidying the evidence.
                let _ = file.set_len(s.bytes / 2);
                let _ = file.sync_all();
                self.torn = true;
                bail!(
                    "injected torn write on shard {} (tmpfile truncated, commit aborted)",
                    self.shard_idx
                );
            }
        }
        file.sync_all()
            .with_context(|| format!("syncing {}", s.tmp.display()))?;
        drop(file);
        let path = shard_path(&self.dir, self.shard_idx);
        std::fs::rename(&s.tmp, &path)
            .with_context(|| format!("renaming {} into place", path.display()))?;
        manifest::sync_dir(&self.dir);
        self.manifest.shards.push(ShardEntry {
            rows: s.rows,
            bytes: s.bytes,
            crc32c: s.crc.finalize(),
        });
        self.manifest.save(&self.dir)?;
        self.shard_idx += 1;
        Ok(())
    }

    /// Commit the final (possibly ragged) shard, write `store.json`
    /// atomically, and remove the in-progress marker. Returns the final
    /// meta. On error, `Drop` cleans up the uncommitted staging file.
    pub fn finish(mut self) -> Result<StoreMeta> {
        self.commit_current()?;
        manifest::write_atomic(
            &self.dir.join("store.json"),
            self.meta.to_json().to_string_pretty().as_bytes(),
        )?;
        let _ = std::fs::remove_file(self.dir.join(PARTIAL_FILE));
        self.finished = true;
        Ok(self.meta.clone())
    }
}

impl Drop for StoreWriter {
    fn drop(&mut self) {
        if self.finished || self.torn {
            return;
        }
        // Abandoned mid-run (error path, or a caller dropping the writer
        // without `finish`): close the in-flight handle and clear
        // uncommitted staging files. Committed shards, the manifest, and
        // the in-progress marker stay — `resume` picks up from them.
        self.current = None;
        remove_tmp_shards(&self.dir);
    }
}

/// A contiguous run of rows inside one shard file — the unit of streamed
/// work. [`StoreReader::plan_blocks`] never emits a block that crosses a
/// shard boundary, so every block is one bounded, seekable read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowBlock {
    /// Global index of the first row in the block.
    pub start: usize,
    /// Number of rows in the block.
    pub rows: usize,
}

/// Contiguous train-row ranges for grouped attribution (GGDA-style): each
/// half-open range is one group, and the streaming scorers aggregate the
/// member rows' scores into a single column per group.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct RowGroups {
    /// Half-open row ranges, ascending and non-overlapping.
    pub ranges: Vec<Range<usize>>,
}

impl RowGroups {
    /// Build from ranges, rejecting empty, overlapping, or out-of-order
    /// entries.
    pub fn new(ranges: Vec<Range<usize>>) -> Result<Self> {
        let g = Self { ranges };
        g.check_ordered()?;
        Ok(g)
    }

    /// Parse a CLI list of half-open ranges: `"0..512,512..1024"`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut ranges = Vec::new();
        for item in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (a, b) = item.split_once("..").ok_or_else(|| {
                anyhow!("row group '{item}' is not of the form 'start..end'")
            })?;
            let start: usize = a
                .trim()
                .parse()
                .map_err(|e| anyhow!("row group '{item}': bad start: {e}"))?;
            let end: usize = b
                .trim()
                .parse()
                .map_err(|e| anyhow!("row group '{item}': bad end: {e}"))?;
            ensure!(start < end, "row group '{item}' is empty (start >= end)");
            ranges.push(start..end);
        }
        ensure!(!ranges.is_empty(), "row group list '{s}' selects nothing");
        Self::new(ranges)
    }

    /// Uniform groups of `block` rows covering `0..n` (the last group may
    /// be short).
    pub fn blocks(n: usize, block: usize) -> Self {
        let block = block.max(1);
        Self {
            ranges: (0..n)
                .step_by(block)
                .map(|s| s..(s + block).min(n))
                .collect(),
        }
    }

    fn check_ordered(&self) -> Result<()> {
        for r in &self.ranges {
            ensure!(r.start < r.end, "row group {r:?} is empty");
        }
        for w in self.ranges.windows(2) {
            ensure!(
                w[0].end <= w[1].start,
                "row groups {:?} and {:?} overlap or are out of order",
                w[0],
                w[1]
            );
        }
        Ok(())
    }

    /// Validate against a store's row count.
    pub fn validate(&self, n: usize) -> Result<()> {
        self.check_ordered()?;
        if let Some(last) = self.ranges.last() {
            ensure!(
                last.end <= n,
                "row group {last:?} exceeds the store's {n} rows"
            );
        }
        Ok(())
    }

    /// Number of groups.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Total rows the selection covers.
    pub fn total_rows(&self) -> usize {
        self.ranges.iter().map(|r| r.end - r.start).sum()
    }

    /// Group index containing `row`, if any (ranges are ordered, so this
    /// is a binary search).
    pub fn group_of(&self, row: usize) -> Option<usize> {
        let i = self.ranges.partition_point(|r| r.end <= row);
        self.ranges
            .get(i)
            .and_then(|r| (r.start <= row).then_some(i))
    }
}

/// Bounded-memory sequential iterator over a store's rows: at most one
/// block (`chunk_rows × k` values) is resident at a time, and blocks never
/// cross shard boundaries. Obtain via [`StoreReader::cursor`] /
/// [`StoreReader::cursor_with`]; the parallel analogue is
/// [`StoreReader::par_for_each_block`].
pub struct ShardCursor<'a> {
    reader: &'a StoreReader,
    blocks: Vec<RowBlock>,
    next: usize,
}

impl ShardCursor<'_> {
    /// Largest block this cursor will yield (for pre-sizing buffers).
    pub fn max_rows(&self) -> usize {
        self.blocks.iter().map(|b| b.rows).max().unwrap_or(0)
    }

    /// Blocks not yet yielded.
    pub fn remaining(&self) -> usize {
        self.blocks.len() - self.next
    }

    /// Read the next block into `buf` (grown as needed, never shrunk);
    /// returns its coordinates, or `None` once the selection is exhausted.
    pub fn next_block(&mut self, buf: &mut Vec<f32>) -> Result<Option<RowBlock>> {
        let Some(&b) = self.blocks.get(self.next) else {
            return Ok(None);
        };
        self.next += 1;
        let want = b.rows * self.reader.meta.k;
        if buf.len() < want {
            buf.resize(want, 0.0);
        }
        self.reader.read_rows(b.start, b.rows, &mut buf[..want])?;
        Ok(Some(b))
    }
}

/// Outcome of verifying one file in [`StoreReader::verify_checksums`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStatus {
    /// Bytes on disk match the recorded length and CRC32C.
    Ok,
    /// The file is gone.
    Missing,
    /// Wrong length on disk.
    SizeMismatch { expected: u64, actual: u64 },
    /// Right length, wrong CRC32C — bytes were altered in place.
    ChecksumMismatch { expected: u32, actual: u32 },
}

impl ShardStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, ShardStatus::Ok)
    }
}

impl std::fmt::Display for ShardStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardStatus::Ok => write!(f, "ok"),
            ShardStatus::Missing => write!(f, "missing"),
            ShardStatus::SizeMismatch { expected, actual } => write!(
                f,
                "size mismatch ({actual} bytes on disk, {expected} recorded)"
            ),
            ShardStatus::ChecksumMismatch { expected, actual } => write!(
                f,
                "checksum mismatch (file hashes to 0x{actual:08x}, manifest records \
                 0x{expected:08x})"
            ),
        }
    }
}

/// Full integrity-scan result (see [`StoreReader::verify_checksums`]).
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// Per-shard status, in shard order.
    pub shards: Vec<(usize, ShardStatus)>,
    /// Status of `precond.bin` — `Some` only when the artifact's checksum
    /// is recorded in the manifest (or it is recorded but the file is
    /// gone); `None` when there is nothing to verify.
    pub precond: Option<ShardStatus>,
    /// Whether a manifest backed the scan — without one only file sizes
    /// can be checked.
    pub has_manifest: bool,
}

impl VerifyReport {
    pub fn all_ok(&self) -> bool {
        let precond_ok = match self.precond {
            Some(s) => s.is_ok(),
            None => true,
        };
        self.shards.iter().all(|(_, s)| s.is_ok()) && precond_ok
    }
}

/// Reader over a finished store.
pub struct StoreReader {
    dir: PathBuf,
    pub meta: StoreMeta,
    manifest: Option<Manifest>,
    /// Optional warm shard cache (see [`crate::serve::ShardCache`]): when
    /// attached, `read_rows` serves blocks from resident shard bytes and
    /// falls back to disk on a miss. Clones share the same cache.
    cache: Option<std::sync::Arc<crate::serve::ShardCache>>,
    #[cfg(any(test, feature = "fault-injection"))]
    faults: Option<std::sync::Arc<FaultPlan>>,
}

impl Clone for StoreReader {
    fn clone(&self) -> Self {
        Self {
            dir: self.dir.clone(),
            meta: self.meta.clone(),
            manifest: self.manifest.clone(),
            cache: self.cache.clone(),
            #[cfg(any(test, feature = "fault-injection"))]
            faults: self.faults.clone(),
        }
    }
}

impl StoreReader {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = match std::fs::read_to_string(dir.join("store.json")) {
            Ok(t) => t,
            Err(e)
                if e.kind() == std::io::ErrorKind::NotFound
                    && dir.join(PARTIAL_FILE).exists() =>
            {
                bail!(
                    "store at {} is an unfinished cache run (found {PARTIAL_FILE} but no \
                     store.json) — finish it with `grass cache ... --resume` first",
                    dir.display()
                );
            }
            Err(e) => {
                return Err(e).with_context(|| format!("opening store at {}", dir.display()));
            }
        };
        let meta = StoreMeta::from_json(&Json::parse(&text)?)?;
        ensure!(
            meta.shard_rows > 0,
            "store at {} has invalid shard_rows = 0 in store.json",
            dir.display()
        );
        let manifest = Manifest::load(&dir)?;
        match &manifest {
            Some(man) => {
                // Open-time verification is counts-only (cheap, and a
                // shard truncated behind our back still surfaces as a
                // descriptive read-time error); `verify_checksums` does
                // the full integrity scan.
                let num_shards = meta.n.div_ceil(meta.shard_rows);
                ensure!(
                    man.shards.len() == num_shards && man.committed_rows() == meta.n,
                    "store at {}: manifest.json lists {} shards / {} rows but store.json \
                     records {} shards / {} rows — the store is inconsistent; recache it",
                    dir.display(),
                    man.shards.len(),
                    man.committed_rows(),
                    num_shards,
                    meta.n
                );
            }
            None => {
                eprintln!(
                    "warning: store at {} has no manifest.json (written before checksummed \
                     manifests) — integrity cannot be verified; upgrade in place with \
                     `grass verify --store {} --upgrade`",
                    dir.display(),
                    dir.display()
                );
            }
        }
        Ok(Self {
            dir,
            meta,
            manifest,
            cache: None,
            #[cfg(any(test, feature = "fault-injection"))]
            faults: None,
        })
    }

    /// Open and validate against the requesting method spec + seed: a
    /// method, seed, or row-width mismatch is a descriptive error instead
    /// of silently mis-scored attribution (see [`StoreMeta::check`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use grass::models::shapes::ModelShapes;
    /// use grass::sketch::MethodSpec;
    /// use grass::store::{StoreMeta, StoreReader, StoreWriter};
    ///
    /// let dir = std::env::temp_dir().join(format!(
    ///     "grass_doc_open_checked_{}",
    ///     std::process::id()
    /// ));
    /// # let _ = std::fs::remove_dir_all(&dir);
    /// let spec = MethodSpec::parse("rm:k=4").unwrap();
    /// let meta = StoreMeta::describe(&spec, 7, "synth", &ModelShapes::flat(16), 2).unwrap();
    /// let mut w = StoreWriter::create_described(&dir, meta).unwrap();
    /// w.push(&[1.0, 2.0, 3.0, 4.0]).unwrap();
    /// w.finish().unwrap();
    ///
    /// // The matching spec + seed opens; a wrong seed is a descriptive error.
    /// assert!(StoreReader::open_checked(&dir, &spec, 7).is_ok());
    /// assert!(StoreReader::open_checked(&dir, &spec, 8).is_err());
    /// # std::fs::remove_dir_all(&dir).ok();
    /// ```
    pub fn open_checked(dir: impl AsRef<Path>, spec: &MethodSpec, seed: u64) -> Result<Self> {
        let dir = dir.as_ref();
        let r = Self::open(dir)?;
        r.meta
            .check(spec, seed)
            .with_context(|| format!("store at {} rejected the requesting spec", dir.display()))?;
        Ok(r)
    }

    pub fn num_shards(&self) -> usize {
        self.meta.n.div_ceil(self.meta.shard_rows)
    }

    /// The store directory this reader was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether the store carries an integrity manifest (`manifest.json`).
    pub fn has_manifest(&self) -> bool {
        self.manifest.is_some()
    }

    /// The parsed manifest, when present.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// Attach a fault plan: subsequent `read_rows` calls consult it per
    /// shard (test / `fault-injection` builds only).
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn inject_faults(&mut self, plan: std::sync::Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// The attached fault plan, if any (test / `fault-injection` builds
    /// only) — lets re-opened readers inherit an injection script.
    #[cfg(any(test, feature = "fault-injection"))]
    pub fn fault_plan(&self) -> Option<std::sync::Arc<FaultPlan>> {
        self.faults.clone()
    }

    /// Full integrity scan: re-read every shard and compare exact length +
    /// CRC32C against the manifest (size-only when the store predates
    /// manifests), plus `precond.bin` when its checksum was recorded. Read
    /// errors other than "file missing" still abort — this reports
    /// *corruption*, not environment flakiness.
    pub fn verify_checksums(&self) -> Result<VerifyReport> {
        let shard_rows = self.meta.shard_rows.max(1);
        let mut shards = Vec::with_capacity(self.num_shards());
        for idx in 0..self.num_shards() {
            let path = shard_path(&self.dir, idx);
            let rows = (self.meta.n - idx * shard_rows).min(shard_rows);
            let expected_len = (rows * self.meta.row_bytes()) as u64;
            let status = match std::fs::read(&path) {
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => ShardStatus::Missing,
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("reading shard {idx} at {}", path.display()));
                }
                Ok(bytes) => {
                    let entry = self.manifest.as_ref().and_then(|m| m.shards.get(idx));
                    let want_len = entry.map_or(expected_len, |s| s.bytes);
                    if bytes.len() as u64 != want_len {
                        ShardStatus::SizeMismatch {
                            expected: want_len,
                            actual: bytes.len() as u64,
                        }
                    } else if let Some(entry) = entry {
                        let actual = crc32c(&bytes);
                        if actual != entry.crc32c {
                            ShardStatus::ChecksumMismatch {
                                expected: entry.crc32c,
                                actual,
                            }
                        } else {
                            ShardStatus::Ok
                        }
                    } else {
                        ShardStatus::Ok
                    }
                }
            };
            shards.push((idx, status));
        }
        let precond_path = self.dir.join(PRECOND_FILE);
        let precond = match (
            self.manifest.as_ref().and_then(|m| m.precond_crc),
            precond_path.exists(),
        ) {
            (Some(expected), true) => {
                let (_, actual) = manifest::file_crc32c(&precond_path)
                    .with_context(|| format!("reading {}", precond_path.display()))?;
                Some(if actual == expected {
                    ShardStatus::Ok
                } else {
                    ShardStatus::ChecksumMismatch { expected, actual }
                })
            }
            (Some(_), false) => Some(ShardStatus::Missing),
            _ => None,
        };
        Ok(VerifyReport {
            shards,
            precond,
            has_manifest: self.manifest.is_some(),
        })
    }

    /// Upgrade a legacy store in place: hash every shard file (refusing if
    /// any has the wrong length — an upgrade must not bless corruption)
    /// and write a fresh `manifest.json`, recording the `precond.bin`
    /// checksum when an artifact is present.
    pub fn write_manifest(&mut self) -> Result<&Manifest> {
        let shard_rows = self.meta.shard_rows.max(1);
        let mut man = Manifest {
            dtype: Some(self.meta.dtype.as_str().to_string()),
            ..Manifest::default()
        };
        for idx in 0..self.num_shards() {
            let path = shard_path(&self.dir, idx);
            let rows = (self.meta.n - idx * shard_rows).min(shard_rows);
            let expected = (rows * self.meta.row_bytes()) as u64;
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading shard {idx} at {}", path.display()))?;
            ensure!(
                bytes.len() as u64 == expected,
                "cannot write a manifest over shard {idx} at {}: it holds {} bytes but \
                 {rows} rows × k = {} columns require {expected} bytes — repair the store \
                 before upgrading",
                path.display(),
                bytes.len(),
                self.meta.k
            );
            man.shards.push(ShardEntry {
                rows,
                bytes: expected,
                crc32c: crc32c(&bytes),
            });
        }
        let precond_path = self.dir.join(PRECOND_FILE);
        if precond_path.exists() {
            let (_, crc) = manifest::file_crc32c(&precond_path)
                .with_context(|| format!("reading {}", precond_path.display()))?;
            man.precond_crc = Some(crc);
        }
        man.save(&self.dir)?;
        self.manifest = Some(man);
        Ok(self.manifest.as_ref().unwrap())
    }

    /// Read `rows` rows starting at global row `start` into `buf`
    /// (`rows × k` values). The block must lie within one shard — the unit
    /// [`StoreReader::plan_blocks`] hands out. Errors are typed
    /// [`StoreError`]s (corrupt / transient / missing, with the shard
    /// index when identifiable) so retry and quarantine logic can act on
    /// the *kind*; the messages stay as descriptive as ever — a truncated
    /// shard names the shard index and expected-vs-actual byte lengths.
    pub fn read_rows(
        &self,
        start: usize,
        rows: usize,
        buf: &mut [f32],
    ) -> std::result::Result<(), StoreError> {
        if rows == 0 {
            return Ok(());
        }
        let k = self.meta.k;
        if start + rows > self.meta.n {
            return Err(StoreError::missing(
                None,
                format!(
                    "rows {start}..{} out of range (store has {} rows)",
                    start + rows,
                    self.meta.n
                ),
            ));
        }
        if buf.len() < rows * k {
            return Err(StoreError::corrupt(
                None,
                format!(
                    "buffer holds {} values but the block needs {} ({rows} rows × k = {k})",
                    buf.len(),
                    rows * k
                ),
            ));
        }
        let shard_rows = self.meta.shard_rows.max(1);
        let shard = start / shard_rows;
        let row_in_shard = start - shard * shard_rows;
        if row_in_shard + rows > shard_rows {
            return Err(StoreError::corrupt(
                Some(shard),
                format!("row block {start}+{rows} crosses the shard {shard} boundary"),
            ));
        }
        if let Some(cache) = &self.cache {
            // Warm path: the whole shard is (or becomes) resident in its
            // *encoded* form — quantized stores stretch the byte budget
            // 2–4× — and the requested rows decode straight into the
            // caller's buffer. Load failures fall through as typed errors
            // so retry/quarantine still see them — the cache never holds a
            // failed load.
            let data = cache.get_or_load(self, shard)?;
            let rb = self.meta.row_bytes();
            let off = row_in_shard * rb;
            self.meta
                .dtype
                .decode_rows(&data[off..off + rows * rb], k, rows, &mut buf[..rows * k]);
            cache.hint_next(shard, self.num_shards());
            return Ok(());
        }
        self.read_rows_from_disk(shard, row_in_shard, rows, buf)
    }

    /// The uncached block read: fault hook, full-shard size check, then a
    /// seek + staged read with decode fused in — encoded bytes stream
    /// through a fixed staging buffer and dequantize straight into `buf`,
    /// so a quantized shard never materializes a second f32 copy.
    /// [`crate::serve::ShardCache`] misses land on the same fault hook and
    /// size check (via [`StoreReader::read_shard_bytes_uncached`]) so
    /// injected faults and truncation detection behave identically with
    /// the cache attached.
    fn read_rows_from_disk(
        &self,
        shard: usize,
        row_in_shard: usize,
        rows: usize,
        buf: &mut [f32],
    ) -> std::result::Result<(), StoreError> {
        let k = self.meta.k;
        let dtype = self.meta.dtype;
        let row_bytes = dtype.row_bytes(k);
        let shard_rows = self.meta.shard_rows.max(1);
        #[cfg(any(test, feature = "fault-injection"))]
        if let Some(plan) = &self.faults {
            plan.check_read(shard)?;
        }
        let path = shard_path(&self.dir, shard);
        let rows_in_shard = (self.meta.n - shard * shard_rows).min(shard_rows);
        let expected = (rows_in_shard * row_bytes) as u64;
        // One stat + one open per block, deliberately: the full-shard size
        // check is what turns a partially-truncated shard into a
        // descriptive error even when this block's own bytes still read
        // (seek-based reads past a truncation point otherwise succeed
        // silently for earlier blocks). Block sizing amortises the cost.
        let actual = std::fs::metadata(&path)
            .map_err(|e| {
                StoreError::from_io(Some(shard), format!("shard {shard} at {}", path.display()), e)
            })?
            .len();
        if actual != expected {
            return Err(StoreError::corrupt(
                Some(shard),
                format!(
                    "shard {shard} at {} holds {actual} bytes but {rows_in_shard} rows × k = {k} \
                     columns require {expected} bytes — the shard file is truncated or corrupted",
                    path.display()
                ),
            ));
        }
        let mut f = std::fs::File::open(&path).map_err(|e| {
            StoreError::from_io(Some(shard), format!("shard {shard} at {}", path.display()), e)
        })?;
        f.seek(SeekFrom::Start((row_in_shard * row_bytes) as u64))
            .map_err(|e| {
                StoreError::from_io(Some(shard), format!("shard {shard}: seek failed"), e)
            })?;
        // Fixed staging buffer: the read path allocates nothing, so
        // per-worker streaming buffers are the only resident state.
        let mut bytes = [0u8; 16384];
        match dtype.elem_bytes() {
            Some(eb) => {
                // Uniform-width payload: stream `total` elements through
                // the staging buffer, decoding each filled chunk in place.
                let total = rows * k;
                let mut done = 0usize;
                while done < total {
                    let take = (total - done).min(bytes.len() / eb);
                    let nb = take * eb;
                    f.read_exact(&mut bytes[..nb]).map_err(|e| {
                        StoreError::from_io(
                            Some(shard),
                            format!("shard {shard}: short read at value {done} of {total}"),
                            e,
                        )
                    })?;
                    dtype.decode_elems(&bytes[..nb], &mut buf[done..done + take]);
                    done += take;
                }
            }
            None => {
                // Row-framed int8 payload: each row opens with its 4-byte
                // f32 scale, then k one-byte codes stream through the
                // staging buffer.
                for r in 0..rows {
                    let mut hdr = [0u8; 4];
                    f.read_exact(&mut hdr).map_err(|e| {
                        StoreError::from_io(
                            Some(shard),
                            format!("shard {shard}: short read at row {r} of {rows} (scale)"),
                            e,
                        )
                    })?;
                    let scale = f32::from_le_bytes(hdr);
                    let mut done = 0usize;
                    while done < k {
                        let take = (k - done).min(bytes.len());
                        f.read_exact(&mut bytes[..take]).map_err(|e| {
                            StoreError::from_io(
                                Some(shard),
                                format!("shard {shard}: short read at row {r} value {done} of {k}"),
                                e,
                            )
                        })?;
                        crate::linalg::quantize::dequantize_i8(
                            &bytes[..take],
                            scale,
                            &mut buf[r * k + done..r * k + done + take],
                        );
                        done += take;
                    }
                }
            }
        }
        Ok(())
    }

    /// Read shard `idx` fully: returns (first_row_index, rows × k data).
    pub fn read_shard(&self, idx: usize) -> std::result::Result<(usize, Vec<f32>), StoreError> {
        let start = idx * self.meta.shard_rows.max(1);
        if start >= self.meta.n {
            return Err(StoreError::missing(
                Some(idx),
                format!(
                    "shard {idx} out of range (store has {} shards)",
                    self.num_shards()
                ),
            ));
        }
        let rows = (self.meta.n - start).min(self.meta.shard_rows);
        let mut data = vec![0.0f32; rows * self.meta.k];
        self.read_rows(start, rows, &mut data)?;
        Ok((start, data))
    }

    /// Read shard `idx`'s raw *encoded* payload, bypassing any attached
    /// [`crate::serve::ShardCache`]. This is the cache's own load path —
    /// it must hit the disk (and the fault hook) rather than recurse into
    /// itself, and it keeps the bytes encoded so resident shards cost
    /// `rows × row_bytes` instead of `rows × k × 4`. The same full-shard
    /// size check as the decoding path guards it, so truncation surfaces
    /// identically with the cache attached.
    pub(crate) fn read_shard_bytes_uncached(
        &self,
        idx: usize,
    ) -> std::result::Result<(usize, Vec<u8>), StoreError> {
        let shard_rows = self.meta.shard_rows.max(1);
        let start = idx * shard_rows;
        if start >= self.meta.n {
            return Err(StoreError::missing(
                Some(idx),
                format!(
                    "shard {idx} out of range (store has {} shards)",
                    self.num_shards()
                ),
            ));
        }
        #[cfg(any(test, feature = "fault-injection"))]
        if let Some(plan) = &self.faults {
            plan.check_read(idx)?;
        }
        let rows = (self.meta.n - start).min(shard_rows);
        let k = self.meta.k;
        let expected = (rows * self.meta.row_bytes()) as u64;
        let path = shard_path(&self.dir, idx);
        let data = std::fs::read(&path).map_err(|e| {
            StoreError::from_io(Some(idx), format!("shard {idx} at {}", path.display()), e)
        })?;
        if data.len() as u64 != expected {
            return Err(StoreError::corrupt(
                Some(idx),
                format!(
                    "shard {idx} at {} holds {} bytes but {rows} rows × k = {k} \
                     columns require {expected} bytes — the shard file is truncated or corrupted",
                    path.display(),
                    data.len()
                ),
            ));
        }
        Ok((start, data))
    }

    /// Attach a warm shard cache: subsequent reads (including through
    /// clones made *after* this call) are served from resident shard bytes,
    /// with misses loaded through the normal fault-checked disk path.
    pub fn attach_cache(&mut self, cache: std::sync::Arc<crate::serve::ShardCache>) {
        self.cache = Some(cache);
    }

    /// The attached shard cache, if any.
    pub fn shard_cache(&self) -> Option<&std::sync::Arc<crate::serve::ShardCache>> {
        self.cache.as_ref()
    }

    /// Load the entire store as an `n × k` matrix (small experiments only).
    pub fn read_all(&self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.meta.n * self.meta.k);
        for s in 0..self.num_shards() {
            let (_, data) = self.read_shard(s)?;
            out.extend_from_slice(&data);
        }
        Ok(out)
    }

    /// Split the selected rows into streamable [`RowBlock`]s of at most
    /// `chunk_rows` rows, never crossing a shard boundary. An empty
    /// `ranges` slice selects the whole store.
    pub fn plan_blocks(&self, chunk_rows: usize, ranges: &[Range<usize>]) -> Vec<RowBlock> {
        let n = self.meta.n;
        let shard_rows = self.meta.shard_rows.max(1);
        let chunk = chunk_rows.max(1);
        let whole = [0..n];
        let ranges: &[Range<usize>] = if ranges.is_empty() { &whole } else { ranges };
        let mut out = Vec::new();
        for r in ranges {
            let end = r.end.min(n);
            let mut start = r.start;
            while start < end {
                let shard_end = (start / shard_rows + 1) * shard_rows;
                let rows = (end - start).min(chunk).min(shard_end - start);
                out.push(RowBlock { start, rows });
                start += rows;
            }
        }
        out
    }

    /// Sequential bounded-memory iteration over the whole store, one shard
    /// of rows per block.
    pub fn cursor(&self) -> ShardCursor<'_> {
        self.cursor_with(self.meta.shard_rows.max(1), &[])
    }

    /// [`StoreReader::cursor`] with explicit block sizing and row-range
    /// selection.
    pub fn cursor_with(&self, chunk_rows: usize, ranges: &[Range<usize>]) -> ShardCursor<'_> {
        ShardCursor {
            reader: self,
            blocks: self.plan_blocks(chunk_rows, ranges),
            next: 0,
        }
    }

    /// Visit every row without holding more than one shard in memory.
    pub fn for_each_row(&self, mut f: impl FnMut(usize, &[f32])) -> Result<()> {
        let mut cur = self.cursor();
        let mut buf = Vec::new();
        while let Some(b) = cur.next_block(&mut buf)? {
            for (i, row) in buf[..b.rows * self.meta.k].chunks(self.meta.k).enumerate() {
                f(b.start + i, row);
            }
        }
        Ok(())
    }

    /// Visit the selected row blocks in parallel: `workers` threads (0 =
    /// [`crate::util::par::num_threads`]), each owning one reusable row
    /// buffer and one scratch [`Vec`], claim blocks off a shared queue.
    /// The closure receives `(block index, block, row data, scratch)`; the
    /// row buffer is mutable so accumulator transforms (e.g. FIM
    /// preconditioning) run in place without a second copy. The first
    /// error wins and stops all workers.
    pub fn par_for_each_block<F>(
        &self,
        chunk_rows: usize,
        ranges: &[Range<usize>],
        workers: usize,
        f: F,
    ) -> Result<()>
    where
        F: Fn(usize, RowBlock, &mut [f32], &mut Vec<f32>) -> Result<()> + Sync,
    {
        self.par_for_each_block_guarded(
            chunk_rows,
            ranges,
            workers,
            &RetryPolicy::none(),
            false,
            &ReadLog::default(),
            f,
        )
    }

    /// [`StoreReader::par_for_each_block`] with fault handling: every
    /// block read goes through a [`ReadGuard`] — transient errors retry
    /// per `retry` with jittered backoff, and with `skip_corrupt` a bad
    /// shard is quarantined in `log` (its blocks are skipped and the
    /// closure never sees them, leaving their outputs at the zero default)
    /// instead of aborting the whole pass. With `skip_corrupt` off this
    /// degenerates to first-error-wins, exactly like the plain variant.
    #[allow(clippy::too_many_arguments)]
    pub fn par_for_each_block_guarded<F>(
        &self,
        chunk_rows: usize,
        ranges: &[Range<usize>],
        workers: usize,
        retry: &RetryPolicy,
        skip_corrupt: bool,
        log: &ReadLog,
        f: F,
    ) -> Result<()>
    where
        F: Fn(usize, RowBlock, &mut [f32], &mut Vec<f32>) -> Result<()> + Sync,
    {
        let blocks = self.plan_blocks(chunk_rows, ranges);
        if blocks.is_empty() {
            return Ok(());
        }
        let max_rows = blocks.iter().map(|b| b.rows).max().unwrap_or(0);
        let workers = if workers == 0 {
            crate::util::par::num_threads()
        } else {
            workers
        }
        .min(blocks.len())
        .max(1);
        let next = AtomicUsize::new(0);
        let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let guard = ReadGuard {
            reader: self,
            retry: retry.clone(),
            skip_corrupt,
            log,
        };
        std::thread::scope(|s| {
            for _ in 0..workers {
                let next = &next;
                let error = &error;
                let blocks = &blocks;
                let f = &f;
                let guard = &guard;
                s.spawn(move || {
                    let mut buf = vec![0.0f32; max_rows * self.meta.k];
                    let mut scratch = Vec::new();
                    loop {
                        if error.lock().unwrap().is_some() {
                            return;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= blocks.len() {
                            return;
                        }
                        let b = blocks[i];
                        let want = b.rows * self.meta.k;
                        let res = match guard.read_block(b, &mut buf[..want]) {
                            Ok(true) => f(i, b, &mut buf[..want], &mut scratch),
                            Ok(false) => Ok(()),
                            Err(e) => Err(e),
                        };
                        if let Err(e) = res {
                            let mut g = error.lock().unwrap();
                            if g.is_none() {
                                *g = Some(e);
                            }
                            return;
                        }
                    }
                });
            }
        });
        match error.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// [`StoreReader::par_for_each_block`] over the full store with
    /// whole-shard blocks — one shard of rows per worker at a time.
    pub fn par_for_each_shard<F>(&self, workers: usize, f: F) -> Result<()>
    where
        F: Fn(usize, RowBlock, &mut [f32], &mut Vec<f32>) -> Result<()> + Sync,
    {
        self.par_for_each_block(self.meta.shard_rows.max(1), &[], workers, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "grass_store_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_single_shard() {
        let dir = tmpdir("single");
        let mut w = StoreWriter::create(&dir, 4, "sjlt:k=4,s=1", 7, 100).unwrap();
        for i in 0..10 {
            w.push(&[i as f32, 1.0, 2.0, 3.0]).unwrap();
        }
        let meta = w.finish().unwrap();
        assert_eq!(meta.n, 10);
        let r = StoreReader::open(&dir).unwrap();
        assert_eq!(r.meta.k, 4);
        assert_eq!(r.meta.method, "sjlt:k=4,s=1");
        assert_eq!(r.meta.seed, 7);
        assert_eq!(r.num_shards(), 1);
        let all = r.read_all().unwrap();
        assert_eq!(all.len(), 40);
        assert_eq!(all[0], 0.0);
        assert_eq!(all[36], 9.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn meta_density_roundtrips_and_defaults_dense() {
        let dir = tmpdir("density");
        let mut w = StoreWriter::create_described(
            &dir,
            StoreMeta {
                k: 2,
                n: 0,
                shard_rows: 4,
                method: "rm:k=2".into(),
                seed: 1,
                model: "synth".into(),
                input_dim: 8,
                layer_dims: vec![],
                density: 0.01,
                dtype: PayloadDtype::F32,
            },
        )
        .unwrap();
        w.push(&[1.0, 2.0]).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&dir).unwrap();
        assert!((r.meta.density - 0.01).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).unwrap();
        // A pre-sparsity store.json without the field reads as dense.
        let legacy = Json::parse(
            r#"{"k":1,"n":0,"shard_rows":4,"method":"rm:k=1","seed":0}"#,
        )
        .unwrap();
        let m = StoreMeta::from_json(&legacy).unwrap();
        assert_eq!(m.density, 1.0);
        // …and a pre-quantization store.json without a dtype reads as f32.
        assert_eq!(m.dtype, PayloadDtype::F32);
        assert_eq!(m.row_bytes(), 4);
    }

    #[test]
    fn quantized_store_roundtrips_with_dtype_sized_shards() {
        use crate::sketch::rng::Pcg;
        for (dtype, tag, rel) in [
            (PayloadDtype::F16, "f16", 1e-3f32),
            (PayloadDtype::Bf16, "bf16", 4e-3),
            (PayloadDtype::Int8, "int8", 1e-2),
        ] {
            let dir = tmpdir(&format!("quant_{tag}"));
            let k = 6;
            let n = 10;
            let mut rng = Pcg::new(11);
            let rows: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
            let mut meta = bare_meta(k, "rm:k=6", 3, 4);
            meta.dtype = dtype;
            let mut w = StoreWriter::create_described(&dir, meta).unwrap();
            w.push_batch(&rows).unwrap();
            w.finish().unwrap();

            let r = StoreReader::open(&dir).unwrap();
            assert_eq!(r.meta.dtype, dtype);
            // Shards hold encoded bytes: sizes and checksums verify.
            let man = Manifest::load(&dir).unwrap().unwrap();
            assert_eq!(man.shards[0].bytes, (4 * dtype.row_bytes(k)) as u64);
            assert_eq!(man.dtype.as_deref(), Some(dtype.as_str()));
            assert!(r.verify_checksums().unwrap().all_ok());
            // Decoded rows land within the dtype's error envelope; the
            // bound is relative for the float dtypes and row-absmax-scaled
            // for int8.
            let all = r.read_all().unwrap();
            assert_eq!(all.len(), n * k);
            for (i, (&v, &d)) in rows.iter().zip(&all).enumerate() {
                let row = &rows[(i / k) * k..(i / k + 1) * k];
                let absmax = row.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let tol = match dtype {
                    PayloadDtype::Int8 => rel * absmax + 1e-7,
                    _ => rel * v.abs() + 1e-7,
                };
                assert!((v - d).abs() <= tol, "{tag} elem {i}: {v} vs {d}");
            }
            // Partial-block reads agree with the full decode.
            let mut block = vec![0.0f32; 2 * k];
            r.read_rows(5, 2, &mut block).unwrap();
            assert_eq!(block, all[5 * k..7 * k]);
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    #[test]
    fn resume_refuses_a_dtype_switch_and_resumes_matching_dtype() {
        let dir = tmpdir("resume_dtype");
        let mut meta = bare_meta(2, "rm:k=2", 4, 2);
        meta.dtype = PayloadDtype::F16;
        let mut w = StoreWriter::create_described(&dir, meta.clone()).unwrap();
        for i in 0..3 {
            w.push(&[i as f32, 0.25]).unwrap();
        }
        drop(w);
        // Same run but asking for f32 payloads: refused.
        let err = format!(
            "{:#}",
            StoreWriter::resume(&dir, &bare_meta(2, "rm:k=2", 4, 2)).unwrap_err()
        );
        assert!(err.contains("cannot resume"), "{err}");
        // The matching dtype resumes from the committed full shard.
        let (mut w, committed) = StoreWriter::resume(&dir, &meta).unwrap();
        assert_eq!(committed, 2);
        for i in committed..3 {
            w.push(&[i as f32, 0.25]).unwrap();
        }
        let done = w.finish().unwrap();
        assert_eq!(done.n, 3);
        assert_eq!(done.dtype, PayloadDtype::F16);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rolls_shards_and_streams() {
        let dir = tmpdir("multi");
        let mut w = StoreWriter::create(&dir, 2, "rm:k=2", 0, 3).unwrap();
        for i in 0..8 {
            w.push(&[i as f32, -(i as f32)]).unwrap();
        }
        w.finish().unwrap();
        let r = StoreReader::open(&dir).unwrap();
        assert_eq!(r.num_shards(), 3); // 3 + 3 + 2
        let (start, data) = r.read_shard(2).unwrap();
        assert_eq!(start, 6);
        assert_eq!(data, vec![6.0, -6.0, 7.0, -7.0]);
        let mut seen = vec![];
        r.for_each_row(|i, row| seen.push((i, row[0]))).unwrap();
        assert_eq!(seen.len(), 8);
        assert_eq!(seen[5], (5, 5.0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn push_batch_and_errors() {
        let dir = tmpdir("batch");
        let mut w = StoreWriter::create(&dir, 3, "m", 0, 10).unwrap();
        w.push_batch(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert!(w.push(&[1.0]).is_err()); // wrong width
        let meta = w.finish().unwrap();
        assert_eq!(meta.n, 2);
        let r = StoreReader::open(&dir).unwrap();
        assert!(r.read_shard(5).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_missing_store_fails() {
        assert!(StoreReader::open("/nonexistent/grass_store").is_err());
    }

    #[test]
    fn plan_blocks_respects_shards_chunks_and_ranges() {
        let dir = tmpdir("plan");
        let mut w = StoreWriter::create(&dir, 1, "m", 0, 4).unwrap();
        for i in 0..10 {
            w.push(&[i as f32]).unwrap();
        }
        w.finish().unwrap();
        let r = StoreReader::open(&dir).unwrap();
        // Whole store, whole-shard chunks: 4 + 4 + 2.
        let blocks = r.plan_blocks(4, &[]);
        assert_eq!(
            blocks,
            vec![
                RowBlock { start: 0, rows: 4 },
                RowBlock { start: 4, rows: 4 },
                RowBlock { start: 8, rows: 2 },
            ]
        );
        // Chunk 3 with shard boundaries at rows 4 and 8: blocks clip at
        // whichever comes first, the chunk size or the shard edge.
        let blocks = r.plan_blocks(3, &[2..9]);
        assert_eq!(
            blocks,
            vec![
                RowBlock { start: 2, rows: 2 }, // clipped at shard end 4
                RowBlock { start: 4, rows: 3 },
                RowBlock { start: 7, rows: 1 }, // clipped at shard end 8
                RowBlock { start: 8, rows: 1 },
            ]
        );
        // Cursor yields the same rows as read_all over the selection.
        let mut cur = r.cursor_with(3, &[2..9]);
        let mut buf = Vec::new();
        let mut seen = Vec::new();
        while let Some(b) = cur.next_block(&mut buf).unwrap() {
            for (i, v) in buf[..b.rows].iter().enumerate() {
                seen.push((b.start + i, *v));
            }
        }
        let want: Vec<(usize, f32)> = (2..9).map(|i| (i, i as f32)).collect();
        assert_eq!(seen, want);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn par_for_each_shard_visits_every_row_once() {
        let dir = tmpdir("parshard");
        let k = 3;
        let mut w = StoreWriter::create(&dir, k, "m", 0, 4).unwrap();
        for i in 0..11 {
            w.push(&[i as f32, 0.0, 0.0]).unwrap();
        }
        w.finish().unwrap();
        let r = StoreReader::open(&dir).unwrap();
        let seen = Mutex::new(Vec::new());
        r.par_for_each_shard(3, |_, b, data, _| {
            let mut g = seen.lock().unwrap();
            for (i, row) in data.chunks(k).enumerate() {
                g.push((b.start + i, row[0]));
            }
            Ok(())
        })
        .unwrap();
        let mut got = seen.into_inner().unwrap();
        got.sort_by_key(|&(i, _)| i);
        assert_eq!(got.len(), 11);
        for (i, &(idx, v)) in got.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(v, i as f32);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn row_groups_parse_blocks_and_group_of() {
        let g = RowGroups::parse("0..4, 4..10,12..13").unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.total_rows(), 11);
        assert_eq!(g.group_of(0), Some(0));
        assert_eq!(g.group_of(3), Some(0));
        assert_eq!(g.group_of(4), Some(1));
        assert_eq!(g.group_of(9), Some(1));
        assert_eq!(g.group_of(10), None);
        assert_eq!(g.group_of(12), Some(2));
        assert_eq!(g.group_of(13), None);
        assert!(g.validate(13).is_ok());
        assert!(g.validate(12).is_err());
        // Malformed inputs are rejected descriptively.
        assert!(RowGroups::parse("").is_err());
        assert!(RowGroups::parse("5..5").is_err());
        assert!(RowGroups::parse("4..2").is_err());
        assert!(RowGroups::parse("0..4,2..6").is_err());
        assert!(RowGroups::parse("abc").is_err());
        // Uniform blocks cover 0..n with a short tail.
        let b = RowGroups::blocks(10, 4);
        assert_eq!(b.ranges, vec![0..4, 4..8, 8..10]);
    }

    #[test]
    fn open_checked_accepts_matching_spec_and_rejects_mismatches() {
        use crate::sketch::MethodSpec;
        let dir = tmpdir("checked");
        let spec = MethodSpec::Sjlt { k: 8, s: 1 };
        let meta = StoreMeta::describe(&spec, 42, "synth", &ModelShapes::flat(64), 100).unwrap();
        let mut w = StoreWriter::create_described(&dir, meta).unwrap();
        for i in 0..5 {
            w.push(&vec![i as f32; 8]).unwrap();
        }
        w.finish().unwrap();

        // Matching spec + seed opens.
        let r = StoreReader::open_checked(&dir, &spec, 42).unwrap();
        assert_eq!(r.meta.n, 5);
        assert_eq!(r.meta.model, "synth");
        assert_eq!(r.meta.input_dim, 64);

        // Wrong method: descriptive rejection naming both specs.
        let err = format!(
            "{:#}",
            StoreReader::open_checked(&dir, &MethodSpec::Gauss { k: 8 }, 42).unwrap_err()
        );
        assert!(err.contains("sjlt:k=8,s=1"), "{err}");
        assert!(err.contains("gauss:k=8"), "{err}");

        // Wrong seed: rejected with both values named.
        let err = format!("{:#}", StoreReader::open_checked(&dir, &spec, 43).unwrap_err());
        assert!(err.contains("42") && err.contains("43"), "{err}");

        // Same spec family, different k: rejected (width mismatch).
        let err = format!(
            "{:#}",
            StoreReader::open_checked(&dir, &MethodSpec::Sjlt { k: 16, s: 1 }, 42).unwrap_err()
        );
        assert!(err.contains("sjlt:k=16"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn factorized_meta_roundtrips_geometry() {
        use crate::sketch::{MaskKind, MethodSpec};
        let dir = tmpdir("factmeta");
        let spec = MethodSpec::FactGrass {
            k: 16,
            k_in: 8,
            k_out: 8,
            mask: MaskKind::Random,
        };
        let shapes = ModelShapes::factored(vec![(32, 24), (24, 32)]);
        let meta = StoreMeta::describe(&spec, 7, "gpt2_tiny", &shapes, 50).unwrap();
        assert_eq!(meta.k, 32); // 2 layers × k_l = 16
        let mut w = StoreWriter::create_described(&dir, meta).unwrap();
        w.push(&vec![0.5; 32]).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open_checked(&dir, &spec, 7).unwrap();
        assert_eq!(r.meta.shapes(), shapes);
        assert_eq!(r.meta.spec().unwrap(), spec);
        // A factorized spec with a different k_l is rejected on width.
        let other = MethodSpec::FactGrass {
            k: 32,
            k_in: 8,
            k_out: 8,
            mask: MaskKind::Random,
        };
        assert!(StoreReader::open_checked(&dir, &other, 7).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn legacy_meta_without_geometry_still_opens() {
        // Pre-redesign store.json: no model/input_dim/layer_dims keys.
        let dir = tmpdir("legacy");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("store.json"),
            r#"{"k": 4, "n": 0, "shard_rows": 10, "method": "rm:k=4", "seed": 3}"#,
        )
        .unwrap();
        let r = StoreReader::open(&dir).unwrap();
        assert_eq!(r.meta.model, "");
        assert_eq!(r.meta.input_dim, 0);
        assert!(r.meta.layer_dims.is_empty());
        // check() still validates method + seed even without geometry.
        use crate::sketch::MethodSpec;
        assert!(StoreReader::open_checked(&dir, &MethodSpec::RandomMask { k: 4 }, 3).is_ok());
        assert!(StoreReader::open_checked(&dir, &MethodSpec::RandomMask { k: 4 }, 9).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn bare_meta(k: usize, method: &str, seed: u64, shard_rows: usize) -> StoreMeta {
        StoreMeta {
            k,
            n: 0,
            shard_rows,
            method: method.to_string(),
            seed,
            model: String::new(),
            input_dim: 0,
            layer_dims: vec![],
            density: 1.0,
            dtype: PayloadDtype::F32,
        }
    }

    #[test]
    fn writer_commits_shards_atomically_with_manifest() {
        let dir = tmpdir("manifest_commit");
        let mut w = StoreWriter::create(&dir, 2, "rm:k=2", 0, 3).unwrap();
        for i in 0..7 {
            w.push(&[i as f32, 0.5]).unwrap();
        }
        assert!(dir.join(PARTIAL_FILE).exists(), "marker present mid-run");
        w.finish().unwrap();
        assert!(!dir.join(PARTIAL_FILE).exists(), "marker removed by finish");
        let man = Manifest::load(&dir).unwrap().expect("manifest written");
        assert_eq!(man.shards.len(), 3);
        assert_eq!(
            man.shards.iter().map(|s| s.rows).collect::<Vec<_>>(),
            vec![3, 3, 1]
        );
        for (i, entry) in man.shards.iter().enumerate() {
            let (len, crc) = manifest::file_crc32c(&shard_path(&dir, i)).unwrap();
            assert_eq!(len, entry.bytes, "shard {i} length");
            assert_eq!(crc, entry.crc32c, "shard {i} checksum");
        }
        // No staging leftovers anywhere in the directory.
        for e in std::fs::read_dir(&dir).unwrap().flatten() {
            assert!(
                !e.file_name().to_string_lossy().ends_with(".tmp"),
                "stray tmp file {:?}",
                e.file_name()
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dropped_writer_resumes_from_committed_rows() {
        // Reference: an uninterrupted run of 7 rows.
        let refdir = tmpdir("resume_ref");
        let mut w = StoreWriter::create(&refdir, 2, "rm:k=2", 4, 2).unwrap();
        for i in 0..7 {
            w.push(&[i as f32, -(i as f32)]).unwrap();
        }
        w.finish().unwrap();

        // Interrupted: drop after 5 rows (2 committed shards + 1 in flight).
        let dir = tmpdir("resume");
        let mut w = StoreWriter::create(&dir, 2, "rm:k=2", 4, 2).unwrap();
        for i in 0..5 {
            w.push(&[i as f32, -(i as f32)]).unwrap();
        }
        drop(w);
        assert!(!dir.join("store.json").exists(), "no store.json mid-run");
        let man = Manifest::load(&dir).unwrap().unwrap();
        assert_eq!(man.shards.len(), 2, "only full shards were committed");

        let expect = bare_meta(2, "rm:k=2", 4, 2);
        let (mut w, committed) = StoreWriter::resume(&dir, &expect).unwrap();
        assert_eq!(committed, 4, "2 full shards of 2 rows were durable");
        for i in committed..7 {
            w.push(&[i as f32, -(i as f32)]).unwrap();
        }
        w.finish().unwrap();
        // The resumed store is byte-identical to the uninterrupted one.
        for i in 0..4 {
            assert_eq!(
                std::fs::read(shard_path(&dir, i)).unwrap(),
                std::fs::read(shard_path(&refdir, i)).unwrap(),
                "shard {i} differs from the uninterrupted run"
            );
        }
        let r = StoreReader::open(&dir).unwrap();
        assert!(r.verify_checksums().unwrap().all_ok());
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&refdir).unwrap();
    }

    #[test]
    fn resume_rejects_mismatched_run_and_missing_marker() {
        let dir = tmpdir("resume_reject");
        let mut w = StoreWriter::create(&dir, 2, "rm:k=2", 4, 2).unwrap();
        w.push(&[0.0, 1.0]).unwrap();
        drop(w);
        let err = format!(
            "{:#}",
            StoreWriter::resume(&dir, &bare_meta(2, "rm:k=2", 9, 2)).unwrap_err()
        );
        assert!(err.contains("seed 4") && err.contains("seed 9"), "{err}");
        // A finished store has no marker: resume refuses and points back.
        let (w, _) = StoreWriter::resume(&dir, &bare_meta(2, "rm:k=2", 4, 2)).unwrap();
        w.finish().unwrap();
        let err = format!(
            "{:#}",
            StoreWriter::resume(&dir, &bare_meta(2, "rm:k=2", 4, 2)).unwrap_err()
        );
        assert!(err.contains(PARTIAL_FILE), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_write_loses_only_the_inflight_shard() {
        let dir = tmpdir("torn");
        let mut w = StoreWriter::create(&dir, 2, "m", 0, 2).unwrap();
        let plan = FaultPlan::new();
        plan.fail_write(1);
        w.inject_faults(plan);
        for i in 0..4 {
            w.push(&[i as f32, i as f32]).unwrap();
        }
        // Shard 1 is full; its commit fires on the next roll and is torn.
        let err = format!("{:#}", w.push(&[4.0, 4.0]).unwrap_err());
        assert!(err.contains("torn write"), "{err}");
        drop(w);
        // The torn tmpfile survives the drop (simulated crash evidence)…
        assert!(shard_tmp_path(&dir, 1).exists());
        // …and resume discards it, keeping only the durable shard 0.
        let (mut w, committed) = StoreWriter::resume(&dir, &bare_meta(2, "m", 0, 2)).unwrap();
        assert_eq!(committed, 2, "only shard 0 was durable");
        assert!(!shard_tmp_path(&dir, 1).exists(), "resume clears torn staging");
        for i in committed..5 {
            w.push(&[i as f32, i as f32]).unwrap();
        }
        let meta = w.finish().unwrap();
        assert_eq!(meta.n, 5);
        let r = StoreReader::open(&dir).unwrap();
        assert!(r.verify_checksums().unwrap().all_ok());
        let all = r.read_all().unwrap();
        assert_eq!(all.len(), 10);
        assert_eq!(all[4], 2.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_flags_bitflips_that_open_accepts() {
        let dir = tmpdir("verify");
        let mut w = StoreWriter::create(&dir, 2, "m", 0, 2).unwrap();
        for i in 0..4 {
            w.push(&[i as f32, 1.0]).unwrap();
        }
        w.finish().unwrap();
        // Flip one byte in shard 1 without changing its length.
        let p = shard_path(&dir, 1);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[3] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        // Open verifies counts only, so it still succeeds…
        let r = StoreReader::open(&dir).unwrap();
        // …but the full scan pinpoints the altered shard.
        let report = r.verify_checksums().unwrap();
        assert!(!report.all_ok());
        assert!(report.has_manifest);
        assert!(report.shards[0].1.is_ok());
        assert!(matches!(
            report.shards[1].1,
            ShardStatus::ChecksumMismatch { .. }
        ));
        // A truncated shard reports a size mismatch; a deleted one, missing.
        std::fs::write(&p, &bytes[..4]).unwrap();
        assert!(matches!(
            r.verify_checksums().unwrap().shards[1].1,
            ShardStatus::SizeMismatch { .. }
        ));
        std::fs::remove_file(&p).unwrap();
        assert!(matches!(
            r.verify_checksums().unwrap().shards[1].1,
            ShardStatus::Missing
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn legacy_store_opens_without_manifest_and_upgrades_in_place() {
        let dir = tmpdir("upgrade");
        let mut w = StoreWriter::create(&dir, 2, "m", 0, 2).unwrap();
        for i in 0..3 {
            w.push(&[i as f32, 2.0]).unwrap();
        }
        w.finish().unwrap();
        std::fs::remove_file(dir.join(MANIFEST_FILE)).unwrap();
        let mut r = StoreReader::open(&dir).unwrap();
        assert!(!r.has_manifest());
        let report = r.verify_checksums().unwrap();
        assert!(report.all_ok(), "the size-only legacy scan passes");
        assert!(!report.has_manifest);
        let man = r.write_manifest().unwrap().clone();
        assert_eq!(man.shards.len(), 2);
        assert!(r.has_manifest());
        let r2 = StoreReader::open(&dir).unwrap();
        assert!(r2.has_manifest());
        assert!(r2.verify_checksums().unwrap().all_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
