//! Sharded on-disk compressed-gradient store — the cache-stage output.
//!
//! Layout: a store directory holds `store.json` (metadata: k, n, shard
//! size, method spec) plus `shard_NNNN.bin` files of raw little-endian f32
//! rows. The writer streams rows in order with a bounded in-memory buffer
//! (backpressure comes from the coordinator's bounded channels); the reader
//! iterates shard-by-shard so attribution never needs the whole cache in
//! memory — at Llama scale the cache is hundreds of GB (n·k·4 bytes) and
//! this layout is what makes the attribute stage streamable.

use crate::models::shapes::ModelShapes;
use crate::sketch::MethodSpec;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// Rows per shard file.
pub const DEFAULT_SHARD_ROWS: usize = 4096;

/// Self-describing store metadata: everything the attribute stage needs to
/// reconstruct the exact compressor bank (method spec, seed, gradient
/// geometry) plus the shard layout. [`StoreReader::open_checked`] validates
/// a requesting spec against it so a mismatched projection is rejected at
/// open time instead of silently mis-scoring.
#[derive(Debug, Clone)]
pub struct StoreMeta {
    /// Compressed dimension per row (factorized: `Σ_l k_l`).
    pub k: usize,
    /// Total rows written.
    pub n: usize,
    pub shard_rows: usize,
    /// Compression method spec string (see
    /// [`crate::sketch::MethodSpec::spec_string`]).
    pub method: String,
    /// Seed used for the projection (must match at attribute time).
    pub seed: u64,
    /// Model the gradients came from (`""` when unknown).
    pub model: String,
    /// Flat gradient dimension `p` (0 when factorized or unknown —
    /// pre-redesign stores did not record geometry).
    pub input_dim: usize,
    /// Hooked-layer `(d_in, d_out)` pairs (empty when flat or unknown).
    pub layer_dims: Vec<(usize, usize)>,
}

impl StoreMeta {
    /// A fresh (zero-row) meta for a store about to be written.
    pub fn describe(
        spec: &MethodSpec,
        seed: u64,
        model: &str,
        shapes: &ModelShapes,
        shard_rows: usize,
    ) -> Result<Self> {
        Ok(Self {
            k: spec.bank_output_dim(shapes)?,
            n: 0,
            shard_rows,
            method: spec.spec_string(),
            seed,
            model: model.to_string(),
            input_dim: if spec.is_factorized() { 0 } else { shapes.p },
            layer_dims: if spec.is_factorized() {
                shapes.layers.clone()
            } else {
                vec![]
            },
        })
    }

    /// Parse the stored method string back into a [`MethodSpec`].
    pub fn spec(&self) -> Result<MethodSpec> {
        MethodSpec::parse(&self.method)
            .with_context(|| format!("store method string '{}' is not a valid spec", self.method))
    }

    /// The gradient geometry the cache stage recorded (for rebuilding the
    /// bank at attribute time).
    pub fn shapes(&self) -> ModelShapes {
        if self.layer_dims.is_empty() {
            ModelShapes::flat(self.input_dim)
        } else {
            ModelShapes::factored(self.layer_dims.clone())
        }
    }

    /// Validate a requesting spec + seed against this store. Errors are
    /// descriptive: they name the stored and requested values.
    pub fn check(&self, spec: &MethodSpec, seed: u64) -> Result<()> {
        let stored = self.spec()?;
        if stored != *spec {
            bail!(
                "store was cached with method '{}' but attribution requested '{}' — \
                 scores would use mismatched projections",
                stored.spec_string(),
                spec.spec_string()
            );
        }
        if self.seed != seed {
            bail!(
                "store was cached with seed {} but attribution requested seed {seed} — \
                 the projections would not match",
                self.seed
            );
        }
        // Dimension check against the recorded geometry (skipped for
        // pre-redesign stores that carry no geometry).
        let shapes = self.shapes();
        if shapes.p > 0 || !shapes.layers.is_empty() {
            let expected = spec.bank_output_dim(&shapes)?;
            if expected != self.k {
                bail!(
                    "store row width k = {} does not match the {} columns spec '{}' \
                     produces on the recorded geometry",
                    self.k,
                    expected,
                    spec.spec_string()
                );
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let layers = self
            .layer_dims
            .iter()
            .map(|&(i, o)| Json::Arr(vec![Json::Num(i as f64), Json::Num(o as f64)]))
            .collect();
        Json::obj(vec![
            ("k", Json::Num(self.k as f64)),
            ("n", Json::Num(self.n as f64)),
            ("shard_rows", Json::Num(self.shard_rows as f64)),
            ("method", Json::Str(self.method.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("model", Json::Str(self.model.clone())),
            ("input_dim", Json::Num(self.input_dim as f64)),
            ("layer_dims", Json::Arr(layers)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let layer_dims = j
            .get("layer_dims")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|pair| {
                        let p = pair.as_arr()?;
                        Some((p.first()?.as_usize()?, p.get(1)?.as_usize()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(Self {
            k: j.req("k")?.as_usize().ok_or_else(|| anyhow!("bad k"))?,
            n: j.req("n")?.as_usize().ok_or_else(|| anyhow!("bad n"))?,
            shard_rows: j
                .req("shard_rows")?
                .as_usize()
                .ok_or_else(|| anyhow!("bad shard_rows"))?,
            method: j.req("method")?.as_str().unwrap_or("").to_string(),
            seed: j.req("seed")?.as_u64().unwrap_or(0),
            model: j
                .get("model")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            input_dim: j.get("input_dim").and_then(|v| v.as_usize()).unwrap_or(0),
            layer_dims,
        })
    }
}

fn shard_path(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("shard_{idx:04}.bin"))
}

/// Streaming writer: rows arrive in order, shards roll automatically.
pub struct StoreWriter {
    dir: PathBuf,
    meta: StoreMeta,
    current: Option<BufWriter<std::fs::File>>,
    rows_in_shard: usize,
    shard_idx: usize,
}

impl StoreWriter {
    /// Minimal creation (benches, free-form method strings). Prefer
    /// [`StoreWriter::create_described`] so the store records the full
    /// geometry and [`StoreReader::open_checked`] can validate readers.
    pub fn create(
        dir: impl AsRef<Path>,
        k: usize,
        method: &str,
        seed: u64,
        shard_rows: usize,
    ) -> Result<Self> {
        Self::create_described(
            dir,
            StoreMeta {
                k,
                n: 0,
                shard_rows,
                method: method.to_string(),
                seed,
                model: String::new(),
                input_dim: 0,
                layer_dims: vec![],
            },
        )
    }

    /// Create from a fully described [`StoreMeta`] (see
    /// [`StoreMeta::describe`]); the row count restarts at zero.
    pub fn create_described(dir: impl AsRef<Path>, mut meta: StoreMeta) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        meta.n = 0;
        Ok(Self {
            dir,
            meta,
            current: None,
            rows_in_shard: 0,
            shard_idx: 0,
        })
    }

    /// Append one compressed row.
    pub fn push(&mut self, row: &[f32]) -> Result<()> {
        if row.len() != self.meta.k {
            bail!("row len {} != k {}", row.len(), self.meta.k);
        }
        if self.current.is_none() || self.rows_in_shard == self.meta.shard_rows {
            self.roll()?;
        }
        let w = self.current.as_mut().unwrap();
        // Little-endian f32; safe, portable serialisation.
        let mut buf = Vec::with_capacity(row.len() * 4);
        for &v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
        self.rows_in_shard += 1;
        self.meta.n += 1;
        Ok(())
    }

    /// Append a batch of rows packed contiguously (`rows × k`).
    pub fn push_batch(&mut self, rows: &[f32]) -> Result<()> {
        for row in rows.chunks(self.meta.k) {
            self.push(row)?;
        }
        Ok(())
    }

    fn roll(&mut self) -> Result<()> {
        if let Some(mut w) = self.current.take() {
            w.flush()?;
            self.shard_idx += 1;
        }
        let path = shard_path(&self.dir, self.shard_idx);
        self.current = Some(BufWriter::new(
            std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?,
        ));
        self.rows_in_shard = 0;
        Ok(())
    }

    /// Flush shards and write metadata. Returns the final meta.
    pub fn finish(mut self) -> Result<StoreMeta> {
        if let Some(mut w) = self.current.take() {
            w.flush()?;
        }
        std::fs::write(
            self.dir.join("store.json"),
            self.meta.to_json().to_string_pretty(),
        )?;
        Ok(self.meta)
    }
}

/// Reader over a finished store.
pub struct StoreReader {
    dir: PathBuf,
    pub meta: StoreMeta,
}

impl StoreReader {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("store.json"))
            .with_context(|| format!("opening store at {}", dir.display()))?;
        let meta = StoreMeta::from_json(&Json::parse(&text)?)?;
        Ok(Self { dir, meta })
    }

    /// Open and validate against the requesting method spec + seed: a
    /// method, seed, or row-width mismatch is a descriptive error instead
    /// of silently mis-scored attribution (see [`StoreMeta::check`]).
    pub fn open_checked(dir: impl AsRef<Path>, spec: &MethodSpec, seed: u64) -> Result<Self> {
        let dir = dir.as_ref();
        let r = Self::open(dir)?;
        r.meta
            .check(spec, seed)
            .with_context(|| format!("store at {} rejected the requesting spec", dir.display()))?;
        Ok(r)
    }

    pub fn num_shards(&self) -> usize {
        self.meta.n.div_ceil(self.meta.shard_rows)
    }

    /// Read shard `idx` fully: returns (first_row_index, rows × k data).
    pub fn read_shard(&self, idx: usize) -> Result<(usize, Vec<f32>)> {
        let start = idx * self.meta.shard_rows;
        if start >= self.meta.n {
            bail!("shard {idx} out of range");
        }
        let rows = (self.meta.n - start).min(self.meta.shard_rows);
        let path = shard_path(&self.dir, idx);
        let mut r = BufReader::new(std::fs::File::open(&path)?);
        let mut bytes = vec![0u8; rows * self.meta.k * 4];
        r.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok((start, data))
    }

    /// Load the entire store as an `n × k` matrix (small experiments only).
    pub fn read_all(&self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.meta.n * self.meta.k);
        for s in 0..self.num_shards() {
            let (_, data) = self.read_shard(s)?;
            out.extend_from_slice(&data);
        }
        Ok(out)
    }

    /// Visit every row without holding more than one shard in memory.
    pub fn for_each_row(&self, mut f: impl FnMut(usize, &[f32])) -> Result<()> {
        for s in 0..self.num_shards() {
            let (start, data) = self.read_shard(s)?;
            for (i, row) in data.chunks(self.meta.k).enumerate() {
                f(start + i, row);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "grass_store_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_single_shard() {
        let dir = tmpdir("single");
        let mut w = StoreWriter::create(&dir, 4, "sjlt:k=4,s=1", 7, 100).unwrap();
        for i in 0..10 {
            w.push(&[i as f32, 1.0, 2.0, 3.0]).unwrap();
        }
        let meta = w.finish().unwrap();
        assert_eq!(meta.n, 10);
        let r = StoreReader::open(&dir).unwrap();
        assert_eq!(r.meta.k, 4);
        assert_eq!(r.meta.method, "sjlt:k=4,s=1");
        assert_eq!(r.meta.seed, 7);
        assert_eq!(r.num_shards(), 1);
        let all = r.read_all().unwrap();
        assert_eq!(all.len(), 40);
        assert_eq!(all[0], 0.0);
        assert_eq!(all[36], 9.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rolls_shards_and_streams() {
        let dir = tmpdir("multi");
        let mut w = StoreWriter::create(&dir, 2, "rm:k=2", 0, 3).unwrap();
        for i in 0..8 {
            w.push(&[i as f32, -(i as f32)]).unwrap();
        }
        w.finish().unwrap();
        let r = StoreReader::open(&dir).unwrap();
        assert_eq!(r.num_shards(), 3); // 3 + 3 + 2
        let (start, data) = r.read_shard(2).unwrap();
        assert_eq!(start, 6);
        assert_eq!(data, vec![6.0, -6.0, 7.0, -7.0]);
        let mut seen = vec![];
        r.for_each_row(|i, row| seen.push((i, row[0]))).unwrap();
        assert_eq!(seen.len(), 8);
        assert_eq!(seen[5], (5, 5.0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn push_batch_and_errors() {
        let dir = tmpdir("batch");
        let mut w = StoreWriter::create(&dir, 3, "m", 0, 10).unwrap();
        w.push_batch(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert!(w.push(&[1.0]).is_err()); // wrong width
        let meta = w.finish().unwrap();
        assert_eq!(meta.n, 2);
        let r = StoreReader::open(&dir).unwrap();
        assert!(r.read_shard(5).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_missing_store_fails() {
        assert!(StoreReader::open("/nonexistent/grass_store").is_err());
    }

    #[test]
    fn open_checked_accepts_matching_spec_and_rejects_mismatches() {
        use crate::sketch::MethodSpec;
        let dir = tmpdir("checked");
        let spec = MethodSpec::Sjlt { k: 8, s: 1 };
        let meta = StoreMeta::describe(&spec, 42, "synth", &ModelShapes::flat(64), 100).unwrap();
        let mut w = StoreWriter::create_described(&dir, meta).unwrap();
        for i in 0..5 {
            w.push(&vec![i as f32; 8]).unwrap();
        }
        w.finish().unwrap();

        // Matching spec + seed opens.
        let r = StoreReader::open_checked(&dir, &spec, 42).unwrap();
        assert_eq!(r.meta.n, 5);
        assert_eq!(r.meta.model, "synth");
        assert_eq!(r.meta.input_dim, 64);

        // Wrong method: descriptive rejection naming both specs.
        let err = format!(
            "{:#}",
            StoreReader::open_checked(&dir, &MethodSpec::Gauss { k: 8 }, 42).unwrap_err()
        );
        assert!(err.contains("sjlt:k=8,s=1"), "{err}");
        assert!(err.contains("gauss:k=8"), "{err}");

        // Wrong seed: rejected with both values named.
        let err = format!("{:#}", StoreReader::open_checked(&dir, &spec, 43).unwrap_err());
        assert!(err.contains("42") && err.contains("43"), "{err}");

        // Same spec family, different k: rejected (width mismatch).
        let err = format!(
            "{:#}",
            StoreReader::open_checked(&dir, &MethodSpec::Sjlt { k: 16, s: 1 }, 42).unwrap_err()
        );
        assert!(err.contains("sjlt:k=16"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn factorized_meta_roundtrips_geometry() {
        use crate::sketch::{MaskKind, MethodSpec};
        let dir = tmpdir("factmeta");
        let spec = MethodSpec::FactGrass {
            k: 16,
            k_in: 8,
            k_out: 8,
            mask: MaskKind::Random,
        };
        let shapes = ModelShapes::factored(vec![(32, 24), (24, 32)]);
        let meta = StoreMeta::describe(&spec, 7, "gpt2_tiny", &shapes, 50).unwrap();
        assert_eq!(meta.k, 32); // 2 layers × k_l = 16
        let mut w = StoreWriter::create_described(&dir, meta).unwrap();
        w.push(&vec![0.5; 32]).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open_checked(&dir, &spec, 7).unwrap();
        assert_eq!(r.meta.shapes(), shapes);
        assert_eq!(r.meta.spec().unwrap(), spec);
        // A factorized spec with a different k_l is rejected on width.
        let other = MethodSpec::FactGrass {
            k: 32,
            k_in: 8,
            k_out: 8,
            mask: MaskKind::Random,
        };
        assert!(StoreReader::open_checked(&dir, &other, 7).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn legacy_meta_without_geometry_still_opens() {
        // Pre-redesign store.json: no model/input_dim/layer_dims keys.
        let dir = tmpdir("legacy");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("store.json"),
            r#"{"k": 4, "n": 0, "shard_rows": 10, "method": "rm:k=4", "seed": 3}"#,
        )
        .unwrap();
        let r = StoreReader::open(&dir).unwrap();
        assert_eq!(r.meta.model, "");
        assert_eq!(r.meta.input_dim, 0);
        assert!(r.meta.layer_dims.is_empty());
        // check() still validates method + seed even without geometry.
        use crate::sketch::MethodSpec;
        assert!(StoreReader::open_checked(&dir, &MethodSpec::RandomMask { k: 4 }, 3).is_ok());
        assert!(StoreReader::open_checked(&dir, &MethodSpec::RandomMask { k: 4 }, 9).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
