//! # GraSS — Scalable Data Attribution with Gradient Sparsification and Sparse Projection
//!
//! A three-layer Rust + JAX + Pallas reproduction of the GraSS paper
//! (Hu et al., 2025). The crate is organised as:
//!
//! - [`sketch`] — the paper's contribution: gradient compressors (SJLT,
//!   Random/Selective Mask, GraSS, FactGraSS) and baselines (Gauss, FJLT,
//!   LoGra).
//! - [`attrib`] — gradient-based data attribution on top of compressed
//!   gradients: influence functions (FIM + iFVP), TRAK, GradDot, and
//!   layer-wise block-diagonal FIM.
//! - [`runtime`] — PJRT client wrapper that loads AOT-compiled HLO text
//!   artifacts (JAX models + Pallas kernels) and executes them on the
//!   request path with zero Python.
//! - [`coordinator`] — the cache-stage pipeline: loader → dynamic batcher →
//!   PJRT gradient workers → rayon compressors → backpressured store writer.
//! - [`store`] — sharded on-disk compressed-gradient cache.
//! - [`eval`] — counterfactual evaluation (LDS) with Rust-driven subset
//!   retraining through HLO train-step executables.
//! - [`data`] — synthetic dataset substrates (digits, two-class images,
//!   themed token corpus, music-event sequences).
//! - [`models`] — model geometry registry (incl. exact Llama-3.1-8B layer
//!   shapes for the Table 2 throughput harness).
//! - [`linalg`] — Cholesky, FWHT, correlation statistics.
//! - [`exp`] — the experiment harnesses regenerating every paper table and
//!   figure (Fig 4, Tables 1a–d, Table 2, Fig 9).

pub mod attrib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod models;
pub mod runtime;
pub mod sketch;
pub mod store;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
