//! # GraSS — Scalable Data Attribution with Gradient Sparsification and Sparse Projection
//!
//! A three-layer Rust + JAX + Pallas reproduction of the GraSS paper
//! (Hu et al., 2025). The crate is organised as:
//!
//! - [`sketch`] — the paper's contribution: gradient compressors (SJLT,
//!   Random/Selective Mask, GraSS, FactGraSS) and baselines (Gauss, FJLT,
//!   LoGra). [`sketch::MethodSpec`] is the total spec language over both
//!   the flat (`rm|sm|sjlt|gauss|fjlt|grass`) and factorized
//!   (`factgrass|logra|factsjlt|factmask`) families;
//!   [`sketch::MethodSpec::build_bank`] is the single construction path
//!   from a spec + model geometry to a [`sketch::CompressorBank`].
//! - [`attrib`] — gradient-based data attribution on top of compressed
//!   gradients: influence functions (FIM + iFVP), TRAK, TracIn, GradDot,
//!   and layer-wise block-diagonal FIM, all behind the unified
//!   [`attrib::Attributor`] trait (`cache` / `cache_stream` →
//!   `attribute` → `self_influence`). [`attrib::stream`] is the
//!   out-of-core path: scorers accumulate Gram/precondition state over
//!   shard streams under a byte budget ([`attrib::StreamOpts`]) and
//!   re-stream the store at attribute time, so stores far larger than RAM
//!   attribute correctly (streamed == in-memory to ≤ 1e-5 relative,
//!   test-enforced). [`attrib::from_spec`] dispatches an
//!   [`attrib::AttributionSpec`]'s scorer string to the right engine.
//!   Every scorer composes `preconditioner ∘ inner-product`:
//!   [`attrib::precond`] is the pluggable second-order subsystem — the
//!   [`attrib::Preconditioner`] trait with identity / damped-Cholesky /
//!   eigen-truncated low-rank (`eig:r`, O(k·r) per row via
//!   [`linalg::eigh()`]) / per-layer blockwise implementations behind the
//!   [`attrib::PrecondSpec`] grammar, persisted solver artifacts
//!   ([`attrib::PrecondArtifact`], `precond.bin` — fitted once by
//!   `grass fit`, validated and reused so repeat attribution skips the
//!   FIM re-stream), and the paper's damping grid search
//!   ([`attrib::precond::select`], `--damping grid`) scored by LDS on
//!   held-out subsets.
//! - [`runtime`] — PJRT client wrapper that loads AOT-compiled HLO text
//!   artifacts (JAX models + Pallas kernels) and executes them on the
//!   request path with zero Python.
//! - [`coordinator`] — the cache-stage pipeline: loader → dynamic batcher →
//!   PJRT gradient workers → rayon compressors → backpressured store writer.
//! - [`store`] — sharded on-disk compressed-gradient cache. Stores are
//!   self-describing (method spec, seed, gradient geometry), and
//!   [`store::StoreReader::open_checked`] rejects readers whose spec or
//!   seed does not match what was cached. Streaming primitives —
//!   [`store::ShardCursor`], [`store::StoreReader::par_for_each_shard`],
//!   [`store::RowGroups`] (GGDA-style grouped row selection) — back the
//!   out-of-core attribute stage.
//! - [`serve`] — the attribution serving daemon behind `grass serve`: the
//!   store is opened once, the [`sketch::CompressorBank`] and
//!   [`attrib::PrecondArtifact`] stay resident, and scoring requests
//!   (raw / pre-compressed / synthetic query gradients) are answered over
//!   a versioned newline-delimited-JSON TCP protocol ([`serve::proto`]) by
//!   a bounded worker pool with admission control ([`serve::Admission`]:
//!   queue-depth load-shedding plus per-request deadlines, typed
//!   `Overloaded` / `DeadlineExceeded` replies). [`serve::ShardCache`]
//!   keeps warm shard bytes under an LRU byte budget with sequential
//!   prefetch — attachable to any [`store::StoreReader`], so it
//!   accelerates the batch path too — and [`serve::Metrics`] tracks
//!   request counts, p50/p95/p99 latency, queue depth, and cache hit rate,
//!   exposed via the `stats` request. A corrupt shard degrades one
//!   response (per-reply coverage) through the [`store::ReadGuard`] layer
//!   instead of killing the daemon.
//! - [`eval`] — counterfactual evaluation (LDS) with Rust-driven subset
//!   retraining through HLO train-step executables.
//! - [`data`] — synthetic dataset substrates (digits, two-class images,
//!   themed token corpus, music-event sequences).
//! - [`models`] — model geometry registry (incl. exact Llama-3.1-8B layer
//!   shapes for the Table 2 throughput harness).
//! - [`linalg`] — Cholesky, FWHT, correlation statistics.
//! - [`exp`] — the experiment harnesses regenerating every paper table and
//!   figure (Fig 4, Tables 1a–d, Table 2, Fig 9).
//!
//! # Performance & threading
//!
//! **Thread pool.** All data-parallel loops go through [`util::par`], a
//! scoped-thread splitter bounded by `available_parallelism()`. Set
//! `GRASS_NUM_THREADS=N` to cap the worker count (useful for benchmarking
//! scaling curves or pinning the pipeline's compress workers); the value is
//! read once per process.
//!
//! **Kernel paths.** Every compressor exposes three execution tiers:
//!
//! 1. *Serial* — [`sketch::Compressor::compress_into`] on one vector. Small
//!    inputs (e.g. SJLT below 2¹⁵ elements) always take this path; large
//!    single vectors switch to input-partitioned parallel scatter with
//!    private accumulators (the paper's contention-free CUDA layout, on
//!    CPU threads).
//! 2. *Batch* — [`sketch::Compressor::compress_batch_with`] /
//!    [`sketch::FactorizedCompressor::compress_batch_with`], the
//!    **batch-first hot path** used by the cache pipeline: projector state
//!    (SJLT bucket/sign tables, FJLT sign vectors, Gaussian projection
//!    blocks, LoGra factor projections) is computed once per batch and
//!    amortised across all rows, with rows partitioned across threads so
//!    output writes never contend.
//! 3. *Sparse* — [`sketch::Compressor::compress_sparse_into`], nnz-scaling
//!    per-sample compression for explicitly sparse gradients.
//! 4. *Sparse batch* — [`sketch::Compressor::compress_sparse_batch_with`] /
//!    [`sketch::FactorizedCompressor::compress_sparse_batch_with`] over
//!    CSR [`sketch::SparseRows`] batches: nnz-proportional kernels
//!    (`O(s·nnz)` SJLT scatter, `O(nnz + k)` sorted mask merges, GraSS
//!    mask-then-project entirely in index space) that never touch a zero
//!    coordinate. For banks that can profit from CSR conversion
//!    ([`sketch::Compressor::sparse_dispatch_viable`]), the pipeline's
//!    grad workers density-probe each batch (early-exit scan) and convert
//!    at the [`sketch::sparse::SPARSE_DISPATCH_MAX_DENSITY`] crossover,
//!    so the compress workers receive whichever representation their
//!    kernels want — tier 2 or tier 4.
//!
//! **Scratch workspaces.** The batch tier draws every temporary from a
//! reusable [`sketch::Scratch`] (one per pipeline compress worker), so
//! steady-state compression performs no heap allocation: buffers are
//! taken, used, returned, and recycled by capacity. The convenience
//! [`sketch::Compressor::compress_batch`] wrapper allocates a throwaway
//! workspace — hot paths should hold a `Scratch` and call the `_with`
//! form.
//!
//! **Scoring GEMM.** The attribute stage (`InfluenceEngine::scores`,
//! `graddot_scores`) is a single `Q·Gᵀ` through the register-tiled
//! parallel GEMM in [`linalg::matmul`] (shared 4×4 dot microkernel), not a
//! triple loop. Benchmarks write machine-readable `BENCH_<name>.json`
//! records (see `util::bench::write_bench_json`) so throughput is
//! trackable across PRs.
//!
//! **Out-of-core scoring.** [`attrib::Attributor::cache_stream`] streams
//! a [`store::StoreReader`] shard-block by shard-block under
//! [`attrib::StreamOpts::mem_budget`]: `workers × chunk_rows × k × 4 × 2`
//! bytes of row buffers are the only resident train-row state, and score
//! columns are written incrementally as blocks complete. The full
//! data-flow diagram and memory model live in `docs/ARCHITECTURE.md`; the
//! complete CLI reference is `docs/CLI.md`.

#![allow(clippy::needless_range_loop)]

pub mod attrib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod models;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod store;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
