//! LDS evaluation subsets: S random subsets, each a fixed fraction of the
//! training set (the paper uses 50 subsets of one half each).

use crate::sketch::rng::Pcg;

/// Sample `s` subsets of `⌊n·frac⌋` distinct indices each (sorted).
pub fn sample_subsets(n: usize, s: usize, frac: f64, seed: u64) -> Vec<Vec<usize>> {
    assert!((0.0..=1.0).contains(&frac));
    let size = ((n as f64 * frac) as usize).max(1);
    let mut rng = Pcg::new(seed ^ 0x5eb5);
    (0..s)
        .map(|_| {
            rng.sample_distinct(n, size)
                .into_iter()
                .map(|i| i as usize)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_sizes_and_distinctness() {
        let subs = sample_subsets(100, 10, 0.5, 1);
        assert_eq!(subs.len(), 10);
        for s in &subs {
            assert_eq!(s.len(), 50);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 50);
            assert!(s.iter().all(|&i| i < 100));
        }
        // different subsets differ
        assert_ne!(subs[0], subs[1]);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(sample_subsets(50, 3, 0.4, 7), sample_subsets(50, 3, 0.4, 7));
        assert_ne!(sample_subsets(50, 3, 0.4, 7), sample_subsets(50, 3, 0.4, 8));
    }
}
