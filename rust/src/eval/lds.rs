//! Linear Datamodeling Score (Park et al. 2023).
//!
//! For each test point `z_q`: predict the counterfactual test loss of a
//! model trained on subset `S` by the (negated) additive attribution mass
//! `−Σ_{i∈S} τ(z_i, z_q)` — more helpful training data included ⇒ lower
//! loss — and rank-correlate against the actually retrained losses:
//!
//! `LDS = mean_q Spearman( (−Σ_{i∈S_s} τ_iq)_s , (loss_{S_s}(z_q))_s )`.

use crate::linalg::stats::{mean, spearman};

/// Compute LDS.
///
/// * `scores`: `m × n` attribution matrix (τ[q][i]).
/// * `subsets`: S index lists into `0..n`.
/// * `subset_losses`: `S × m` — per-test losses of the model retrained on
///   each subset (row s = losses under subset s).
///
/// Returns (lds, per-test scores).
pub fn lds_score(
    scores: &[f32],
    n: usize,
    m: usize,
    subsets: &[Vec<usize>],
    subset_losses: &[f32],
) -> (f64, Vec<f64>) {
    let s_count = subsets.len();
    assert_eq!(scores.len(), m * n);
    assert_eq!(subset_losses.len(), s_count * m);

    // predicted[s][q] = Σ_{i ∈ S_s} τ[q][i]
    let mut per_test = Vec::with_capacity(m);
    for q in 0..m {
        let srow = &scores[q * n..(q + 1) * n];
        let mut predicted = Vec::with_capacity(s_count);
        let mut actual = Vec::with_capacity(s_count);
        for (s, subset) in subsets.iter().enumerate() {
            let mass: f32 = subset.iter().map(|&i| srow[i]).sum();
            predicted.push(-mass); // more attribution mass ⇒ lower loss
            actual.push(subset_losses[s * m + q]);
        }
        per_test.push(spearman(&predicted, &actual));
    }
    (mean(&per_test), per_test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    /// Ground-truth additive datamodel: loss_S(q) = Σ_{i∈S} w_iq + noise.
    /// An attributor with τ = −w should get LDS ≈ 1.
    #[test]
    fn perfect_attributor_scores_one() {
        let (n, m, s_count) = (40, 6, 24);
        let mut rng = Pcg::new(1);
        let w: Vec<f32> = (0..m * n).map(|_| rng.next_gaussian()).collect();
        let subsets = crate::eval::subsets::sample_subsets(n, s_count, 0.5, 2);
        let mut losses = vec![0.0f32; s_count * m];
        for (s, subset) in subsets.iter().enumerate() {
            for q in 0..m {
                let sum: f32 = subset.iter().map(|&i| w[q * n + i]).sum();
                losses[s * m + q] = sum;
            }
        }
        // τ = −w (helpful sample ⇒ negative loss contribution ⇒ positive τ)
        let tau: Vec<f32> = w.iter().map(|&x| -x).collect();
        let (lds, per_test) = lds_score(&tau, n, m, &subsets, &losses);
        assert!(lds > 0.99, "perfect attributor LDS = {lds}");
        assert!(per_test.iter().all(|&v| v > 0.95));
    }

    #[test]
    fn anti_attributor_scores_minus_one() {
        let (n, m, s_count) = (30, 4, 16);
        let mut rng = Pcg::new(3);
        let w: Vec<f32> = (0..m * n).map(|_| rng.next_gaussian()).collect();
        let subsets = crate::eval::subsets::sample_subsets(n, s_count, 0.5, 4);
        let mut losses = vec![0.0f32; s_count * m];
        for (s, subset) in subsets.iter().enumerate() {
            for q in 0..m {
                losses[s * m + q] = subset.iter().map(|&i| w[q * n + i]).sum();
            }
        }
        let (lds, _) = lds_score(&w, n, m, &subsets, &losses); // τ = +w: inverted
        assert!(lds < -0.99, "anti attributor LDS = {lds}");
    }

    #[test]
    fn random_attributor_scores_near_zero() {
        let (n, m, s_count) = (50, 8, 30);
        let mut rng = Pcg::new(5);
        let w: Vec<f32> = (0..m * n).map(|_| rng.next_gaussian()).collect();
        let noise: Vec<f32> = (0..m * n).map(|_| rng.next_gaussian()).collect();
        let subsets = crate::eval::subsets::sample_subsets(n, s_count, 0.5, 6);
        let mut losses = vec![0.0f32; s_count * m];
        for (s, subset) in subsets.iter().enumerate() {
            for q in 0..m {
                losses[s * m + q] = subset.iter().map(|&i| w[q * n + i]).sum();
            }
        }
        let (lds, _) = lds_score(&noise, n, m, &subsets, &losses);
        assert!(lds.abs() < 0.35, "random attributor LDS = {lds}");
    }

    #[test]
    fn noisy_ground_truth_degrades_gracefully() {
        let (n, m, s_count) = (40, 5, 20);
        let mut rng = Pcg::new(7);
        let w: Vec<f32> = (0..m * n).map(|_| rng.next_gaussian()).collect();
        let subsets = crate::eval::subsets::sample_subsets(n, s_count, 0.5, 8);
        let mut losses = vec![0.0f32; s_count * m];
        for (s, subset) in subsets.iter().enumerate() {
            for q in 0..m {
                let sum: f32 = subset.iter().map(|&i| w[q * n + i]).sum();
                losses[s * m + q] = sum + 2.0 * rng.next_gaussian();
            }
        }
        let tau: Vec<f32> = w.iter().map(|&x| -x).collect();
        let (lds, _) = lds_score(&tau, n, m, &subsets, &losses);
        assert!(lds > 0.3 && lds < 1.0, "noisy LDS = {lds}");
    }
}
