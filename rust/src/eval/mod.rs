//! Counterfactual evaluation: the Linear Datamodeling Score (LDS, Park et
//! al. 2023) with Rust-driven subset retraining through HLO train-step
//! executables. [`subsets`] samples the evaluation subsets; [`lds`] computes
//! the score; [`retrain`] drives SGD through the PJRT runtime.

pub mod lds;
pub mod retrain;
pub mod subsets;

pub use lds::lds_score;
pub use subsets::sample_subsets;
