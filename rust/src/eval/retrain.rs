//! Subset retraining driver: SGD through the model's `train_step` HLO
//! executable, entirely from Rust. Used for LDS ground truth (every subset
//! model) and for producing TRAK checkpoints.

use crate::data::{Labelled, Sequences};
use crate::runtime::{Arg, Executable, Runtime};
use crate::sketch::rng::Pcg;
use anyhow::Result;
use std::sync::Arc;

/// Task data: labelled tensors (MLP / CNN) or token sequences (LMs).
pub enum TaskData<'a> {
    Labelled(&'a Labelled),
    Sequences(&'a Sequences),
}

impl TaskData<'_> {
    pub fn len(&self) -> usize {
        match self {
            TaskData::Labelled(d) => d.n,
            TaskData::Sequences(d) => d.n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A model's training/eval executables bound to the PJRT runtime.
pub struct Trainer {
    pub model: String,
    pub p: usize,
    pub train_batch: usize,
    pub loss_batch: usize,
    pub grads_batch: usize,
    init_exe: Arc<Executable>,
    step_exe: Arc<Executable>,
    loss_exe: Arc<Executable>,
    grads_exe: Arc<Executable>,
    feature_shape: Vec<usize>,
    is_lm: bool,
}

impl Trainer {
    pub fn new(rt: &Runtime, model: &str) -> Result<Self> {
        let meta = rt.manifest.model(model)?;
        let is_lm = meta.seq.is_some();
        // feature shape from the grads artifact's x input (index 1)
        let spec = &rt
            .manifest
            .artifacts
            .get(&format!("{model}_grads"))
            .ok_or_else(|| anyhow::anyhow!("no grads artifact for {model}"))?
            .inputs[1];
        let feature_shape = spec.shape[1..].to_vec();
        Ok(Self {
            model: model.to_string(),
            p: meta.p,
            train_batch: rt.manifest.batch_size("train", model)?,
            loss_batch: rt.manifest.batch_size("loss", model)?,
            grads_batch: rt.manifest.batch_size("grads", model)?,
            init_exe: rt.executable(&format!("{model}_init"))?,
            step_exe: rt.executable(&format!("{model}_train_step"))?,
            loss_exe: rt.executable(&format!("{model}_loss"))?,
            grads_exe: rt.executable(&format!("{model}_grads"))?,
            feature_shape,
            is_lm,
        })
    }

    pub fn init(&self, seed: i32) -> Result<Vec<f32>> {
        Ok(self.init_exe.run(&[Arg::ScalarI32(seed)])?.remove(0).data)
    }

    fn data_args(&self, data: &TaskData, idx: &[usize], batch: usize) -> Vec<Arg> {
        let mut shape = vec![batch];
        shape.extend_from_slice(&self.feature_shape);
        match data {
            TaskData::Labelled(d) => {
                let (x, y) = d.gather(idx, batch);
                vec![Arg::F32(x, shape), Arg::I32(y, vec![batch])]
            }
            TaskData::Sequences(d) => {
                let toks = d.gather(idx, batch);
                vec![Arg::I32(toks, shape)]
            }
        }
    }

    /// SGD over `indices` (shuffled each epoch) for `epochs`; returns the
    /// trained flat parameter vector.
    pub fn train(
        &self,
        mut params: Vec<f32>,
        data: &TaskData,
        indices: &[usize],
        epochs: usize,
        lr: f32,
        seed: u64,
    ) -> Result<Vec<f32>> {
        let mut rng = Pcg::new(seed ^ 0x7124);
        let mut order: Vec<usize> = indices.to_vec();
        let b = self.train_batch;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(b) {
                let mut args = vec![Arg::F32(params, vec![self.p])];
                args.extend(self.data_args(data, chunk, b));
                args.push(Arg::ScalarF32(lr));
                params = self.step_exe.run(&args)?.remove(0).data;
            }
        }
        Ok(params)
    }

    /// Per-sample losses for `indices` (batched; exact count returned).
    pub fn losses(&self, params: &[f32], data: &TaskData, indices: &[usize]) -> Result<Vec<f32>> {
        let b = self.loss_batch;
        let mut out = Vec::with_capacity(indices.len());
        for chunk in indices.chunks(b) {
            let mut args = vec![Arg::F32(params.to_vec(), vec![self.p])];
            args.extend(self.data_args(data, chunk, b));
            let losses = self.loss_exe.run(&args)?.remove(0).data;
            out.extend_from_slice(&losses[..chunk.len()]);
        }
        Ok(out)
    }

    /// Per-sample gradients for `indices`: returns a `len × P` matrix.
    pub fn grads(&self, params: &[f32], data: &TaskData, indices: &[usize]) -> Result<Vec<f32>> {
        let b = self.grads_batch;
        let mut out = Vec::with_capacity(indices.len() * self.p);
        for chunk in indices.chunks(b) {
            let mut args = vec![Arg::F32(params.to_vec(), vec![self.p])];
            args.extend(self.data_args(data, chunk, b));
            let grads = self.grads_exe.run(&args)?.remove(0);
            out.extend_from_slice(&grads.data[..chunk.len() * self.p]);
        }
        Ok(out)
    }

    /// Per-sample gradients with a callback per batch (streaming form used
    /// by the coordinator's cache stage; avoids materialising n × P).
    pub fn grads_streamed(
        &self,
        params: &[f32],
        data: &TaskData,
        indices: &[usize],
        mut sink: impl FnMut(&[usize], &[f32]) -> Result<()>,
    ) -> Result<()> {
        let b = self.grads_batch;
        for chunk in indices.chunks(b) {
            let mut args = vec![Arg::F32(params.to_vec(), vec![self.p])];
            args.extend(self.data_args(data, chunk, b));
            let grads = self.grads_exe.run(&args)?.remove(0);
            sink(chunk, &grads.data[..chunk.len() * self.p])?;
        }
        Ok(())
    }

    pub fn is_lm(&self) -> bool {
        self.is_lm
    }
}

#[cfg(test)]
mod tests {
    // Trainer is exercised end-to-end in rust/tests/integration_attrib.rs
    // (requires artifacts); pure-logic pieces are covered there.
}
