//! The staged cache pipeline (see module docs on [`super`]).

use super::metrics::Metrics;
use crate::data::{Labelled, Sequences};
use crate::runtime::{Arg, Executable, Runtime};
use crate::sketch::sparse::probe;
use crate::sketch::{Compressor, FactorizedCompressor, Scratch, SparseRows};
use crate::store::{PayloadDtype, StoreMeta, StoreWriter};
use anyhow::{anyhow, Result};

pub use crate::sketch::CompressorBank;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Pipeline tuning knobs.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub grad_workers: usize,
    pub compress_workers: usize,
    /// Bounded channel depth — the backpressure horizon.
    pub queue_depth: usize,
    /// Rows per shard file; 0 = derive from `mem_budget` and the bank's
    /// output width (see [`PipelineConfig::effective_shard_rows`]).
    pub shard_rows: usize,
    /// Byte budget hint for the attribute-stage streaming buffers. Used to
    /// auto-size shards when `shard_rows` is 0, so one shard of the cache
    /// this pipeline writes sits comfortably inside the streamed
    /// [`crate::attrib::StreamOpts::mem_budget`] at attribute time.
    pub mem_budget: usize,
    /// Resume an interrupted cache run: inventory the shards an earlier
    /// (killed) run committed to `store_dir`, validate their checksums,
    /// and restart gradient computation from the first missing row instead
    /// of recomputing everything (see [`StoreWriter::resume`]).
    pub resume: bool,
    /// Payload codec the writer encodes shard rows with (`grass cache
    /// --dtype`); f32 is the legacy default.
    pub dtype: PayloadDtype,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            grad_workers: 2,
            compress_workers: 2,
            queue_depth: 4,
            shard_rows: crate::store::DEFAULT_SHARD_ROWS,
            mem_budget: crate::attrib::DEFAULT_MEM_BUDGET,
            resume: false,
            dtype: PayloadDtype::F32,
        }
    }
}

impl PipelineConfig {
    /// Shard size the writer uses: the configured `shard_rows`, or — when
    /// zero — the largest row count keeping one shard of width `k` inside
    /// an eighth of `mem_budget` (clamped to `64..=65536` rows), so the
    /// streaming attribute stage always has several shards per worker to
    /// overlap.
    pub fn effective_shard_rows(&self, k: usize) -> usize {
        if self.shard_rows > 0 {
            return self.shard_rows;
        }
        let budget = if self.mem_budget > 0 {
            self.mem_budget
        } else {
            crate::attrib::DEFAULT_MEM_BUDGET
        };
        (budget / 8 / (4 * k.max(1))).clamp(64, 65536)
    }
}

/// What the grad stage hands to the compress stage. When the bank's
/// kernels can profit from CSR input
/// ([`CompressorBank::sparse_dispatch_viable`]), the grad workers
/// density-[`probe`] each batch (early-exit scan) and convert
/// sparse-enough batches to CSR on their side of the channel, so the
/// compress stage receives the representation its kernels want and the
/// channel carries ~`nnz` floats instead of `n·p` for sparse batches.
enum GradBatch {
    /// Flat per-sample gradients: `len(indices) × dim` rows.
    Flat { first: usize, rows: Vec<f32>, count: usize },
    /// Flat rows in CSR form — density at or below the dispatch crossover.
    SparseFlat {
        first: usize,
        rows: SparseRows,
        count: usize,
    },
    /// LoGra hooks: per-layer (x: count×T×d_in, dy: count×T×d_out).
    Factored {
        first: usize,
        count: usize,
        seq: usize,
        layers: Vec<(Vec<f32>, Vec<f32>)>,
    },
    /// LoGra hooks in CSR form, per factor side, over `count·T` timestep
    /// rows per layer.
    SparseFactored {
        first: usize,
        count: usize,
        seq: usize,
        layers: Vec<(SparseRows, SparseRows)>,
    },
}

/// Data source for the batcher.
pub enum Source<'a> {
    Labelled(&'a Labelled),
    Sequences(&'a Sequences),
}

impl Source<'_> {
    fn len(&self) -> usize {
        match self {
            Source::Labelled(d) => d.n,
            Source::Sequences(d) => d.n,
        }
    }
}

/// The cache pipeline: per-sample gradients → compression → gradient store.
pub struct CachePipeline<'a> {
    pub rt: &'a Runtime,
    pub model: String,
    pub params: Vec<f32>,
    pub cfg: PipelineConfig,
    pub metrics: Arc<Metrics>,
}

impl<'a> CachePipeline<'a> {
    pub fn new(rt: &'a Runtime, model: &str, params: Vec<f32>, cfg: PipelineConfig) -> Self {
        Self {
            rt,
            model: model.to_string(),
            params,
            cfg,
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Run the cache stage through whichever gradient source the bank
    /// calls for: flat per-sample gradients for a flat bank, LoGra hooks
    /// for a factorized one.
    pub fn run(
        &self,
        data: &Source,
        bank: &CompressorBank,
        store_dir: &std::path::Path,
        method: &str,
        seed: u64,
    ) -> Result<StoreMeta> {
        if bank.is_factored() {
            self.run_factored(data, bank, store_dir, method, seed)
        } else {
            self.run_flat(data, bank, store_dir, method, seed)
        }
    }

    /// Run the flat-gradient cache stage over `data`, writing compressed
    /// rows (in dataset order) into `store_dir`.
    pub fn run_flat(
        &self,
        data: &Source,
        bank: &CompressorBank,
        store_dir: &std::path::Path,
        method: &str,
        seed: u64,
    ) -> Result<StoreMeta> {
        let grads_exe = self.rt.executable(&format!("{}_grads", self.model))?;
        let batch = self.rt.manifest.batch_size("grads", &self.model)?;
        self.run_inner(data, bank, store_dir, method, seed, grads_exe, batch, false)
    }

    /// Run the factorized (LoGra hooks) cache stage — FactGraSS's path.
    pub fn run_factored(
        &self,
        data: &Source,
        bank: &CompressorBank,
        store_dir: &std::path::Path,
        method: &str,
        seed: u64,
    ) -> Result<StoreMeta> {
        let hooks_exe = self.rt.executable(&format!("{}_hooks", self.model))?;
        let batch = self.rt.manifest.batch_size("hooks", &self.model)?;
        self.run_inner(data, bank, store_dir, method, seed, hooks_exe, batch, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_inner(
        &self,
        data: &Source,
        bank: &CompressorBank,
        store_dir: &std::path::Path,
        method: &str,
        seed: u64,
        exe: Arc<Executable>,
        batch: usize,
        factored: bool,
    ) -> Result<StoreMeta> {
        let n = data.len();
        let k = bank.output_dim();
        let p = self.rt.manifest.model(&self.model)?.p;
        let meta = self.rt.manifest.model(&self.model)?.clone();
        let metrics = self.metrics.clone();
        // Self-describing store metadata: record the model and gradient
        // geometry alongside the spec string so the attribute stage can
        // rebuild the exact compressor bank (and `open_checked` can reject
        // mismatched readers).
        let target = StoreMeta {
            k,
            n: 0,
            shard_rows: self.cfg.effective_shard_rows(k),
            method: method.to_string(),
            seed,
            model: self.model.clone(),
            input_dim: if factored { 0 } else { p },
            layer_dims: if factored {
                meta.layers.iter().map(|l| (l.d_in, l.d_out)).collect()
            } else {
                vec![]
            },
            density: 1.0,
            dtype: self.cfg.dtype,
        };
        let (writer, committed) = if self.cfg.resume {
            let (w, committed) = StoreWriter::resume(store_dir, &target)?;
            println!(
                "resuming: {committed} rows already committed at {}, continuing from row \
                 {committed}",
                store_dir.display()
            );
            (w, committed)
        } else {
            (StoreWriter::create_described(store_dir, target)?, 0)
        };
        let writer = Mutex::new(writer);
        let seq = meta.seq.unwrap_or(1);
        // Probe dense batches for CSR conversion only when every kernel in
        // the bank can actually win from it (SJLT / LoGra / FactSjlt —
        // kernels whose dense cost scales with the input width). For
        // gather-bound banks (masks, GraSS, FactGraSS) the probe itself
        // would cost more than the dense kernel, so it is skipped.
        let sparse_viable = bank.sparse_dispatch_viable();

        // Stage 1 → 2 channel: index batches.
        let (batch_tx, batch_rx) = sync_channel::<Vec<usize>>(self.cfg.queue_depth);
        let batch_rx = Mutex::new(batch_rx);
        // Stage 2 → 3 channel: gradient payloads.
        let (grad_tx, grad_rx) = sync_channel::<GradBatch>(self.cfg.queue_depth);
        let grad_rx = Mutex::new(grad_rx);
        // Stage 3 → 4 channel: compressed row blocks.
        let (row_tx, row_rx) = sync_channel::<(usize, usize, Vec<f32>)>(self.cfg.queue_depth * 2);

        let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let fail = |e: anyhow::Error| {
            let mut guard = error.lock().unwrap();
            if guard.is_none() {
                *guard = Some(e);
            }
        };

        std::thread::scope(|s| {
            // ---- stage 1: batcher ----
            // Under resume the first `committed` rows are already safely
            // on disk (checksum-validated full shards) — batching restarts
            // at the first missing row.
            s.spawn(|| {
                for start in (committed..n).step_by(batch) {
                    let idx: Vec<usize> = (start..(start + batch).min(n)).collect();
                    if batch_tx.send(idx).is_err() {
                        return;
                    }
                }
                drop(batch_tx);
            });

            // ---- stage 2: grad workers (PJRT) ----
            for _ in 0..self.cfg.grad_workers.max(1) {
                let exe = exe.clone();
                let metrics = metrics.clone();
                let grad_tx: SyncSender<GradBatch> = grad_tx.clone();
                let batch_rx = &batch_rx;
                let params = &self.params;
                let fail = &fail;
                let meta = &meta;
                s.spawn(move || {
                    loop {
                        let idx = match batch_rx.lock().unwrap().recv() {
                            Ok(i) => i,
                            Err(_) => return,
                        };
                        let count = idx.len();
                        let first = idx[0];
                        let t0 = Instant::now();
                        let mut args = vec![Arg::F32(params.clone(), vec![p])];
                        match data {
                            Source::Labelled(d) => {
                                let (x, y) = d.gather(&idx, batch);
                                let mut shape = vec![batch];
                                shape.extend_from_slice(&d.feature_shape);
                                args.push(Arg::F32(x, shape));
                                args.push(Arg::I32(y, vec![batch]));
                            }
                            Source::Sequences(d) => {
                                let toks = d.gather(&idx, batch);
                                args.push(Arg::I32(toks, vec![batch, d.seq]));
                            }
                        }
                        let outputs = match exe.run(&args) {
                            Ok(o) => o,
                            Err(e) => {
                                fail(e);
                                return;
                            }
                        };
                        metrics.add(&metrics.grad_ns, t0.elapsed().as_nanos() as u64);
                        metrics.add(&metrics.batches, 1);
                        metrics.add(&metrics.samples, count as u64);
                        metrics.add(&metrics.tokens, (count * seq) as u64);
                        // Early-exit density probe (viable banks only):
                        // records what it saw for the input-density gauge
                        // and short-circuits to dense on the first buffer
                        // that crosses the crossover.
                        let run_probe = |buf: &[f32], go: &mut bool| {
                            let (sparse, nnz, scanned) = probe(buf);
                            metrics.add(&metrics.input_nnz, nnz as u64);
                            metrics.add(&metrics.input_elems, scanned as u64);
                            *go &= sparse;
                        };
                        let payload = if factored {
                            let l = meta.layers.len();
                            // Per-layer borrowed slices of the PJRT
                            // outputs — probing and the chosen conversion
                            // both read these directly, so no dense copy
                            // is ever made for a sparse-dispatched batch.
                            let sides: Vec<(&[f32], &[f32])> = (0..l)
                                .map(|li| {
                                    let x = &outputs[li];
                                    let dy = &outputs[l + li];
                                    let xw: usize = x.shape[1..].iter().product();
                                    let dw: usize = dy.shape[1..].iter().product();
                                    (&x.data[..count * xw], &dy.data[..count * dw])
                                })
                                .collect();
                            let mut go_sparse = sparse_viable;
                            for &(xd, dyd) in &sides {
                                if go_sparse {
                                    run_probe(xd, &mut go_sparse);
                                }
                                if go_sparse {
                                    run_probe(dyd, &mut go_sparse);
                                }
                            }
                            if go_sparse {
                                metrics.add(&metrics.sparse_batches, 1);
                                let layers = sides
                                    .iter()
                                    .map(|&(xd, dyd)| {
                                        let d_in = xd.len() / (count * seq);
                                        let d_out = dyd.len() / (count * seq);
                                        (
                                            SparseRows::from_dense_threshold(
                                                xd,
                                                count * seq,
                                                d_in,
                                                0.0,
                                            ),
                                            SparseRows::from_dense_threshold(
                                                dyd,
                                                count * seq,
                                                d_out,
                                                0.0,
                                            ),
                                        )
                                    })
                                    .collect();
                                GradBatch::SparseFactored {
                                    first,
                                    count,
                                    seq,
                                    layers,
                                }
                            } else {
                                metrics.add(&metrics.dense_batches, 1);
                                let layers = sides
                                    .iter()
                                    .map(|&(xd, dyd)| (xd.to_vec(), dyd.to_vec()))
                                    .collect();
                                GradBatch::Factored {
                                    first,
                                    count,
                                    seq,
                                    layers,
                                }
                            }
                        } else {
                            let rows = &outputs[0].data[..count * p];
                            let mut go_sparse = sparse_viable;
                            if go_sparse {
                                run_probe(rows, &mut go_sparse);
                            }
                            if go_sparse {
                                metrics.add(&metrics.sparse_batches, 1);
                                GradBatch::SparseFlat {
                                    first,
                                    rows: SparseRows::from_dense_threshold(rows, count, p, 0.0),
                                    count,
                                }
                            } else {
                                metrics.add(&metrics.dense_batches, 1);
                                GradBatch::Flat {
                                    first,
                                    rows: rows.to_vec(),
                                    count,
                                }
                            }
                        };
                        if grad_tx.send(payload).is_err() {
                            return;
                        }
                    }
                });
            }
            drop(grad_tx);

            // ---- stage 3: compress workers ----
            // Batch-first: each worker owns a reusable Scratch workspace and
            // hands the whole GradBatch to the tuned batch kernels — one
            // call per batch (flat) or per layer (factored), instead of the
            // old per-sample loop. Only the output block (the channel
            // payload) is allocated per batch; every kernel temporary is
            // recycled through the worker's scratch.
            for _ in 0..self.cfg.compress_workers.max(1) {
                let metrics = metrics.clone();
                let row_tx = row_tx.clone();
                let grad_rx = &grad_rx;
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    loop {
                        let gb = match grad_rx.lock().unwrap().recv() {
                            Ok(g) => g,
                            Err(_) => return,
                        };
                        let t0 = Instant::now();
                        let (first, count, rows) = match gb {
                            GradBatch::Flat { first, rows, count } => {
                                let c: &dyn Compressor = match bank {
                                    CompressorBank::Flat(c) => c.as_ref(),
                                    _ => unreachable!("flat batch with factored bank"),
                                };
                                let mut out = vec![0.0f32; count * k];
                                c.compress_batch_with(
                                    &rows[..count * p],
                                    count,
                                    &mut out,
                                    &mut scratch,
                                );
                                (first, count, out)
                            }
                            GradBatch::SparseFlat { first, rows, count } => {
                                let c: &dyn Compressor = match bank {
                                    CompressorBank::Flat(c) => c.as_ref(),
                                    _ => unreachable!("flat batch with factored bank"),
                                };
                                let mut out = vec![0.0f32; count * k];
                                c.compress_sparse_batch_with(&rows, &mut out, &mut scratch);
                                (first, count, out)
                            }
                            GradBatch::SparseFactored {
                                first,
                                count,
                                seq,
                                layers,
                            } => {
                                let cs: &[Box<dyn FactorizedCompressor>] = match bank {
                                    CompressorBank::Factored(cs) => cs,
                                    _ => unreachable!("factored batch with flat bank"),
                                };
                                let mut out = vec![0.0f32; count * k];
                                let mut off = 0usize;
                                for (li, c) in cs.iter().enumerate() {
                                    let (x, dy) = &layers[li];
                                    c.compress_sparse_batch_with(
                                        count,
                                        seq,
                                        x,
                                        dy,
                                        &mut out,
                                        k,
                                        off,
                                        &mut scratch,
                                    );
                                    off += c.output_dim();
                                }
                                (first, count, out)
                            }
                            GradBatch::Factored {
                                first,
                                count,
                                seq,
                                layers,
                            } => {
                                let cs: &[Box<dyn FactorizedCompressor>] = match bank {
                                    CompressorBank::Factored(cs) => cs,
                                    _ => unreachable!("factored batch with flat bank"),
                                };
                                let mut out = vec![0.0f32; count * k];
                                let mut off = 0usize;
                                for (li, c) in cs.iter().enumerate() {
                                    let (x, dy) = &layers[li];
                                    c.compress_batch_with(
                                        count,
                                        seq,
                                        x,
                                        dy,
                                        &mut out,
                                        k,
                                        off,
                                        &mut scratch,
                                    );
                                    off += c.output_dim();
                                }
                                (first, count, out)
                            }
                        };
                        metrics.add(&metrics.compress_ns, t0.elapsed().as_nanos() as u64);
                        if row_tx.send((first, count, rows)).is_err() {
                            return;
                        }
                    }
                });
            }
            drop(row_tx);

            // ---- stage 4: writer with reorder buffer ----
            let written = AtomicUsize::new(0);
            let writer_ref = &writer;
            let metrics2 = metrics.clone();
            let fail2 = &fail;
            s.spawn(move || {
                let rx: Receiver<(usize, usize, Vec<f32>)> = row_rx;
                let mut pending: BTreeMap<usize, (usize, Vec<f32>)> = BTreeMap::new();
                let mut next = committed;
                // Reorder-buffer accounting: pending bytes are bounded in
                // practice by queue_depth × batch, and the observed peak is
                // surfaced through metrics so the bound stays checkable.
                let mut pending_bytes = 0usize;
                let flush = |pending: &mut BTreeMap<usize, (usize, Vec<f32>)>,
                                 next: &mut usize,
                                 pending_bytes: &mut usize|
                 -> Result<()> {
                    while let Some((count, rows)) = pending.remove(next) {
                        *pending_bytes -= rows.len() * 4;
                        let t0 = Instant::now();
                        let mut w = writer_ref.lock().unwrap();
                        w.push_batch(&rows)?;
                        metrics2.add(&metrics2.write_ns, t0.elapsed().as_nanos() as u64);
                        metrics2.add(&metrics2.rows_written, count as u64);
                        written.fetch_add(count, Ordering::Relaxed);
                        *next += count;
                    }
                    Ok(())
                };
                for (first, count, rows) in rx.iter() {
                    pending_bytes += rows.len() * 4;
                    metrics2.set_peak(&metrics2.reorder_peak_bytes, pending_bytes as u64);
                    pending.insert(first, (count, rows));
                    if let Err(e) = flush(&mut pending, &mut next, &mut pending_bytes) {
                        fail2(e);
                        return;
                    }
                }
                if let Err(e) = flush(&mut pending, &mut next, &mut pending_bytes) {
                    fail2(e);
                }
            });
        });

        if let Some(e) = error.into_inner().unwrap() {
            return Err(e);
        }
        let meta = writer.into_inner().unwrap().finish()?;
        if meta.n != n {
            return Err(anyhow!("pipeline wrote {} rows, expected {n}", meta.n));
        }
        Ok(meta)
    }
}
