//! Pipeline metrics: atomic counters sampled by the leader, plus a
//! throughput report matching the paper's Table 2 units (tokens/s for LMs,
//! samples/s otherwise).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    pub samples: AtomicU64,
    pub tokens: AtomicU64,
    pub batches: AtomicU64,
    pub rows_written: AtomicU64,
    /// Nanoseconds spent inside each stage (summed across workers).
    pub grad_ns: AtomicU64,
    pub compress_ns: AtomicU64,
    pub write_ns: AtomicU64,
    /// Peak bytes held by the writer's reorder buffer — the pipeline's
    /// only unbounded-looking allocation, surfaced so the memory model in
    /// docs/ARCHITECTURE.md stays checkable.
    pub reorder_peak_bytes: AtomicU64,
    /// Batches the density probe routed through the CSR (sparse) kernels.
    pub sparse_batches: AtomicU64,
    /// Batches routed through the dense batch kernels.
    pub dense_batches: AtomicU64,
    /// Non-zero gradient elements seen by the density probe.
    pub input_nnz: AtomicU64,
    /// Total gradient elements seen by the density probe.
    pub input_elems: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            samples: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows_written: AtomicU64::new(0),
            grad_ns: AtomicU64::new(0),
            compress_ns: AtomicU64::new(0),
            write_ns: AtomicU64::new(0),
            reorder_peak_bytes: AtomicU64::new(0),
            sparse_batches: AtomicU64::new(0),
            dense_batches: AtomicU64::new(0),
            input_nnz: AtomicU64::new(0),
            input_elems: AtomicU64::new(0),
        }
    }

    /// Observed input density across all batches (1.0 when the probe saw
    /// nothing, so dense-only runs read as fully dense).
    pub fn input_density(&self) -> f64 {
        let elems = self.input_elems.load(Ordering::Relaxed);
        if elems == 0 {
            1.0
        } else {
            self.input_nnz.load(Ordering::Relaxed) as f64 / elems as f64
        }
    }

    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Raise a high-water-mark gauge to `v` if it is the new peak.
    pub fn set_peak(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_max(v, Ordering::Relaxed);
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn samples_per_sec(&self) -> f64 {
        self.samples.load(Ordering::Relaxed) as f64 / self.elapsed_secs().max(1e-9)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens.load(Ordering::Relaxed) as f64 / self.elapsed_secs().max(1e-9)
    }

    pub fn report(&self) -> String {
        let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
        format!(
            "samples={} tokens={} batches={} rows_written={} elapsed={:.2}s \
             throughput={:.1} samples/s ({:.0} tok/s) | stage-time grad={:.2}s \
             compress={:.2}s write={:.2}s | reorder-peak={}KB | \
             dispatch sparse={} dense={} input-density={:.4}",
            load(&self.samples),
            load(&self.tokens),
            load(&self.batches),
            load(&self.rows_written),
            self.elapsed_secs(),
            self.samples_per_sec(),
            self.tokens_per_sec(),
            load(&self.grad_ns) as f64 / 1e9,
            load(&self.compress_ns) as f64 / 1e9,
            load(&self.write_ns) as f64 / 1e9,
            load(&self.reorder_peak_bytes) / 1024,
            load(&self.sparse_batches),
            load(&self.dense_batches),
            self.input_density(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add(&m.samples, 10);
        m.add(&m.samples, 5);
        m.add(&m.tokens, 640);
        assert_eq!(m.samples.load(Ordering::Relaxed), 15);
        assert!(m.samples_per_sec() > 0.0);
        assert!(m.report().contains("samples=15"));
    }

    #[test]
    fn input_density_gauge() {
        let m = Metrics::new();
        assert_eq!(m.input_density(), 1.0, "no observations reads as dense");
        m.add(&m.input_nnz, 25);
        m.add(&m.input_elems, 1000);
        assert!((m.input_density() - 0.025).abs() < 1e-12);
        m.add(&m.sparse_batches, 1);
        assert!(m.report().contains("sparse=1"));
        assert!(m.report().contains("input-density=0.025"));
    }
}
