//! The cache-stage coordinator — GraSS's L3 runtime contribution.
//!
//! Pipeline (all stages bounded, so a slow stage backpressures upstream):
//!
//! ```text
//! batcher ──(sync_channel)──▶ grad workers ──(sync_channel)──▶ compress
//!  (index     depth=Q          (PJRT execute,   depth=Q         workers
//!   batches)                    G threads)                      (C threads)
//!                                                                  │
//!                                             writer ◀─(channel)───┘
//!                                     (reorder buffer → StoreWriter)
//! ```
//!
//! Two gradient sources implement the same pipeline: flat per-sample
//! gradients (`<model>_grads` HLO) compressed by a [`Compressor`], and the
//! LoGra hook source (`<model>_hooks` HLO) compressed per layer by
//! [`FactorizedCompressor`]s — the FactGraSS path that never materialises
//! the full gradient.

pub mod metrics;
pub mod pipeline;

pub use metrics::Metrics;
pub use pipeline::{CachePipeline, CompressorBank, PipelineConfig};
