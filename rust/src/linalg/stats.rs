//! Correlation statistics for the LDS evaluation (Park et al. 2023): the
//! linear datamodeling score is a mean Spearman rank correlation between
//! predicted group scores and actual counterfactual losses.

/// Pearson correlation; returns 0 for degenerate (constant) inputs.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let am = a.iter().map(|&x| x as f64).sum::<f64>() / nf;
    let bm = b.iter().map(|&x| x as f64).sum::<f64>() / nf;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for i in 0..n {
        let da = a[i] as f64 - am;
        let db = b[i] as f64 - bm;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va < 1e-18 || vb < 1e-18 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Fractional ranks with average tie handling.
pub fn ranks(x: &[f32]) -> Vec<f64> {
    let n = x.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| x[i].partial_cmp(&x[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut r = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && x[order[j + 1]] == x[order[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &oi in &order[i..=j] {
            r[oi] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation.
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    let ra: Vec<f32> = ranks(a).into_iter().map(|x| x as f32).collect();
    let rb: Vec<f32> = ranks(b).into_iter().map(|x| x as f32).collect();
    pearson(&ra, &rb)
}

/// Mean of a slice of f64 (NaNs filtered).
pub fn mean(xs: &[f64]) -> f64 {
    let good: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if good.is_empty() {
        return 0.0;
    }
    good.iter().sum::<f64>() / good.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_and_anti() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [8.0f32, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0; 4], &[1.0, 2.0, 3.0, 4.0]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0f32, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ranks_handle_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_is_permutation_sensitive() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [2.0f32, 1.0, 4.0, 3.0, 6.0, 5.0];
        let s = spearman(&a, &b);
        assert!(s > 0.5 && s < 1.0, "s = {s}");
    }

    #[test]
    fn mean_filters_nan() {
        assert_eq!(mean(&[1.0, f64::NAN, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
