//! Blocked, thread-parallel matmuls for the factorized compressors.
//!
//! LoGra's hot loop is `Y = X Pᵀ` (activations × projection factors) and the
//! Kronecker reconstruction is `A = XᵀD`. These are modest sizes
//! (T ≤ 4096, d ≤ 14336, k ≤ 128) so a cache-blocked loop with f32
//! accumulate is within ~2-3× of a tuned BLAS while keeping the crate
//! dependency-free; the Table 2 comparison is method-vs-method on the same
//! matmul substrate, so the *ratio* (what the paper reports) is preserved.

use crate::util::par;

/// `C(m×n) = A(m×t) · B(t×n)`, all row-major. `C` is overwritten.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, t: usize, n: usize) {
    assert_eq!(a.len(), m * t);
    assert_eq!(b.len(), t * n);
    assert_eq!(c.len(), m * n);
    let do_row = |i: usize, crow: &mut [f32]| {
        crow.fill(0.0);
        let arow = &a[i * t..(i + 1) * t];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    };
    if m * t * n < (1 << 16) {
        for (i, crow) in c.chunks_mut(n).enumerate() {
            do_row(i, crow);
        }
    } else {
        par::par_chunks_mut(c, n, 1, |start_row, chunk| {
            for (off, crow) in chunk.chunks_mut(n).enumerate() {
                do_row(start_row + off, crow);
            }
        });
    }
}

/// `C(m×n) = Aᵀ(m×t) · B(t×n)` where `A` is stored `t×m` row-major — the
/// Kronecker reconstruction `XᵀD` without transposing X.
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], t: usize, m: usize, n: usize) {
    assert_eq!(a.len(), t * m);
    assert_eq!(b.len(), t * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // Rank-1 update per row of A/B: C += a_rowᵀ ⊗ b_row. Sequential over t,
    // vectorised over n; parallel over output rows when large.
    if m * n < (1 << 14) {
        for r in 0..t {
            let arow = &a[r * m..(r + 1) * m];
            let brow = &b[r * n..(r + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    } else {
        par::par_chunks_mut(c, n, 1, |start_row, chunk| {
            for (off, crow) in chunk.chunks_mut(n).enumerate() {
                let i = start_row + off;
                for r in 0..t {
                    let av = a[r * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[r * n..(r + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn naive(a: &[f32], b: &[f32], m: usize, t: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..t {
                    s += a[i * t + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let (m, t, n) = (13, 29, 17);
        let mut rng = Pcg::new(1);
        let a: Vec<f32> = (0..m * t).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| rng.next_gaussian()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul(&a, &b, &mut c, m, t, n);
        let want = naive(&a, &b, m, t, n);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let (m, t, n) = (64, 128, 64); // above the parallel threshold
        let mut rng = Pcg::new(2);
        let a: Vec<f32> = (0..m * t).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| rng.next_gaussian()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul(&a, &b, &mut c, m, t, n);
        let want = naive(&a, &b, m, t, n);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let (t, m, n) = (21, 11, 9);
        let mut rng = Pcg::new(3);
        let a: Vec<f32> = (0..t * m).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| rng.next_gaussian()).collect();
        // explicit Aᵀ
        let mut at = vec![0.0f32; m * t];
        for r in 0..t {
            for i in 0..m {
                at[i * t + r] = a[r * m + i];
            }
        }
        let want = naive(&at, &b, m, t, n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b(&a, &b, &mut c, t, m, n);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-3);
        }
    }
}
