//! Blocked, thread-parallel matmuls for the compression and scoring hot
//! paths.
//!
//! Three shapes cover every dense kernel in the crate, all built on the
//! shared microkernels in `micro`:
//!
//! * [`matmul`] — `C = A·B` (row-major), rank-1 updates via `micro::axpy`
//!   with 4-row register blocking so each `B` row is streamed once per four
//!   output rows. Used by the dense Gaussian batch projection.
//! * [`matmul_at_b`] — `C = Aᵀ·B` with `A` stored `t×m`, the Kronecker
//!   reconstruction `XᵀD` of the factorized compressors, also on
//!   `micro::axpy`.
//! * [`matmul_abt`] — `C = A·Bᵀ` with both operands row-major, i.e. an
//!   all-pairs dot product. This is the scoring GEMM
//!   (`scores[q][i] = ⟨g_q, g_i⟩`) and the LoGra factor projection
//!   (`Y = X·Pᵀ`); it runs a register-tiled 4×4 microkernel
//!   (`micro::dot4x4`) so sixteen accumulators stay in registers across
//!   the shared inner dimension.
//!
//! These are modest sizes (T ≤ 4096, d ≤ 14336, k ≤ 8192), so the blocked
//! loops land within a small factor of a tuned BLAS while keeping the crate
//! dependency-free; Table 2 compares method-vs-method on the same matmul
//! substrate, so the *ratio* the paper reports is preserved.
//!
//! The microkernels themselves live in [`crate::linalg::simd`]: `micro`
//! below is a thin façade over the runtime-dispatched `simd::axpy` /
//! `simd::dot4x4` / `simd::dot_tile`, which pick AVX2+FMA, NEON, or the
//! 8-wide-unrolled scalar reference once per process (and honour the
//! `--no-simd` / `GRASS_NO_SIMD=1` escape hatch). The dot kernels skip
//! vector dispatch below `simd::MIN_SIMD_K` shared-dimension elements —
//! tiny-`k` edge tiles can't amortise vector setup — so the blocked loops
//! here never need size checks of their own.

use crate::util::par;

/// Shared microkernels: every GEMM shape reduces to one of these inner
/// loops. Each delegates to the runtime-dispatched kernel in
/// [`crate::linalg::simd`], so ISA selection lands in one place.
pub(crate) mod micro {
    use crate::linalg::simd;

    /// `c += a · b` over one row — the rank-1 row update shared by
    /// [`super::matmul`] and [`super::matmul_at_b`].
    #[inline(always)]
    pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
        simd::axpy(c, a, b);
    }

    /// Register-tiled 4×4 dot-product block over a shared inner dimension:
    /// `acc[ii][jj] += Σ_k a[ii][k] · b[jj][k]`. The sixteen accumulators
    /// live in registers for the whole `kdim` sweep.
    #[inline(always)]
    pub fn dot4x4(a: [&[f32]; 4], b: [&[f32]; 4], kdim: usize, acc: &mut [[f32; 4]; 4]) {
        simd::dot4x4(a, b, kdim, acc);
    }

    /// Edge-tile fallback for [`dot4x4`]: `ib×jb` block with `ib, jb ≤ 4`.
    #[inline(always)]
    pub fn dot_tile(
        a: &[f32],
        b: &[f32],
        kdim: usize,
        ib: usize,
        jb: usize,
        acc: &mut [[f32; 4]; 4],
    ) {
        simd::dot_tile(a, b, kdim, ib, jb, acc);
    }
}

/// `C(m×n) = A(m×t) · B(t×n)`, all row-major. `C` is overwritten.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, t: usize, n: usize) {
    assert_eq!(a.len(), m * t);
    assert_eq!(b.len(), t * n);
    assert_eq!(c.len(), m * n);
    let do_block = |row0: usize, crows: &mut [f32]| {
        crows.fill(0.0);
        for (bi, band) in crows.chunks_mut(4 * n).enumerate() {
            let i0 = row0 + 4 * bi;
            if band.len() == 4 * n {
                // 4-row register block: each B row is loaded once for four
                // output rows. The zero-skip preserves the nnz-scaling of
                // sparse gradient batches.
                let (r0, rest) = band.split_at_mut(n);
                let (r1, rest) = rest.split_at_mut(n);
                let (r2, r3) = rest.split_at_mut(n);
                for kk in 0..t {
                    let brow = &b[kk * n..(kk + 1) * n];
                    let base = i0 * t + kk;
                    let (a0, a1, a2, a3) = (a[base], a[base + t], a[base + 2 * t], a[base + 3 * t]);
                    if a0 != 0.0 {
                        micro::axpy(r0, a0, brow);
                    }
                    if a1 != 0.0 {
                        micro::axpy(r1, a1, brow);
                    }
                    if a2 != 0.0 {
                        micro::axpy(r2, a2, brow);
                    }
                    if a3 != 0.0 {
                        micro::axpy(r3, a3, brow);
                    }
                }
            } else {
                for (ri, crow) in band.chunks_mut(n).enumerate() {
                    let arow = &a[(i0 + ri) * t..(i0 + ri + 1) * t];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        micro::axpy(crow, av, &b[kk * n..(kk + 1) * n]);
                    }
                }
            }
        }
    };
    if m * t * n < (1 << 16) {
        do_block(0, c);
    } else {
        par::par_chunks_mut(c, n, 1, |start_row, chunk| do_block(start_row, chunk));
    }
}

/// `C(m×n) = Aᵀ(m×t) · B(t×n)` where `A` is stored `t×m` row-major — the
/// Kronecker reconstruction `XᵀD` without transposing X.
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], t: usize, m: usize, n: usize) {
    assert_eq!(a.len(), t * m);
    assert_eq!(b.len(), t * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // Rank-1 update per row of A/B: C += a_rowᵀ ⊗ b_row. Sequential over t,
    // vectorised over n; parallel over output rows when large.
    if m * n < (1 << 14) {
        for r in 0..t {
            let arow = &a[r * m..(r + 1) * m];
            let brow = &b[r * n..(r + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                micro::axpy(&mut c[i * n..(i + 1) * n], av, brow);
            }
        }
    } else {
        par::par_chunks_mut(c, n, 1, |start_row, chunk| {
            for (off, crow) in chunk.chunks_mut(n).enumerate() {
                let i = start_row + off;
                for r in 0..t {
                    let av = a[r * m + i];
                    if av == 0.0 {
                        continue;
                    }
                    micro::axpy(crow, av, &b[r * n..(r + 1) * n]);
                }
            }
        });
    }
}

/// `C(m×n) = A(m×k) · Bᵀ` with `B` stored `n×k` row-major — the all-pairs
/// dot-product GEMM. `C` is overwritten.
///
/// This is the attribute-stage scoring kernel (`queries × cache`) and the
/// LoGra factor projection; it replaces the naive triple loop with a
/// parallel, register-tiled blocked GEMM (4×4 tiles via `micro::dot4x4`).
pub fn matmul_abt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, kdim: usize, n: usize) {
    assert_eq!(a.len(), m * kdim);
    assert_eq!(b.len(), n * kdim);
    assert_eq!(c.len(), m * n);
    let do_block = |row0: usize, crows: &mut [f32]| {
        let rows = crows.len() / n;
        let mut i = 0;
        while i < rows {
            let ib = (rows - i).min(4);
            let ai = row0 + i;
            let mut j = 0;
            while j < n {
                let jb = (n - j).min(4);
                let mut acc = [[0.0f32; 4]; 4];
                if ib == 4 && jb == 4 {
                    let ar = [
                        &a[ai * kdim..(ai + 1) * kdim],
                        &a[(ai + 1) * kdim..(ai + 2) * kdim],
                        &a[(ai + 2) * kdim..(ai + 3) * kdim],
                        &a[(ai + 3) * kdim..(ai + 4) * kdim],
                    ];
                    let br = [
                        &b[j * kdim..(j + 1) * kdim],
                        &b[(j + 1) * kdim..(j + 2) * kdim],
                        &b[(j + 2) * kdim..(j + 3) * kdim],
                        &b[(j + 3) * kdim..(j + 4) * kdim],
                    ];
                    micro::dot4x4(ar, br, kdim, &mut acc);
                } else {
                    micro::dot_tile(
                        &a[ai * kdim..(ai + ib) * kdim],
                        &b[j * kdim..(j + jb) * kdim],
                        kdim,
                        ib,
                        jb,
                        &mut acc,
                    );
                }
                for ii in 0..ib {
                    let crow = &mut crows[(i + ii) * n..(i + ii + 1) * n];
                    crow[j..j + jb].copy_from_slice(&acc[ii][..jb]);
                }
                j += jb;
            }
            i += ib;
        }
    };
    if m * n * kdim < (1 << 16) {
        do_block(0, c);
    } else {
        par::par_chunks_mut(c, n, 1, |start_row, chunk| do_block(start_row, chunk));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn naive(a: &[f32], b: &[f32], m: usize, t: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..t {
                    s += a[i * t + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let (m, t, n) = (13, 29, 17);
        let mut rng = Pcg::new(1);
        let a: Vec<f32> = (0..m * t).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| rng.next_gaussian()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul(&a, &b, &mut c, m, t, n);
        let want = naive(&a, &b, m, t, n);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let (m, t, n) = (64, 128, 64); // above the parallel threshold
        let mut rng = Pcg::new(2);
        let a: Vec<f32> = (0..m * t).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| rng.next_gaussian()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul(&a, &b, &mut c, m, t, n);
        let want = naive(&a, &b, m, t, n);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let (t, m, n) = (21, 11, 9);
        let mut rng = Pcg::new(3);
        let a: Vec<f32> = (0..t * m).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| rng.next_gaussian()).collect();
        // explicit Aᵀ
        let mut at = vec![0.0f32; m * t];
        for r in 0..t {
            for i in 0..m {
                at[i * t + r] = a[r * m + i];
            }
        }
        let want = naive(&at, &b, m, t, n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b(&a, &b, &mut c, t, m, n);
        for i in 0..m * n {
            assert!((c[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn abt_matches_explicit_transpose() {
        // exercises the full-4×4 tile, both edge tiles, and the remainder
        for (m, kdim, n) in [(9, 33, 7), (4, 16, 4), (1, 5, 1), (13, 64, 21)] {
            let mut rng = Pcg::new(4 + m as u64);
            let a: Vec<f32> = (0..m * kdim).map(|_| rng.next_gaussian()).collect();
            let b: Vec<f32> = (0..n * kdim).map(|_| rng.next_gaussian()).collect();
            // explicit Bᵀ (kdim×n)
            let mut bt = vec![0.0f32; kdim * n];
            for r in 0..n {
                for kk in 0..kdim {
                    bt[kk * n + r] = b[r * kdim + kk];
                }
            }
            let want = naive(&a, &bt, m, kdim, n);
            let mut c = vec![0.0f32; m * n];
            matmul_abt(&a, &b, &mut c, m, kdim, n);
            for i in 0..m * n {
                assert!(
                    (c[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
                    "({m},{kdim},{n}) at {i}: {} vs {}",
                    c[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn scalar_dot4x4_tile_matches_naive() {
        // Regression pin for the rewritten scalar microkernel (the per-kk
        // av/bv temp arrays are gone; the tile is now sixteen 8-wide
        // unrolled dot products): exact shape change, same results.
        use crate::linalg::simd;
        for kdim in [1usize, 7, 8, 64, 257] {
            let mut rng = Pcg::new(40 + kdim as u64);
            let rows: Vec<Vec<f32>> = (0..8)
                .map(|_| (0..kdim).map(|_| rng.next_gaussian()).collect())
                .collect();
            let a = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
            let b = [&rows[4][..], &rows[5][..], &rows[6][..], &rows[7][..]];
            let mut acc = [[0.0f32; 4]; 4];
            simd::scalar::dot4x4(a, b, kdim, &mut acc);
            for ii in 0..4 {
                for jj in 0..4 {
                    let mut want = 0.0f64;
                    let mut cond = 0.0f64;
                    for kk in 0..kdim {
                        let p = a[ii][kk] as f64 * b[jj][kk] as f64;
                        want += p;
                        cond += p.abs();
                    }
                    assert!(
                        (acc[ii][jj] as f64 - want).abs() <= 1e-6 * (1.0 + cond),
                        "kdim={kdim} ({ii},{jj}): {} vs {want}",
                        acc[ii][jj]
                    );
                }
            }
        }
    }

    #[test]
    fn abt_parallel_path_matches() {
        let (m, kdim, n) = (37, 96, 53); // m·n·k above the parallel threshold
        let mut rng = Pcg::new(9);
        let a: Vec<f32> = (0..m * kdim).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..n * kdim).map(|_| rng.next_gaussian()).collect();
        let mut c = vec![0.0f32; m * n];
        matmul_abt(&a, &b, &mut c, m, kdim, n);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = a[i * kdim..(i + 1) * kdim]
                    .iter()
                    .zip(&b[j * kdim..(j + 1) * kdim])
                    .map(|(x, y)| x * y)
                    .sum();
                assert!(
                    (c[i * n + j] - want).abs() < 1e-2 * (1.0 + want.abs()),
                    "({i},{j}): {} vs {}",
                    c[i * n + j],
                    want
                );
            }
        }
    }
}
