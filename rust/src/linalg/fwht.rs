//! Fast Walsh–Hadamard transform — the `H` in the FJLT's `P·H·D` sandwich.
//! In-place, O(n log n), n must be a power of two. Normalised by `1/√n` so
//! the transform is orthonormal (applying it twice gives the identity).
//!
//! Each butterfly stage runs through [`crate::linalg::simd::fwht_butterfly`]
//! on the paired half-blocks, so the stage is vectorized whenever the
//! half-block length `h` covers at least one vector lane group; the
//! per-element arithmetic is identical to the scalar loop (bit-compatible).

use crate::linalg::simd;

/// In-place orthonormal FWHT. Panics unless `data.len()` is a power of two.
pub fn fwht_inplace(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for block in data.chunks_exact_mut(h * 2) {
            let (lo, hi) = block.split_at_mut(h);
            simd::fwht_butterfly(lo, hi);
        }
        h *= 2;
    }
    simd::scale_inplace(data, 1.0 / (n as f32).sqrt());
}

/// Next power of two ≥ n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn involution() {
        let orig: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn preserves_l2_norm() {
        let orig: Vec<f32> = (0..256).map(|i| ((i * i) as f32 * 0.01).cos()).collect();
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        let n0: f64 = orig.iter().map(|&v| (v as f64).powi(2)).sum();
        let n1: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((n0 - n1).abs() < 1e-3 * n0);
    }

    #[test]
    fn matches_naive_hadamard_small() {
        // H_4 (unnormalised) rows: ++++, +-+-, ++--, +--+
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        fwht_inplace(&mut x);
        let expect = [10.0f32, -2.0, -4.0, 0.0].map(|v| v / 2.0);
        for (a, b) in x.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        fwht_inplace(&mut [1.0, 2.0, 3.0]);
    }
}
