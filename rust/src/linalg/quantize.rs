//! Scalar quantization kernels behind the store payload codecs: IEEE
//! binary16 (f16) and bfloat16 conversions with round-to-nearest-even,
//! plus symmetric int8 row quantization against a per-row absmax scale.
//! The framing (row layout, scale headers, dtype tags) lives in
//! [`crate::store::quant`]; this module is the pure numeric inner loops
//! the dequant-on-read path runs per element, kept in `linalg` next to
//! the matmuls that consume the decoded tiles. The bulk decode loops
//! (f16/bf16/int8 → f32) dispatch through [`crate::linalg::simd`].

/// Convert an `f32` to IEEE binary16 bits, rounding to nearest even.
/// Overflow saturates to ±inf, underflow denormalizes and then flushes
/// to ±0; NaNs stay NaN (quiet bit forced so the payload can't vanish).
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN: truncate the payload, forcing a quiet bit for NaN.
        let quiet = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | quiet | (man >> 13) as u16;
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // below the smallest subnormal → ±0
        }
        // Subnormal: shift the implicit leading 1 into the mantissa and
        // round to nearest even at the shifted position.
        let m = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (m + (half - 1) + ((m >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    // Normal: round the 23-bit mantissa to 10 bits, nearest even; a
    // mantissa carry bumps the exponent (possibly into ±inf).
    let rounded = man + 0x0fff + ((man >> 13) & 1);
    let mut e16 = e as u32;
    let mut m16 = rounded >> 13;
    if m16 & 0x400 != 0 {
        m16 = 0;
        e16 += 1;
        if e16 >= 0x1f {
            return sign | 0x7c00;
        }
    }
    sign | ((e16 << 10) as u16) | (m16 as u16)
}

/// Convert IEEE binary16 bits back to `f32` (exact: every f16 value is
/// representable in f32).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // ±0 or subnormal: man × 2⁻²⁴, an exact power-of-two scale.
        let v = man as f32 * f32::from_bits(0x3380_0000); // 2^-24
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp + 127 - 15) << 23) | (man << 13))
}

/// Convert an `f32` to bfloat16 bits (top 16 bits of the f32 layout),
/// rounding to nearest even. NaNs keep a quiet payload bit.
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if bits & 0x7fff_ffff > 0x7f80_0000 {
        return ((bits >> 16) as u16) | 0x0040; // NaN stays NaN
    }
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Convert bfloat16 bits back to `f32` (exact by construction).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// The symmetric per-row int8 scale: `absmax / 127`, so the full ±127
/// code range covers the row. Zero rows (and all-zero gradients) get a
/// zero scale, which round-trips every element exactly to 0.
#[inline]
pub fn i8_row_scale(row: &[f32]) -> f32 {
    let mut absmax = 0.0f32;
    for &v in row {
        let a = v.abs();
        if a > absmax {
            absmax = a;
        }
    }
    absmax / 127.0
}

/// Quantize a row to int8 codes against `scale` (as from
/// [`i8_row_scale`]), appending one byte per element. Codes saturate at
/// ±127; non-finite inputs collapse to 0 via Rust's saturating cast.
#[inline]
pub fn quantize_i8(row: &[f32], scale: f32, out: &mut Vec<u8>) {
    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
    for &v in row {
        let q = (v * inv).round().clamp(-127.0, 127.0) as i8;
        out.push(q as u8);
    }
}

/// Dequantize int8 codes back to `f32` against the row's scale. Routes
/// through the runtime-dispatched [`crate::linalg::simd::dequant_i8`]
/// kernel — one exact widening convert plus one multiply per element on
/// every ISA, so the result is identical to the scalar loop bit-for-bit.
#[inline]
pub fn dequantize_i8(bytes: &[u8], scale: f32, out: &mut [f32]) {
    crate::linalg::simd::dequant_i8(bytes, scale, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn f16_roundtrip_exact_values_and_edge_cases() {
        // Exactly representable values survive the roundtrip bit-perfectly.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "{v}");
        }
        // Signed zero keeps its sign bit.
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        // Overflow saturates to ±inf.
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xfc00);
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
        // NaN stays NaN.
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Underflow flushes to zero, tiny-but-representable stays nonzero.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-10)), 0.0);
        let sub = f16_bits_to_f32(f32_to_f16_bits(3e-7));
        assert!(sub > 0.0 && (sub - 3e-7).abs() < 6e-8, "{sub}");
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16
        // (1 + 2^-10); nearest-even rounds down to 1.0.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0 + 0.00048828125)), 1.0);
        // 1 + 3·2^-11 is halfway between 1+2^-10 and 1+2^-9; nearest-even
        // rounds up to 1 + 2^-9 (even mantissa 2).
        let up = f16_bits_to_f32(f32_to_f16_bits(1.0 + 3.0 * 0.000488281250));
        assert_eq!(up, 1.0 + 2.0 * 0.0009765625);
    }

    #[test]
    fn f16_relative_error_within_half_ulp() {
        let mut rng = Pcg::new(3);
        for _ in 0..20_000 {
            let v = rng.next_gaussian() * 10f32.powi((rng.next_f32() * 8.0 - 4.0) as i32);
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            // Normal range: rel err ≤ 2^-11; subnormal: abs err ≤ 2^-25.
            let tol = f32::max(4.8829e-4 * v.abs(), 3.0e-8);
            assert!((rt - v).abs() <= tol, "{v} -> {rt}");
        }
    }

    #[test]
    fn bf16_roundtrip_and_rounding() {
        for v in [0.0f32, -0.0, 1.0, -2.5, 3.0e38, 1.0e-30] {
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(v));
            assert!((rt - v).abs() <= 3.91e-3 * v.abs(), "{v} -> {rt}");
        }
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.0)), 1.0);
        assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)).is_infinite());
        // Values just under the rounding boundary stay put; the tie at
        // 1 + 2^-9 rounds to even (down to 1.0).
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.0 + 0.001953125)), 1.0);
        let mut rng = Pcg::new(5);
        for _ in 0..20_000 {
            let v = rng.next_gaussian();
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(v));
            assert!((rt - v).abs() <= 3.91e-3 * (1e-30 + v.abs()), "{v} -> {rt}");
        }
    }

    #[test]
    fn i8_row_quantization_bounds_and_zero_row() {
        let mut rng = Pcg::new(7);
        let row: Vec<f32> = (0..64).map(|_| rng.next_gaussian()).collect();
        let scale = i8_row_scale(&row);
        let mut enc = Vec::new();
        quantize_i8(&row, scale, &mut enc);
        assert_eq!(enc.len(), row.len());
        let mut dec = vec![0.0f32; row.len()];
        dequantize_i8(&enc, scale, &mut dec);
        let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (i, (&v, &d)) in row.iter().zip(&dec).enumerate() {
            // Rounding error ≤ scale/2 = absmax/254.
            assert!((v - d).abs() <= absmax / 254.0 + 1e-7, "elem {i}: {v} vs {d}");
        }
        // The row absmax maps to exactly ±127 and back exactly.
        let zero = vec![0.0f32; 8];
        let s0 = i8_row_scale(&zero);
        assert_eq!(s0, 0.0);
        let mut enc0 = Vec::new();
        quantize_i8(&zero, s0, &mut enc0);
        let mut dec0 = vec![1.0f32; 8];
        dequantize_i8(&enc0, s0, &mut dec0);
        assert!(dec0.iter().all(|&v| v == 0.0));
    }
}
