//! Cholesky factorisation of the (damped) compressed FIM — the iFVP engine.
//!
//! The attribute pipeline needs `(F̂ + λI)^{-1} ĝ` for every cached gradient.
//! `F̂` is k×k symmetric PSD; we factor once (`O(k³/3)`) and back-solve per
//! vector (`O(k²)`), which is the paper's "matrix inversion complexity
//! scales down from O(p²) to O(k²)" claim in practice.

use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`, stored row-major.
#[derive(Debug, Clone)]
pub struct CholeskyFactor {
    n: usize,
    l: Vec<f64>,
}

impl CholeskyFactor {
    /// Factor `A + damping·I`, where `a` is `n×n` row-major (only the lower
    /// triangle is read). Uses f64 accumulation for stability.
    pub fn factor_damped(a: &[f32], n: usize, damping: f64) -> Result<Self> {
        assert_eq!(a.len(), n * n);
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[i * n + j] as f64;
                if i == j {
                    sum += damping;
                }
                for t in 0..j {
                    sum -= l[i * n + t] * l[j * n + t];
                }
                if i == j {
                    if sum <= 0.0 {
                        bail!("matrix not PD at pivot {i} (got {sum}); increase damping");
                    }
                    l[i * n + j] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Ok(Self { n, l })
    }

    pub fn dim(&self) -> usize {
        self.n
    }

    /// Solve `A x = b` via forward + backward substitution, in place.
    pub fn solve_into(&self, b: &mut [f64]) {
        let n = self.n;
        assert_eq!(b.len(), n);
        // L y = b
        for i in 0..n {
            let mut s = b[i];
            for j in 0..i {
                s -= self.l[i * n + j] * b[j];
            }
            b[i] = s / self.l[i * n + i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = b[i];
            for j in (i + 1)..n {
                s -= self.l[j * n + i] * b[j];
            }
            b[i] = s / self.l[i * n + i];
        }
    }

    /// f32 convenience: returns `A^{-1} b`.
    pub fn solve_f32(&self, b: &[f32]) -> Vec<f32> {
        let mut work: Vec<f64> = b.iter().map(|&x| x as f64).collect();
        self.solve_into(&mut work);
        work.into_iter().map(|x| x as f32).collect()
    }

    /// Dense inverse (used by tests and the TRAK preconditioner which
    /// re-applies the inverse to many vectors via one matmul).
    pub fn inverse(&self) -> Vec<f64> {
        let n = self.n;
        let mut inv = vec![0.0f64; n * n];
        let mut e = vec![0.0f64; n];
        for c in 0..n {
            e.fill(0.0);
            e[c] = 1.0;
            self.solve_into(&mut e);
            for r in 0..n {
                inv[r * n + c] = e[r];
            }
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn random_spd(n: usize, seed: u64) -> Vec<f32> {
        // A = B Bᵀ + 0.1 I
        let mut rng = Pcg::new(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian() as f64).collect();
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 0.1 } else { 0.0 };
                for t in 0..n {
                    s += b[i * n + t] * b[j * n + t];
                }
                a[i * n + j] = s as f32;
            }
        }
        a
    }

    #[test]
    fn solve_recovers_known_x() {
        let n = 24;
        let a = random_spd(n, 5);
        let f = CholeskyFactor::factor_damped(&a, n, 0.0).unwrap();
        let mut rng = Pcg::new(6);
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian() as f64).collect();
        // b = A x
        let mut b = vec![0.0f64; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] as f64 * x[j];
            }
        }
        f.solve_into(&mut b);
        for i in 0..n {
            assert!((b[i] - x[i]).abs() < 1e-3, "x[{i}]: {} vs {}", b[i], x[i]);
        }
    }

    #[test]
    fn damping_regularises_singular_matrix() {
        // rank-1 matrix fails without damping, succeeds with it
        let n = 4;
        let v = [1.0f32, 2.0, 3.0, 4.0];
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = v[i] * v[j];
            }
        }
        assert!(CholeskyFactor::factor_damped(&a, n, 0.0).is_err());
        assert!(CholeskyFactor::factor_damped(&a, n, 1e-3).is_ok());
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let n = 12;
        let a = random_spd(n, 9);
        let f = CholeskyFactor::factor_damped(&a, n, 0.0).unwrap();
        let inv = f.inverse();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for t in 0..n {
                    s += inv[i * n + t] * a[t * n + j] as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-3, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn identity_solve_is_identity() {
        let n = 8;
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let f = CholeskyFactor::factor_damped(&a, n, 0.0).unwrap();
        let b: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let x = f.solve_f32(&b);
        for i in 0..n {
            assert!((x[i] - b[i]).abs() < 1e-6);
        }
    }
}
