//! Runtime-dispatched SIMD kernels behind every hot loop in the crate.
//!
//! The crate is dependency-free, so this layer is hand-rolled on
//! `std::arch`: one scalar reference implementation per kernel (the
//! [`scalar`] module — always available, property-pinned against the
//! vectorized paths by `tests/simd_kernels.rs`), an AVX2+FMA(+F16C)
//! implementation for `x86_64`, and a NEON implementation for `aarch64`.
//! The instruction set is picked **once at runtime** (`is_x86_feature_
//! detected!` / `is_aarch64_feature_detected!`), so a single portable
//! binary runs the widest loops the host supports.
//!
//! ## Dispatch table
//!
//! | kernel                | consumer                                   | AVX2+FMA | NEON | scalar |
//! |-----------------------|--------------------------------------------|----------|------|--------|
//! | [`dot4x4`]            | `matmul_abt` scoring / LoGra GEMM tile     | ✓ (8-wide FMA) | ✓ (4-wide FMA) | ✓ (8-wide unroll) |
//! | [`dot`] / [`dot_tile`]| `matmul_abt` edge tiles                    | ✓        | ✓    | ✓ |
//! | [`axpy`]              | `matmul` / `matmul_at_b` rank-1 updates    | ✓        | ✓    | ✓ |
//! | [`add_assign`]        | private-accumulator reductions             | ✓        | ✓    | ✓ |
//! | [`scale_inplace`]     | SJLT `1/√s`, FWHT `1/√n` normalisation     | ✓        | ✓    | ✓ |
//! | [`fwht_butterfly`]    | FJLT's Walsh–Hadamard stages (`h ≥ 8`)     | ✓        | ✓    | ✓ |
//! | [`gather_scale`]      | RandomMask / GraSS stage-1 batch gather    | ✓ (`vgatherdps`) | scalar | ✓ |
//! | [`sjlt_scatter`]      | SJLT dense chunked-table scatter           | ✓ (vectorized zero-skip) | scalar | ✓ |
//! | [`decode_f16`]        | f16 shard payload dequant                  | ✓ (`vcvtph2ps`) | scalar | ✓ |
//! | [`decode_bf16`]       | bf16 shard payload dequant                 | ✓        | ✓    | ✓ |
//! | [`dequant_i8`]        | int8 shard payload dequant                 | ✓        | ✓    | ✓ |
//!
//! Kernels marked "scalar" under NEON fall back to the reference loop on
//! aarch64 (no gather instruction; f16 conversion intrinsics are not
//! stable) — the dispatch layer makes adding them later a local change.
//!
//! ## Where SIMD is skipped
//!
//! The dot-product kernels fall back to the scalar path below
//! [`MIN_SIMD_K`] shared-dimension elements: tiny-`k` edge tiles pay more
//! in vector setup + horizontal reduction than the lanes save. Everything
//! elementwise (axpy, scale, butterflies, decodes) vectorizes at any
//! length with a scalar tail for the last `len % lanes` elements.
//!
//! ## Numerics
//!
//! Elementwise kernels (`scale_inplace`, `fwht_butterfly`,
//! `gather_scale`, `add_assign`, the three decoders) perform *exactly*
//! the scalar arithmetic per element, so they are bit-compatible with the
//! reference. The FMA dot/axpy kernels fuse the multiply-add (one
//! rounding instead of two) and reassociate the `k`-sum across lanes;
//! `tests/simd_kernels.rs` pins them within `1e-6` of the scalar
//! reference relative to `Σ|aᵢ·bᵢ|` (the natural condition measure of a
//! dot product).
//!
//! ## Observability & escape hatch
//!
//! [`active_isa`] reports the selected instruction set (`"avx2+fma"`,
//! `"neon"`, `"scalar"`); it is surfaced in `grass serve` stats
//! (`simd_isa`), every `BENCH_*.json`, and the `grass serve` startup log.
//! Setting `GRASS_NO_SIMD=1` in the environment (read once at first
//! dispatch) or passing `--no-simd` to any `grass` subcommand (which
//! calls [`set_simd_enabled`]`(false)`) forces the scalar reference
//! everywhere, so the fallback stays testable on wide hosts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Shared-dimension floor below which the dot-product kernels stay
/// scalar: a `k < 16` tile cannot amortise vector setup and horizontal
/// reduction (see module docs, "Where SIMD is skipped").
pub const MIN_SIMD_K: usize = 16;

/// Instruction set selected by runtime detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable reference loops (also the `GRASS_NO_SIMD` escape hatch).
    Scalar,
    /// x86_64 with AVX2 + FMA + F16C (every AVX2-era core has all three).
    Avx2,
    /// aarch64 NEON (baseline on every aarch64 core).
    Neon,
}

impl Isa {
    /// Stable human/machine-readable name (what stats and bench JSON carry).
    pub fn as_str(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2+fma",
            Isa::Neon => "neon",
        }
    }
}

static DETECTED: OnceLock<Isa> = OnceLock::new();
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

fn detect() -> Isa {
    // Env escape hatch: checked once, folded into the cached detection so
    // a `GRASS_NO_SIMD=1` process can never silently re-enable wide loops.
    if std::env::var_os("GRASS_NO_SIMD").is_some_and(|v| v != "0") {
        return Isa::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
        {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// The instruction set every dispatched kernel will run on right now.
#[inline]
pub fn isa() -> Isa {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        return Isa::Scalar;
    }
    *DETECTED.get_or_init(detect)
}

/// Name of the active instruction set: `"avx2+fma"`, `"neon"`, or
/// `"scalar"`.
pub fn active_isa() -> &'static str {
    isa().as_str()
}

/// Runtime escape hatch (the `--no-simd` flag): `false` forces every
/// dispatched kernel onto the scalar reference; `true` restores the
/// detected instruction set (which stays `scalar` when the host lacks
/// the features or `GRASS_NO_SIMD=1` was set at startup).
pub fn set_simd_enabled(enabled: bool) {
    FORCE_SCALAR.store(!enabled, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Scalar reference implementations
// ---------------------------------------------------------------------------

/// Portable reference kernels. Every vectorized path is property-pinned
/// against these (`tests/simd_kernels.rs`), and they *are* the dispatch
/// target under `GRASS_NO_SIMD=1` / `--no-simd` / unsupported hosts.
///
/// The dot kernels are written with 8-wide unrolled partial sums and no
/// per-`kk` temporaries, so the compiler's autovectorizer can use the
/// baseline vector ISA (SSE2 on x86_64) even on the fallback path.
pub mod scalar {
    use crate::linalg::quantize::{bf16_bits_to_f32, f16_bits_to_f32};

    /// `c += a · b` over one row (rank-1 row update).
    #[inline]
    pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
        for (cv, &bv) in c.iter_mut().zip(b) {
            *cv += a * bv;
        }
    }

    /// Dot product with 8 independent partial sums (breaks the serial
    /// add dependency chain, autovectorizes on the baseline ISA).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (a, b) = (&a[..n], &b[..n]);
        let mut acc = [0.0f32; 8];
        let ca = a.chunks_exact(8);
        let cb = b.chunks_exact(8);
        let (ra, rb) = (ca.remainder(), cb.remainder());
        for (ea, eb) in ca.zip(cb) {
            for l in 0..8 {
                acc[l] += ea[l] * eb[l];
            }
        }
        let mut tail = 0.0f32;
        for (x, y) in ra.iter().zip(rb) {
            tail += x * y;
        }
        let s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        s + tail
    }

    /// Register-tiled 4×4 dot-product block: `acc[ii][jj] += ⟨a[ii], b[jj]⟩`
    /// over the shared inner dimension. Sixteen independent unrolled dot
    /// products — the per-`kk` `av`/`bv` temp arrays of the original
    /// kernel are gone, so nothing blocks autovectorization.
    #[inline]
    pub fn dot4x4(a: [&[f32]; 4], b: [&[f32]; 4], kdim: usize, acc: &mut [[f32; 4]; 4]) {
        for (ii, row) in acc.iter_mut().enumerate() {
            let ar = &a[ii][..kdim];
            for (jj, cell) in row.iter_mut().enumerate() {
                *cell += dot(ar, &b[jj][..kdim]);
            }
        }
    }

    /// Element-wise `a += b` (private-accumulator merge).
    #[inline]
    pub fn add_assign(a: &mut [f32], b: &[f32]) {
        for (x, &y) in a.iter_mut().zip(b) {
            *x += y;
        }
    }

    /// `v[i] *= s` for every element.
    #[inline]
    pub fn scale_inplace(v: &mut [f32], s: f32) {
        for x in v.iter_mut() {
            *x *= s;
        }
    }

    /// One Walsh–Hadamard butterfly stage over paired halves:
    /// `(lo[i], hi[i]) ← (lo[i] + hi[i], lo[i] − hi[i])`.
    #[inline]
    pub fn fwht_butterfly(lo: &mut [f32], hi: &mut [f32]) {
        for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
            let (x, y) = (*a, *b);
            *a = x + y;
            *b = x - y;
        }
    }

    /// Mask gather: `out[i] = src[idx[i]] · scale`. Caller guarantees
    /// every index is in range (mask indices are validated at
    /// construction).
    #[inline]
    pub fn gather_scale(src: &[f32], idx: &[u32], scale: f32, out: &mut [f32]) {
        for (o, &j) in out.iter_mut().zip(idx) {
            *o = src[j as usize] * scale;
        }
    }

    /// SJLT scatter of one dense coordinate chunk through the shared
    /// `(bucket, sign)` table (`s` replicas per coordinate), ascending-`j`
    /// accumulation order. Zero entries cost one branch (nnz-scaling).
    #[inline]
    pub fn sjlt_scatter(g: &[f32], table: &[(u32, f32)], s: usize, acc: &mut [f32]) {
        debug_assert!(table.len() >= g.len() * s);
        for (jj, &v) in g.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            for &(b, sgn) in &table[jj * s..jj * s + s] {
                acc[b as usize] += sgn * v;
            }
        }
    }

    /// Decode little-endian IEEE binary16 payload bytes to f32.
    #[inline]
    pub fn decode_f16(bytes: &[u8], out: &mut [f32]) {
        for (dst, ch) in out.iter_mut().zip(bytes.chunks_exact(2)) {
            *dst = f16_bits_to_f32(u16::from_le_bytes([ch[0], ch[1]]));
        }
    }

    /// Decode little-endian bfloat16 payload bytes to f32.
    #[inline]
    pub fn decode_bf16(bytes: &[u8], out: &mut [f32]) {
        for (dst, ch) in out.iter_mut().zip(bytes.chunks_exact(2)) {
            *dst = bf16_bits_to_f32(u16::from_le_bytes([ch[0], ch[1]]));
        }
    }

    /// Dequantize symmetric int8 codes against a (row) scale.
    #[inline]
    pub fn dequant_i8(codes: &[u8], scale: f32, out: &mut [f32]) {
        for (o, &b) in out.iter_mut().zip(codes) {
            *o = (b as i8) as f32 * scale;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA (+ F16C) implementations — x86_64 only
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of one 8-lane register: pairwise (lo+hi halves,
    /// then within the 128-bit half), matching the scalar reference's
    /// pairwise partial-sum reduction shape.
    #[inline]
    unsafe fn hsum256(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let cv = _mm256_loadu_ps(c.as_ptr().add(i));
            _mm256_storeu_ps(c.as_mut_ptr().add(i), _mm256_fmadd_ps(av, bv, cv));
            i += 8;
        }
        while i < n {
            *c.get_unchecked_mut(i) += a * b.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        // Two accumulator streams hide FMA latency on the 8-wide sweep.
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
            let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
            s0 = _mm256_fmadd_ps(a0, b0, s0);
            let a1 = _mm256_loadu_ps(a.as_ptr().add(i + 8));
            let b1 = _mm256_loadu_ps(b.as_ptr().add(i + 8));
            s1 = _mm256_fmadd_ps(a1, b1, s1);
            i += 16;
        }
        if i + 8 <= n {
            let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
            let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
            s0 = _mm256_fmadd_ps(a0, b0, s0);
            i += 8;
        }
        let mut s = hsum256(_mm256_add_ps(s0, s1));
        while i < n {
            s += a.get_unchecked(i) * b.get_unchecked(i);
            i += 1;
        }
        s
    }

    /// 4×4 register tile: 16 8-lane accumulators over the shared `kdim`
    /// sweep, each `b` row loaded once per 4 output rows per step.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot4x4(a: [&[f32]; 4], b: [&[f32]; 4], kdim: usize, acc: &mut [[f32; 4]; 4]) {
        let mut vacc = [[_mm256_setzero_ps(); 4]; 4];
        let mut kk = 0;
        while kk + 8 <= kdim {
            let bv = [
                _mm256_loadu_ps(b[0].as_ptr().add(kk)),
                _mm256_loadu_ps(b[1].as_ptr().add(kk)),
                _mm256_loadu_ps(b[2].as_ptr().add(kk)),
                _mm256_loadu_ps(b[3].as_ptr().add(kk)),
            ];
            for ii in 0..4 {
                let av = _mm256_loadu_ps(a[ii].as_ptr().add(kk));
                for jj in 0..4 {
                    vacc[ii][jj] = _mm256_fmadd_ps(av, bv[jj], vacc[ii][jj]);
                }
            }
            kk += 8;
        }
        for ii in 0..4 {
            for jj in 0..4 {
                let mut s = hsum256(vacc[ii][jj]);
                for t in kk..kdim {
                    s += a[ii].get_unchecked(t) * b[jj].get_unchecked(t);
                }
                acc[ii][jj] += s;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(a: &mut [f32], b: &[f32]) {
        let n = a.len().min(b.len());
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            _mm256_storeu_ps(a.as_mut_ptr().add(i), _mm256_add_ps(av, bv));
            i += 8;
        }
        while i < n {
            *a.get_unchecked_mut(i) += b.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_inplace(v: &mut [f32], s: f32) {
        let sv = _mm256_set1_ps(s);
        let n = v.len();
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(v.as_ptr().add(i));
            _mm256_storeu_ps(v.as_mut_ptr().add(i), _mm256_mul_ps(x, sv));
            i += 8;
        }
        while i < n {
            *v.get_unchecked_mut(i) *= s;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn fwht_butterfly(lo: &mut [f32], hi: &mut [f32]) {
        let n = lo.len().min(hi.len());
        let mut i = 0;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(lo.as_ptr().add(i));
            let b = _mm256_loadu_ps(hi.as_ptr().add(i));
            _mm256_storeu_ps(lo.as_mut_ptr().add(i), _mm256_add_ps(a, b));
            _mm256_storeu_ps(hi.as_mut_ptr().add(i), _mm256_sub_ps(a, b));
            i += 8;
        }
        while i < n {
            let (x, y) = (*lo.get_unchecked(i), *hi.get_unchecked(i));
            *lo.get_unchecked_mut(i) = x + y;
            *hi.get_unchecked_mut(i) = x - y;
            i += 1;
        }
    }

    /// 8-lane `vgatherdps` mask gather. Caller guarantees `idx[i] <
    /// src.len()` (mask indices are construction-validated).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_scale(src: &[f32], idx: &[u32], scale: f32, out: &mut [f32]) {
        let n = out.len().min(idx.len());
        let sv = _mm256_set1_ps(scale);
        let mut i = 0;
        while i + 8 <= n {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(i) as *const __m256i);
            let g = _mm256_i32gather_ps::<4>(src.as_ptr(), iv);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(g, sv));
            i += 8;
        }
        while i < n {
            *out.get_unchecked_mut(i) = src[*idx.get_unchecked(i) as usize] * scale;
            i += 1;
        }
    }

    /// Dense SJLT scatter with a vectorized zero-skip: 8 coordinates are
    /// tested per compare+movemask, and only lanes holding non-zeros walk
    /// the scalar scatter (ascending-`j` within the block, so the
    /// accumulation order matches the reference exactly). `NEQ_UQ` keeps
    /// NaN lanes "non-zero", matching the scalar `v == 0.0` test.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sjlt_scatter(g: &[f32], table: &[(u32, f32)], s: usize, acc: &mut [f32]) {
        debug_assert!(table.len() >= g.len() * s);
        let zero = _mm256_setzero_ps();
        let n = g.len();
        let mut jj = 0;
        while jj + 8 <= n {
            let v = _mm256_loadu_ps(g.as_ptr().add(jj));
            let mut m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_NEQ_UQ>(v, zero)) as u32;
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                m &= m - 1;
                let j = jj + lane;
                let x = *g.get_unchecked(j);
                for &(b, sgn) in &table[j * s..j * s + s] {
                    *acc.get_unchecked_mut(b as usize) += sgn * x;
                }
            }
            jj += 8;
        }
        while jj < n {
            let x = *g.get_unchecked(jj);
            if x != 0.0 {
                for &(b, sgn) in &table[jj * s..jj * s + s] {
                    *acc.get_unchecked_mut(b as usize) += sgn * x;
                }
            }
            jj += 1;
        }
    }

    /// `vcvtph2ps` f16 → f32 widening decode, 8 elements per step. The
    /// hardware conversion is IEEE-exact, identical to the scalar
    /// bit-twiddling reference on every finite value.
    #[target_feature(enable = "avx2,f16c")]
    pub unsafe fn decode_f16(bytes: &[u8], out: &mut [f32]) {
        let n = out.len().min(bytes.len() / 2);
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(bytes.as_ptr().add(2 * i) as *const __m128i);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_cvtph_ps(h));
            i += 8;
        }
        super::scalar::decode_f16(&bytes[2 * i..], &mut out[i..n]);
    }

    /// bf16 → f32: widen each u16 and shift into the top half (exact).
    #[target_feature(enable = "avx2")]
    pub unsafe fn decode_bf16(bytes: &[u8], out: &mut [f32]) {
        let n = out.len().min(bytes.len() / 2);
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(bytes.as_ptr().add(2 * i) as *const __m128i);
            let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_castsi256_ps(w));
            i += 8;
        }
        super::scalar::decode_bf16(&bytes[2 * i..], &mut out[i..n]);
    }

    /// int8 → f32 widening convert + scale multiply (both exact: every
    /// i8 is representable, and the multiply is the same single rounding
    /// the scalar reference performs).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_i8(codes: &[u8], scale: f32, out: &mut [f32]) {
        let n = out.len().min(codes.len());
        let sv = _mm256_set1_ps(scale);
        let mut i = 0;
        while i + 8 <= n {
            let c = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
            let w = _mm256_cvtepi8_epi32(c);
            let f = _mm256_cvtepi32_ps(w);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(f, sv));
            i += 8;
        }
        while i < n {
            *out.get_unchecked_mut(i) = (*codes.get_unchecked(i) as i8) as f32 * scale;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON implementations — aarch64 only
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len().min(b.len());
        let av = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let bv = vld1q_f32(b.as_ptr().add(i));
            let cv = vld1q_f32(c.as_ptr().add(i));
            vst1q_f32(c.as_mut_ptr().add(i), vfmaq_f32(cv, av, bv));
            i += 4;
        }
        while i < n {
            c[i] += a * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut s0 = vdupq_n_f32(0.0);
        let mut s1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 8 <= n {
            let a0 = vld1q_f32(a.as_ptr().add(i));
            let b0 = vld1q_f32(b.as_ptr().add(i));
            s0 = vfmaq_f32(s0, a0, b0);
            let a1 = vld1q_f32(a.as_ptr().add(i + 4));
            let b1 = vld1q_f32(b.as_ptr().add(i + 4));
            s1 = vfmaq_f32(s1, a1, b1);
            i += 8;
        }
        if i + 4 <= n {
            let a0 = vld1q_f32(a.as_ptr().add(i));
            let b0 = vld1q_f32(b.as_ptr().add(i));
            s0 = vfmaq_f32(s0, a0, b0);
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(s0, s1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot4x4(a: [&[f32]; 4], b: [&[f32]; 4], kdim: usize, acc: &mut [[f32; 4]; 4]) {
        let mut vacc = [[vdupq_n_f32(0.0); 4]; 4];
        let mut kk = 0;
        while kk + 4 <= kdim {
            let bv = [
                vld1q_f32(b[0].as_ptr().add(kk)),
                vld1q_f32(b[1].as_ptr().add(kk)),
                vld1q_f32(b[2].as_ptr().add(kk)),
                vld1q_f32(b[3].as_ptr().add(kk)),
            ];
            for ii in 0..4 {
                let av = vld1q_f32(a[ii].as_ptr().add(kk));
                for jj in 0..4 {
                    vacc[ii][jj] = vfmaq_f32(vacc[ii][jj], av, bv[jj]);
                }
            }
            kk += 4;
        }
        for ii in 0..4 {
            for jj in 0..4 {
                let mut s = vaddvq_f32(vacc[ii][jj]);
                for t in kk..kdim {
                    s += a[ii][t] * b[jj][t];
                }
                acc[ii][jj] += s;
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign(a: &mut [f32], b: &[f32]) {
        let n = a.len().min(b.len());
        let mut i = 0;
        while i + 4 <= n {
            let av = vld1q_f32(a.as_ptr().add(i));
            let bv = vld1q_f32(b.as_ptr().add(i));
            vst1q_f32(a.as_mut_ptr().add(i), vaddq_f32(av, bv));
            i += 4;
        }
        while i < n {
            a[i] += b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale_inplace(v: &mut [f32], s: f32) {
        let sv = vdupq_n_f32(s);
        let n = v.len();
        let mut i = 0;
        while i + 4 <= n {
            let x = vld1q_f32(v.as_ptr().add(i));
            vst1q_f32(v.as_mut_ptr().add(i), vmulq_f32(x, sv));
            i += 4;
        }
        while i < n {
            v[i] *= s;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fwht_butterfly(lo: &mut [f32], hi: &mut [f32]) {
        let n = lo.len().min(hi.len());
        let mut i = 0;
        while i + 4 <= n {
            let a = vld1q_f32(lo.as_ptr().add(i));
            let b = vld1q_f32(hi.as_ptr().add(i));
            vst1q_f32(lo.as_mut_ptr().add(i), vaddq_f32(a, b));
            vst1q_f32(hi.as_mut_ptr().add(i), vsubq_f32(a, b));
            i += 4;
        }
        while i < n {
            let (x, y) = (lo[i], hi[i]);
            lo[i] = x + y;
            hi[i] = x - y;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn decode_bf16(bytes: &[u8], out: &mut [f32]) {
        let n = out.len().min(bytes.len() / 2);
        let mut i = 0;
        while i + 4 <= n {
            let h = vld1_u16(bytes.as_ptr().add(2 * i) as *const u16);
            let w = vshlq_n_u32::<16>(vmovl_u16(h));
            vst1q_f32(out.as_mut_ptr().add(i), vreinterpretq_f32_u32(w));
            i += 4;
        }
        super::scalar::decode_bf16(&bytes[2 * i..], &mut out[i..n]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_i8(codes: &[u8], scale: f32, out: &mut [f32]) {
        let n = out.len().min(codes.len());
        let sv = vdupq_n_f32(scale);
        let mut i = 0;
        while i + 8 <= n {
            let c = vld1_s8(codes.as_ptr().add(i) as *const i8);
            let w = vmovl_s8(c);
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(lo, sv));
            vst1q_f32(out.as_mut_ptr().add(i + 4), vmulq_f32(hi, sv));
            i += 8;
        }
        while i < n {
            out[i] = (codes[i] as i8) as f32 * scale;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points
// ---------------------------------------------------------------------------

/// `c += a · b` over one row — the rank-1 row update behind `matmul` and
/// `matmul_at_b`.
#[inline]
pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::axpy(c, a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::axpy(c, a, b) },
        _ => scalar::axpy(c, a, b),
    }
}

/// Dot product `⟨a, b⟩` over `min(len)` elements. Stays scalar below
/// [`MIN_SIMD_K`] (tiny-k edge tiles — see module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    if a.len().min(b.len()) < MIN_SIMD_K {
        return scalar::dot(a, b);
    }
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot(a, b) },
        _ => scalar::dot(a, b),
    }
}

/// Register-tiled 4×4 dot-product block over a shared inner dimension:
/// `acc[ii][jj] += ⟨a[ii][..kdim], b[jj][..kdim]⟩` (additive, like the
/// historical `micro::dot4x4` contract). Stays scalar below
/// [`MIN_SIMD_K`].
#[inline]
pub fn dot4x4(a: [&[f32]; 4], b: [&[f32]; 4], kdim: usize, acc: &mut [[f32; 4]; 4]) {
    debug_assert!(a.iter().chain(b.iter()).all(|r| r.len() >= kdim));
    if kdim < MIN_SIMD_K {
        return scalar::dot4x4(a, b, kdim, acc);
    }
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::dot4x4(a, b, kdim, acc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot4x4(a, b, kdim, acc) },
        _ => scalar::dot4x4(a, b, kdim, acc),
    }
}

/// Edge-tile fallback for [`dot4x4`]: `ib×jb` block with `ib, jb ≤ 4`,
/// rows packed contiguously at stride `kdim`. Each pair runs the
/// dispatched [`dot`] kernel.
#[inline]
pub fn dot_tile(a: &[f32], b: &[f32], kdim: usize, ib: usize, jb: usize, acc: &mut [[f32; 4]; 4]) {
    for ii in 0..ib {
        let ar = &a[ii * kdim..(ii + 1) * kdim];
        for jj in 0..jb {
            acc[ii][jj] += dot(ar, &b[jj * kdim..(jj + 1) * kdim]);
        }
    }
}

/// Element-wise `a += b` (private-accumulator merges in the parallel
/// reductions).
#[inline]
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::add_assign(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::add_assign(a, b) },
        _ => scalar::add_assign(a, b),
    }
}

/// `v[i] *= s` — the SJLT `1/√s` and FWHT `1/√n` normalisation sweeps.
/// Bit-compatible with the scalar reference (same single multiply).
#[inline]
pub fn scale_inplace(v: &mut [f32], s: f32) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::scale_inplace(v, s) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::scale_inplace(v, s) },
        _ => scalar::scale_inplace(v, s),
    }
}

/// One Walsh–Hadamard butterfly stage over paired halves (`lo[i] ± hi[i]`).
/// Bit-compatible with the scalar reference (same adds/subs per element).
#[inline]
pub fn fwht_butterfly(lo: &mut [f32], hi: &mut [f32]) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::fwht_butterfly(lo, hi) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::fwht_butterfly(lo, hi) },
        _ => scalar::fwht_butterfly(lo, hi),
    }
}

/// Mask gather `out[i] = src[idx[i]] · scale` — RandomMask / GraSS
/// stage 1. Every index must be `< src.len()` (mask indices are
/// validated at construction; checked here in debug builds).
/// Bit-compatible with the scalar reference.
#[inline]
pub fn gather_scale(src: &[f32], idx: &[u32], scale: f32, out: &mut [f32]) {
    debug_assert!(idx.iter().all(|&j| (j as usize) < src.len()));
    #[cfg(target_arch = "x86_64")]
    if isa() == Isa::Avx2 {
        return unsafe { avx2::gather_scale(src, idx, scale, out) };
    }
    scalar::gather_scale(src, idx, scale, out)
}

/// Dense SJLT scatter of one coordinate chunk through the shared
/// `(bucket, sign)` table (`s` replicas per coordinate, `+=` semantics,
/// ascending-`j` order preserved). The vector win is the 8-wide
/// zero-skip; the scatter itself is serial by nature (bucket conflicts).
#[inline]
pub fn sjlt_scatter(g: &[f32], table: &[(u32, f32)], s: usize, acc: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if isa() == Isa::Avx2 {
        return unsafe { avx2::sjlt_scatter(g, table, s, acc) };
    }
    scalar::sjlt_scatter(g, table, s, acc)
}

/// Decode little-endian f16 payload bytes to f32 (IEEE-exact on either
/// path).
#[inline]
pub fn decode_f16(bytes: &[u8], out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if isa() == Isa::Avx2 {
        return unsafe { avx2::decode_f16(bytes, out) };
    }
    scalar::decode_f16(bytes, out)
}

/// Decode little-endian bf16 payload bytes to f32 (exact on either path).
#[inline]
pub fn decode_bf16(bytes: &[u8], out: &mut [f32]) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::decode_bf16(bytes, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::decode_bf16(bytes, out) },
        _ => scalar::decode_bf16(bytes, out),
    }
}

/// Dequantize symmetric int8 codes against a row scale (exact widening
/// convert + one multiply on either path).
#[inline]
pub fn dequant_i8(codes: &[u8], scale: f32, out: &mut [f32]) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::dequant_i8(codes, scale, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dequant_i8(codes, scale, out) },
        _ => scalar::dequant_i8(codes, scale, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn isa_name_is_stable() {
        let name = active_isa();
        assert!(
            ["scalar", "avx2+fma", "neon"].contains(&name),
            "unexpected ISA name {name}"
        );
    }

    #[test]
    fn scalar_dot_matches_f64_reference() {
        for n in [0, 1, 7, 8, 17, 64, 1000] {
            let a = gaussian(n, 1);
            let b = gaussian(n, 2);
            let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = scalar::dot(&a, &b) as f64;
            let cond: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x as f64 * y as f64).abs())
                .sum();
            assert!(
                (got - want).abs() <= 1e-6 * (1.0 + cond),
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn dispatched_elementwise_kernels_match_scalar_bitwise() {
        // scale, butterfly, gather, add_assign, decodes: exactly the
        // scalar arithmetic per element, so bitwise equality holds on
        // every ISA.
        let v = gaussian(101, 3);
        let mut a = v.clone();
        let mut b = v.clone();
        scale_inplace(&mut a, 0.37);
        scalar::scale_inplace(&mut b, 0.37);
        assert_eq!(a, b);

        let (mut lo1, mut hi1) = (gaussian(33, 4), gaussian(33, 5));
        let (mut lo2, mut hi2) = (lo1.clone(), hi1.clone());
        fwht_butterfly(&mut lo1, &mut hi1);
        scalar::fwht_butterfly(&mut lo2, &mut hi2);
        assert_eq!((lo1, hi1), (lo2, hi2));

        let src = gaussian(500, 6);
        let idx: Vec<u32> = (0..77).map(|i| (i * 13 + 5) % 500).collect();
        let mut o1 = vec![0.0f32; idx.len()];
        let mut o2 = vec![0.0f32; idx.len()];
        gather_scale(&src, &idx, 1.25, &mut o1);
        scalar::gather_scale(&src, &idx, 1.25, &mut o2);
        assert_eq!(o1, o2);

        let mut a1 = gaussian(67, 7);
        let mut a2 = a1.clone();
        let add = gaussian(67, 8);
        add_assign(&mut a1, &add);
        scalar::add_assign(&mut a2, &add);
        assert_eq!(a1, a2);
    }

    #[test]
    fn dispatched_dot4x4_within_fma_tolerance() {
        for kdim in [1, 5, 16, 33, 256, 1000] {
            let rows: Vec<Vec<f32>> = (0..8).map(|i| gaussian(kdim, 10 + i as u64)).collect();
            let a = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
            let b = [&rows[4][..], &rows[5][..], &rows[6][..], &rows[7][..]];
            let mut got = [[0.0f32; 4]; 4];
            let mut want = [[0.0f32; 4]; 4];
            dot4x4(a, b, kdim, &mut got);
            scalar::dot4x4(a, b, kdim, &mut want);
            for ii in 0..4 {
                for jj in 0..4 {
                    let cond: f32 = a[ii].iter().zip(b[jj]).map(|(x, y)| (x * y).abs()).sum();
                    assert!(
                        (got[ii][jj] - want[ii][jj]).abs() <= 1e-6 * (1.0 + cond),
                        "kdim={kdim} ({ii},{jj}): {} vs {}",
                        got[ii][jj],
                        want[ii][jj]
                    );
                }
            }
        }
    }

    #[test]
    fn sjlt_scatter_handles_tails_and_zeros() {
        // 8-wide zero-skip with ragged tails: identical buckets and
        // identical ascending-j accumulation order on every ISA.
        let mut rng = Pcg::new(20);
        for n in [3usize, 8, 9, 64, 100] {
            for s in [1usize, 3] {
                let g: Vec<f32> = (0..n)
                    .map(|_| {
                        if rng.next_f32() < 0.5 {
                            0.0
                        } else {
                            rng.next_gaussian()
                        }
                    })
                    .collect();
                let table: Vec<(u32, f32)> = (0..n * s)
                    .map(|i| ((i as u32 * 7) % 16, if i % 2 == 0 { 1.0 } else { -1.0 }))
                    .collect();
                let mut acc1 = vec![0.0f32; 16];
                let mut acc2 = vec![0.0f32; 16];
                sjlt_scatter(&g, &table, s, &mut acc1);
                scalar::sjlt_scatter(&g, &table, s, &mut acc2);
                assert_eq!(acc1, acc2, "n={n} s={s}");
            }
        }
    }

    #[test]
    fn decoders_match_scalar_bitwise() {
        use crate::linalg::quantize::{f32_to_bf16_bits, f32_to_f16_bits};
        let vals = gaussian(115, 30);
        let (mut f16b, mut bf16b) = (Vec::new(), Vec::new());
        for &v in &vals {
            f16b.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            bf16b.extend_from_slice(&f32_to_bf16_bits(v).to_le_bytes());
        }
        let mut a = vec![0.0f32; vals.len()];
        let mut b = vec![0.0f32; vals.len()];
        decode_f16(&f16b, &mut a);
        scalar::decode_f16(&f16b, &mut b);
        assert_eq!(a, b, "f16");
        decode_bf16(&bf16b, &mut a);
        scalar::decode_bf16(&bf16b, &mut b);
        assert_eq!(a, b, "bf16");
        let codes: Vec<u8> = (0..115u32).map(|i| (i * 37) as u8).collect();
        dequant_i8(&codes, 0.031, &mut a);
        scalar::dequant_i8(&codes, 0.031, &mut b);
        assert_eq!(a, b, "int8");
    }
}
