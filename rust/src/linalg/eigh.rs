//! Symmetric eigendecomposition (cyclic Jacobi) — the factorisation behind
//! the eigen-truncated low-rank preconditioner (`--precond eig:r`).
//!
//! The compressed FIM is `k × k` symmetric PSD with `k` in the hundreds to
//! low thousands, so the classic cyclic Jacobi iteration is the right
//! tool: O(k³) per sweep, unconditionally stable in f64, no external
//! dependencies, and it delivers the full spectrum with orthonormal
//! eigenvectors — which the rank-`r` inverse needs exactly once per fit.

/// Eigendecomposition `A = Σ_j values[j] · v_j v_jᵀ` of a symmetric matrix.
#[derive(Debug, Clone)]
pub struct Eigh {
    pub n: usize,
    /// Eigenvalues, sorted descending.
    pub values: Vec<f64>,
    /// Row-major `n × n`; row `j` is the (unit-norm) eigenvector paired
    /// with `values[j]`.
    pub vectors: Vec<f64>,
}

/// Decompose a symmetric `n × n` row-major matrix (the strict upper and
/// lower triangles are averaged, so mild asymmetry from f32 accumulation
/// is tolerated). Cyclic Jacobi with the Golub–Van Loan rotation choice;
/// converges to ~f64 precision in a handful of sweeps for PSD inputs.
pub fn eigh(a: &[f32], n: usize) -> Eigh {
    assert_eq!(a.len(), n * n, "eigh: matrix is not n × n");
    let mut m = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            m[i * n + j] = 0.5 * (a[i * n + j] as f64 + a[j * n + i] as f64);
        }
    }
    // V accumulates the rotations; its *columns* are eigenvectors.
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    if n > 1 {
        let fro = m.iter().map(|x| x * x).sum::<f64>().sqrt();
        let tol = 1e-14 * fro.max(f64::MIN_POSITIVE);
        'sweeps: for _ in 0..100 {
            let mut max_off = 0.0f64;
            for p in 0..n {
                for q in (p + 1)..n {
                    max_off = max_off.max(m[p * n + q].abs());
                }
            }
            if max_off <= tol {
                break 'sweeps;
            }
            for p in 0..n - 1 {
                for q in (p + 1)..n {
                    let apq = m[p * n + q];
                    if apq.abs() <= tol {
                        continue;
                    }
                    let app = m[p * n + p];
                    let aqq = m[q * n + q];
                    let theta = (aqq - app) / (2.0 * apq);
                    let t = if theta >= 0.0 {
                        1.0 / (theta + (1.0 + theta * theta).sqrt())
                    } else {
                        1.0 / (theta - (1.0 + theta * theta).sqrt())
                    };
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = t * c;
                    for i in 0..n {
                        if i == p || i == q {
                            continue;
                        }
                        let aip = m[i * n + p];
                        let aiq = m[i * n + q];
                        let nip = c * aip - s * aiq;
                        let niq = s * aip + c * aiq;
                        m[i * n + p] = nip;
                        m[p * n + i] = nip;
                        m[i * n + q] = niq;
                        m[q * n + i] = niq;
                    }
                    m[p * n + p] = app - t * apq;
                    m[q * n + q] = aqq + t * apq;
                    m[p * n + q] = 0.0;
                    m[q * n + p] = 0.0;
                    for i in 0..n {
                        let vip = v[i * n + p];
                        let viq = v[i * n + q];
                        v[i * n + p] = c * vip - s * viq;
                        v[i * n + q] = s * vip + c * viq;
                    }
                }
            }
        }
    }
    let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| diag[y].partial_cmp(&diag[x]).unwrap_or(std::cmp::Ordering::Equal));
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = vec![0.0f64; n * n];
    for (r, &col) in order.iter().enumerate() {
        for i in 0..n {
            vectors[r * n + i] = v[i * n + col];
        }
    }
    Eigh { n, values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CholeskyFactor;
    use crate::sketch::rng::Pcg;

    fn random_spd(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian() as f64).collect();
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 0.05 } else { 0.0 };
                for t in 0..n {
                    s += b[i * n + t] * b[j * n + t];
                }
                a[i * n + j] = s as f32;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_recovers_basis() {
        let n = 5;
        let mut a = vec![0.0f32; n * n];
        for (i, d) in [3.0, 1.0, 7.0, 0.5, 2.0].iter().enumerate() {
            a[i * n + i] = *d;
        }
        let e = eigh(&a, n);
        let want = [7.0, 3.0, 2.0, 1.0, 0.5];
        for (got, w) in e.values.iter().zip(want) {
            assert!((got - w).abs() < 1e-10, "{got} vs {w}");
        }
        // Each eigenvector is ± a unit basis vector.
        for j in 0..n {
            let row = &e.vectors[j * n..(j + 1) * n];
            let big = row.iter().filter(|v| v.abs() > 0.5).count();
            assert_eq!(big, 1, "eigenvector {j} not axis-aligned: {row:?}");
        }
    }

    #[test]
    fn reconstructs_and_is_orthonormal() {
        let n = 12;
        let a = random_spd(n, 3);
        let e = eigh(&a, n);
        // Eigenvalues descending and (PSD input) non-negative-ish.
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        // Orthonormal rows.
        for i in 0..n {
            for j in 0..n {
                let dot: f64 = e.vectors[i * n..(i + 1) * n]
                    .iter()
                    .zip(&e.vectors[j * n..(j + 1) * n])
                    .map(|(x, y)| x * y)
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "({i},{j}) = {dot}");
            }
        }
        // A == Σ_j λ_j v_j v_jᵀ.
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0f64;
                for r in 0..n {
                    s += e.values[r] * e.vectors[r * n + i] * e.vectors[r * n + j];
                }
                assert!(
                    (s - a[i * n + j] as f64).abs() < 1e-4,
                    "({i},{j}): {s} vs {}",
                    a[i * n + j]
                );
            }
        }
    }

    #[test]
    fn full_spectrum_solve_matches_cholesky() {
        // (A + λI)⁻¹ b via the eigendecomposition equals the Cholesky solve.
        let (n, lambda) = (10, 0.3f64);
        let a = random_spd(n, 9);
        let e = eigh(&a, n);
        let f = CholeskyFactor::factor_damped(&a, n, lambda).unwrap();
        let mut rng = Pcg::new(10);
        let b: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let want = f.solve_f32(&b);
        for i in 0..n {
            let mut s = 0.0f64;
            for r in 0..n {
                let coef: f64 = e.vectors[r * n..(r + 1) * n]
                    .iter()
                    .zip(&b)
                    .map(|(v, &x)| v * x as f64)
                    .sum();
                s += e.vectors[r * n + i] * coef / (e.values[r] + lambda);
            }
            assert!(
                (s - want[i] as f64).abs() < 1e-5 * (1.0 + want[i].abs() as f64),
                "x[{i}]: {s} vs {}",
                want[i]
            );
        }
    }

    #[test]
    fn one_by_one_and_empty() {
        let e = eigh(&[4.0], 1);
        assert_eq!(e.values, vec![4.0]);
        assert_eq!(e.vectors, vec![1.0]);
        let e = eigh(&[], 0);
        assert!(e.values.is_empty());
    }
}
