//! Small dense linear algebra used across the attribution pipeline:
//! Cholesky factorisation (FIM inversion), the symmetric Jacobi
//! eigensolver (eigen-truncated preconditioners), the fast Walsh–Hadamard
//! transform (FJLT baseline), correlation statistics (LDS), and the
//! register-tiled blocked matmuls behind the factorized compressors and the
//! influence scoring GEMM.

pub mod cholesky;
pub mod eigh;
pub mod fwht;
pub mod matmul;
pub mod stats;

pub use cholesky::CholeskyFactor;
pub use eigh::{eigh, Eigh};
pub use fwht::fwht_inplace;
pub use matmul::{matmul, matmul_abt, matmul_at_b};
pub use stats::{pearson, spearman};
