//! Small dense linear algebra used across the attribution pipeline:
//! Cholesky factorisation (FIM inversion), the symmetric Jacobi
//! eigensolver (eigen-truncated preconditioners), the fast Walsh–Hadamard
//! transform (FJLT baseline), correlation statistics (LDS), the
//! register-tiled blocked matmuls behind the factorized compressors and the
//! influence scoring GEMM, and the scalar quantization kernels
//! (f16/bf16/int8) the store payload codecs decode through on every
//! streamed read. The hot loops of all of these dispatch through the
//! [`simd`] layer, which picks AVX2+FMA / NEON / scalar once at runtime.

pub mod cholesky;
pub mod eigh;
pub mod fwht;
pub mod matmul;
pub mod quantize;
pub mod simd;
pub mod stats;

pub use cholesky::CholeskyFactor;
pub use eigh::{eigh, Eigh};
pub use fwht::fwht_inplace;
pub use matmul::{matmul, matmul_abt, matmul_at_b};
pub use stats::{pearson, spearman};
