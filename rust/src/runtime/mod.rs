//! PJRT runtime — loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//! Python never runs here; the `grass` binary is self-contained once
//! `make artifacts` has been run.
//!
//! Layout: [`registry`] parses `artifacts/manifest.json` into typed specs;
//! [`Runtime`] owns the PJRT CPU client and a compile-once executable cache;
//! [`Executable::run`] validates shapes and converts literals.

pub mod registry;

use anyhow::{anyhow, bail, Context, Result};
use registry::{ArtifactSpec, Dtype, Manifest};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A typed argument for an executable call.
#[derive(Debug, Clone)]
pub enum Arg {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl Arg {
    pub fn shape(&self) -> Vec<usize> {
        match self {
            Arg::F32(_, s) | Arg::I32(_, s) => s.clone(),
            Arg::ScalarF32(_) | Arg::ScalarI32(_) => vec![],
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(..) | Arg::ScalarF32(_) => Dtype::F32,
            Arg::I32(..) | Arg::ScalarI32(_) => Dtype::S32,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Arg::I32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Arg::ScalarF32(v) => xla::Literal::scalar(*v),
            Arg::ScalarI32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// An output tensor (all our artifacts emit f32).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn rows(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Row `i` of a tensor with leading batch dimension.
    pub fn row(&self, i: usize) -> &[f32] {
        let w: usize = self.shape[1..].iter().product();
        &self.data[i * w..(i + 1) * w]
    }
}

/// A compiled HLO executable plus its manifest spec.
///
/// SAFETY of `Send + Sync`: `PjRtLoadedExecutable` wraps a raw pointer into
/// the PJRT C API. The PJRT contract (and the CPU plugin implementation)
/// guarantees `Execute` is thread-safe on a loaded executable, and the
/// wrapper never exposes interior mutation; the pointer itself has no thread
/// affinity. We rely on that contract to share executables across the
/// coordinator's worker threads — the same pattern jaxlib uses.
pub struct Executable {
    pub name: String,
    pub spec: ArtifactSpec,
    inner: xla::PjRtLoadedExecutable,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with shape/dtype validation against the manifest spec.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        for (i, (arg, spec)) in args.iter().zip(&self.spec.inputs).enumerate() {
            if arg.shape() != spec.shape || arg.dtype() != spec.dtype {
                bail!(
                    "{}: input {i} mismatch: got {:?}/{:?}, want {:?}/{:?}",
                    self.name,
                    arg.shape(),
                    arg.dtype(),
                    spec.shape,
                    spec.dtype
                );
            }
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self
            .inner
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.name))?;
        // aot.py lowers with return_tuple=True.
        let parts = out.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| {
                Ok(Tensor {
                    data: lit.to_vec::<f32>()?,
                    shape: spec.shape.clone(),
                })
            })
            .collect()
    }
}

/// The PJRT runtime: client + manifest + compile-once executable cache.
///
/// SAFETY of `Send + Sync`: same PJRT thread-safety contract as
/// [`Executable`]; `PjRtClient::compile` is thread-safe and the cache is
/// guarded by a mutex.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest from `dir` and create the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = Manifest::load(&manifest_path)
            .with_context(|| format!("loading {}", manifest_path.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$GRASS_ARTIFACTS` or `./artifacts`.
    pub fn artifacts_dir() -> PathBuf {
        std::env::var("GRASS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Get (compiling on first use) the named executable.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exec = Arc::new(Executable {
            name: name.to_string(),
            spec,
            inner: exe,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_shapes_and_dtypes() {
        let a = Arg::F32(vec![1.0; 6], vec![2, 3]);
        assert_eq!(a.shape(), vec![2, 3]);
        assert_eq!(a.dtype(), Dtype::F32);
        let b = Arg::ScalarI32(7);
        assert_eq!(b.shape(), Vec::<usize>::new());
        assert_eq!(b.dtype(), Dtype::S32);
    }

    #[test]
    fn tensor_row_access() {
        let t = Tensor {
            data: (0..12).map(|i| i as f32).collect(),
            shape: vec![3, 4],
        };
        assert_eq!(t.rows(), 3);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
    }
}
