//! Artifact manifest parsing — the typed contract between `aot.py` and the
//! Rust coordinator (shapes, dtypes, batch sizes, model metadata).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn parse(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("shape not an array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.req("dtype")?.as_str().unwrap_or("f32"))?;
        Ok(Self { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Hooked linear layer metadata (LM models): name, d_in, d_out.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Flat parameter count P.
    pub p: usize,
    /// Parameter layout: (name, shape) in flat-vector order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Hooked linear layers (LMs only).
    pub layers: Vec<LayerMeta>,
    pub seq: Option<usize>,
    pub vocab: Option<usize>,
}

impl ModelMeta {
    /// The gradient geometry for [`crate::sketch::MethodSpec::build_bank`]:
    /// flat dimension `p` plus the hooked layers' `(d_in, d_out)` pairs.
    pub fn shapes(&self) -> crate::models::shapes::ModelShapes {
        crate::models::shapes::ModelShapes {
            p: self.p,
            layers: self.layers.iter().map(|l| (l.d_in, l.d_out)).collect(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelMeta>,
    /// batch-size contract: kind ("grads"/"train"/"loss"/"hooks") → model → B.
    pub batch_sizes: BTreeMap<String, BTreeMap<String, usize>>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let inputs = spec
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs not an array"))?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = spec
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs not an array"))?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: spec
                        .req("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("file not a string"))?
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, meta) in j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            let p = meta.req("p")?.as_usize().ok_or_else(|| anyhow!("bad p"))?;
            let params = meta
                .req("params")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|pair| {
                    let arr = pair.as_arr().ok_or_else(|| anyhow!("bad param pair"))?;
                    let pname = arr[0].as_str().unwrap_or("").to_string();
                    let shape = arr[1]
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect();
                    Ok((pname, shape))
                })
                .collect::<Result<Vec<_>>>()?;
            let layers = meta
                .get("layers")
                .and_then(|l| l.as_arr())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|t| {
                            let t = t.as_arr()?;
                            Some(LayerMeta {
                                name: t[0].as_str()?.to_string(),
                                d_in: t[1].as_usize()?,
                                d_out: t[2].as_usize()?,
                            })
                        })
                        .collect()
                })
                .unwrap_or_default();
            models.insert(
                name.clone(),
                ModelMeta {
                    p,
                    params,
                    layers,
                    seq: meta.get("seq").and_then(|v| v.as_usize()),
                    vocab: meta.get("vocab").and_then(|v| v.as_usize()),
                },
            );
        }

        let mut batch_sizes = BTreeMap::new();
        if let Some(bs) = j.get("batch_sizes").and_then(|b| b.as_obj()) {
            for (kind, per_model) in bs {
                let mut inner = BTreeMap::new();
                if let Some(pm) = per_model.as_obj() {
                    for (m, v) in pm {
                        if let Some(n) = v.as_usize() {
                            inner.insert(m.clone(), n);
                        }
                    }
                }
                batch_sizes.insert(kind.clone(), inner);
            }
        }

        Ok(Self {
            artifacts,
            models,
            batch_sizes,
        })
    }

    pub fn batch_size(&self, kind: &str, model: &str) -> Result<usize> {
        self.batch_sizes
            .get(kind)
            .and_then(|m| m.get(model))
            .copied()
            .ok_or_else(|| anyhow!("no batch size for {kind}/{model}"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": {
        "mlp_grads": {
          "file": "mlp_grads.hlo.txt",
          "inputs": [{"shape": [84618], "dtype": "f32"},
                      {"shape": [16, 196], "dtype": "f32"},
                      {"shape": [16], "dtype": "s32"}],
          "outputs": [{"shape": [16, 84618], "dtype": "f32"}]
        }
      },
      "models": {
        "mlp": {"p": 84618, "params": [["w0", [256, 196]], ["b0", [256]]]},
        "gpt2_tiny": {"p": 300000, "params": [],
          "layers": [["blk0.qkv", 128, 384]], "seq": 64, "vocab": 256}
      },
      "batch_sizes": {"grads": {"mlp": 16}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["mlp_grads"];
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![16, 196]);
        assert_eq!(a.inputs[2].dtype, Dtype::S32);
        assert_eq!(a.outputs[0].elements(), 16 * 84618);
        assert_eq!(m.model("mlp").unwrap().p, 84618);
        assert_eq!(m.batch_size("grads", "mlp").unwrap(), 16);
        let lm = m.model("gpt2_tiny").unwrap();
        assert_eq!(lm.layers.len(), 1);
        assert_eq!(lm.layers[0].d_out, 384);
        assert_eq!(lm.seq, Some(64));
    }

    #[test]
    fn model_shapes_from_meta() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let flat = m.model("mlp").unwrap().shapes();
        assert_eq!(flat.p, 84618);
        assert!(flat.layers.is_empty());
        let lm = m.model("gpt2_tiny").unwrap().shapes();
        assert_eq!(lm.layers, vec![(128, 384)]);
    }

    #[test]
    fn missing_pieces_error() {
        assert!(Manifest::parse("{}").is_err());
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.batch_size("train", "mlp").is_err());
    }
}
