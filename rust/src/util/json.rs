//! Minimal JSON parser/serializer (offline stand-in for serde_json).
//! Used for the artifact manifest, experiment configs, and result reports.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers round-trip through f64.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ------- accessors -------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------- builders -------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line serialization with no whitespace, suitable for
    /// newline-delimited protocols where one value must occupy one line.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.pos,
                self.bytes[self.pos] as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{s}' at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            self.pos += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                b => {
                    // collect the full UTF-8 sequence starting at b
                    let len = match b {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n\"x\"", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "hi\n\"x\""
        );
    }

    #[test]
    fn integers_roundtrip_exact() {
        let v = Json::parse("[0, 42, -7, 123456789]").unwrap();
        let s = v.to_string_pretty();
        assert!(s.contains("123456789"));
        assert!(!s.contains("123456789.0"));
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let src = r#"{"a": [1, 2.5], "b": {"c": "x\ny"}, "d": null}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string_compact();
        assert!(!s.contains('\n'), "compact output must be one line: {s:?}");
        assert!(!s.contains(": "), "compact output has no pretty spacing");
        assert_eq!(Json::parse(&s).unwrap(), v);
        let f = Json::arr_f32(&[1.5, -0.25]);
        assert_eq!(f.to_string_compact(), "[1.5,-0.25]");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let v = Json::parse(r#""été ☀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "été ☀");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": false}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.req("b").unwrap().as_bool().unwrap(), false);
        assert!(v.req("missing").is_err());
    }
}
