//! Tiny CLI argument parser (offline stand-in for clap): subcommand +
//! `--flag value` / `--flag=value` / boolean `--flag` options.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} expects a number, got '{v}': {e}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Human byte size with optional binary suffix, e.g.
    /// `--mem-budget 256M` / `1.5G` / `4096` (plain bytes).
    pub fn get_bytes(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                parse_bytes(v).map_err(|e| anyhow!("--{key} expects a byte size: {e}"))
            }
        }
    }

    /// Comma-separated list of usizes, e.g. `--k 256,1024,4096`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|e| anyhow!("--{key} element '{t}': {e}"))
                })
                .collect(),
        }
    }
}

/// Parse a human byte size: plain bytes, or a binary `K`/`M`/`G` suffix
/// (case-insensitive); fractional values like `1.5G` are allowed.
pub fn parse_bytes(s: &str) -> Result<usize> {
    let t = s.trim();
    let (num, mult) = match t.chars().last() {
        Some('k') | Some('K') => (&t[..t.len() - 1], 1usize << 10),
        Some('m') | Some('M') => (&t[..t.len() - 1], 1 << 20),
        Some('g') | Some('G') => (&t[..t.len() - 1], 1 << 30),
        _ => (t, 1),
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|e| anyhow!("bad byte size '{s}': {e}"))?;
    if v < 0.0 {
        bail!("byte size '{s}' is negative");
    }
    Ok((v * mult as f64) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn byte_sizes_parse_with_suffixes() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("64K").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("256M").unwrap(), 256 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2 << 30);
        assert_eq!(parse_bytes("1.5M").unwrap(), (1.5 * (1 << 20) as f64) as usize);
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-3M").is_err());
        let a = parse(&["attribute", "--mem-budget", "64M"]);
        assert_eq!(a.get_bytes("mem-budget", 1).unwrap(), 64 << 20);
        assert_eq!(a.get_bytes("absent", 7).unwrap(), 7);
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["exp", "fig4", "--k", "512", "--fast", "--out=res.json"]);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.get("k"), Some("512"));
        assert!(a.get_bool("fast"));
        assert_eq!(a.get("out"), Some("res.json"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--n", "42", "--lr", "0.5", "--ks", "1,2,3"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize_list("ks", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["x", "--n", "notanum"]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = parse(&["x", "--verbose", "--n", "3"]);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }
}
