//! In-crate replacements for the usual ecosystem crates — the build
//! environment is offline, so data-parallel helpers ([`par`]), JSON
//! ([`json`]), the micro-benchmark harness ([`bench`]), and CLI argument
//! parsing ([`cli`]) are implemented here on plain `std`.

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
