//! Micro-benchmark harness (offline stand-in for criterion): warmup,
//! adaptive iteration count, median-of-samples reporting, plus a
//! machine-readable `BENCH_<name>.json` emitter so the perf trajectory is
//! trackable across PRs. Used by every `cargo bench` target and by the
//! experiment wall-time columns.

use crate::util::json::Json;
use std::path::PathBuf;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Mean time per iteration.
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub samples: usize,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<48} median {:>12} mean {:>12} (min {}, max {}, n={})",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.max),
            self.samples
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`, targeting `budget` total runtime (min 3 samples).
pub fn bench_with_budget<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: run once to estimate cost.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let samples = ((budget.as_secs_f64() / once.as_secs_f64()) as usize).clamp(3, 1000);
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        median: times[times.len() / 2],
        mean: total / times.len() as u32,
        min: times[0],
        max: *times.last().unwrap(),
        samples: times.len(),
    }
}

/// Benchmark with the default 1-second budget.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let budget = std::env::var("GRASS_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(800));
    bench_with_budget(name, budget, f)
}

/// One machine-readable benchmark record for `BENCH_<name>.json`.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Compression method / configuration label.
    pub method: String,
    /// Rows (samples) per measured iteration.
    pub n: usize,
    /// Input dimensionality.
    pub p: usize,
    /// Output (compressed) dimensionality.
    pub k: usize,
    /// Throughput in samples per second.
    pub samples_per_sec: f64,
    /// Cost per input element in nanoseconds.
    pub ns_per_elem: f64,
    /// Input density (stored non-zeros / total elements) of the measured
    /// workload, when known — lets BENCH_*.json show nnz-proportional
    /// scaling across PRs.
    pub density: Option<f64>,
    /// Mean stored non-zeros per input row, when known.
    pub mean_nnz: Option<f64>,
    /// Preconditioner fit cost of the measured configuration in
    /// milliseconds (stream-FIM pass, or artifact load+build), when the
    /// record covers a second-order solver.
    pub precond_fit_ms: Option<f64>,
    /// Preconditioner apply cost (`apply_rows` over the record's `n`
    /// rows) in milliseconds, when known.
    pub precond_apply_ms: Option<f64>,
    /// Rows a `--resume` run skipped recomputing (already committed by an
    /// interrupted earlier run), when the record covers a recovery stage.
    pub resume_skipped_rows: Option<u64>,
    /// Shard-read retries the streaming passes attempted, when the record
    /// covers a fault-injected run.
    pub retries_attempted: Option<u64>,
    /// Served queries per second, when the record covers a `grass serve`
    /// daemon stage.
    pub qps: Option<f64>,
    /// Request latency percentiles (milliseconds) of the serving stage.
    pub p50_ms: Option<f64>,
    pub p95_ms: Option<f64>,
    pub p99_ms: Option<f64>,
    /// Shard-cache hit rate in `[0, 1]` of the serving stage, when a warm
    /// [`crate::serve::ShardCache`] was attached.
    pub cache_hit_rate: Option<f64>,
    /// Fraction of admitted-eligible requests the serving stage answered
    /// with scores (`answered / offered`, in `[0, 1]`), when the record
    /// covers a resilience/soak stage.
    pub availability: Option<f64>,
    /// Requests the daemon shed with typed overloaded/deadline replies
    /// during the measured serving stage.
    pub sheds: Option<u64>,
    /// Payload codec of the store the record was measured against
    /// (`"f32"`, `"f16"`, `"bf16"`, `"int8"`), when the stage reads a
    /// quantized shard store.
    pub dtype: Option<String>,
    /// Encoded bytes per stored row under that codec, when known — lets
    /// BENCH_*.json show the bandwidth reduction quantization buys.
    pub bytes_per_row: Option<f64>,
    /// Free-form extra metrics (e.g. `speedup_vs_per_sample`, `tokens_per_sec`).
    pub extra: Vec<(String, f64)>,
}

impl BenchRecord {
    /// Build a record from a measured per-iteration duration over `n`
    /// rows of `p` elements compressed to `k`.
    pub fn from_duration(method: &str, n: usize, p: usize, k: usize, per_iter: Duration) -> Self {
        let secs = per_iter.as_secs_f64().max(1e-12);
        Self {
            method: method.to_string(),
            n,
            p,
            k,
            samples_per_sec: n as f64 / secs,
            ns_per_elem: secs * 1e9 / (n as f64 * p as f64).max(1.0),
            density: None,
            mean_nnz: None,
            precond_fit_ms: None,
            precond_apply_ms: None,
            resume_skipped_rows: None,
            retries_attempted: None,
            qps: None,
            p50_ms: None,
            p95_ms: None,
            p99_ms: None,
            cache_hit_rate: None,
            availability: None,
            sheds: None,
            dtype: None,
            bytes_per_row: None,
            extra: vec![],
        }
    }

    /// Attach an extra named metric (builder style).
    pub fn with(mut self, key: &str, value: f64) -> Self {
        self.extra.push((key.to_string(), value));
        self
    }

    /// Record the measured workload's input density and mean non-zeros per
    /// row (builder style) so the JSON shows nnz-proportional scaling.
    pub fn with_density(mut self, density: f64, mean_nnz: f64) -> Self {
        self.density = Some(density);
        self.mean_nnz = Some(mean_nnz);
        self
    }

    /// Record the solver fit and apply cost (builder style) so the
    /// preconditioner cost trajectory lands in `BENCH_*.json` artifacts.
    pub fn with_precond(mut self, fit_ms: f64, apply_ms: f64) -> Self {
        self.precond_fit_ms = Some(fit_ms);
        self.precond_apply_ms = Some(apply_ms);
        self
    }

    /// Record recovery metrics of a fault-tolerance stage (builder style):
    /// rows a `--resume` run skipped recomputing and shard-read retries the
    /// streaming passes attempted.
    pub fn with_recovery(mut self, resume_skipped_rows: u64, retries_attempted: u64) -> Self {
        self.resume_skipped_rows = Some(resume_skipped_rows);
        self.retries_attempted = Some(retries_attempted);
        self
    }

    /// Record serving-stage throughput and latency percentiles (builder
    /// style) so the daemon's QPS/p50/p95/p99 trajectory lands in
    /// `BENCH_*.json` artifacts.
    pub fn with_serving(mut self, qps: f64, p50_ms: f64, p95_ms: f64, p99_ms: f64) -> Self {
        self.qps = Some(qps);
        self.p50_ms = Some(p50_ms);
        self.p95_ms = Some(p95_ms);
        self.p99_ms = Some(p99_ms);
        self
    }

    /// Record the serving stage's shard-cache hit rate (builder style).
    pub fn with_cache_hit_rate(mut self, rate: f64) -> Self {
        self.cache_hit_rate = Some(rate);
        self
    }

    /// Record the serving stage's availability (fraction of offered
    /// requests answered with scores) and typed-shed count (builder
    /// style) so resilience regressions show up in `BENCH_*.json`.
    pub fn with_availability(mut self, availability: f64, sheds: u64) -> Self {
        self.availability = Some(availability);
        self.sheds = Some(sheds);
        self
    }

    /// Record the payload codec of the measured store and its encoded
    /// bytes per row (builder style) so quantized-vs-f32 runs are
    /// distinguishable in `BENCH_*.json` artifacts.
    pub fn with_dtype(mut self, dtype: &str, bytes_per_row: f64) -> Self {
        self.dtype = Some(dtype.to_string());
        self.bytes_per_row = Some(bytes_per_row);
        self
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("method", Json::Str(self.method.clone())),
            ("n", Json::Num(self.n as f64)),
            ("p", Json::Num(self.p as f64)),
            ("k", Json::Num(self.k as f64)),
            ("samples_per_sec", Json::Num(self.samples_per_sec)),
            ("ns_per_elem", Json::Num(self.ns_per_elem)),
        ];
        if let Some(d) = self.density {
            pairs.push(("density", Json::Num(d)));
        }
        if let Some(m) = self.mean_nnz {
            pairs.push(("mean_nnz", Json::Num(m)));
        }
        if let Some(v) = self.precond_fit_ms {
            pairs.push(("precond_fit_ms", Json::Num(v)));
        }
        if let Some(v) = self.precond_apply_ms {
            pairs.push(("precond_apply_ms", Json::Num(v)));
        }
        if let Some(v) = self.resume_skipped_rows {
            pairs.push(("resume_skipped_rows", Json::Num(v as f64)));
        }
        if let Some(v) = self.retries_attempted {
            pairs.push(("retries_attempted", Json::Num(v as f64)));
        }
        if let Some(v) = self.qps {
            pairs.push(("qps", Json::Num(v)));
        }
        if let Some(v) = self.p50_ms {
            pairs.push(("p50_ms", Json::Num(v)));
        }
        if let Some(v) = self.p95_ms {
            pairs.push(("p95_ms", Json::Num(v)));
        }
        if let Some(v) = self.p99_ms {
            pairs.push(("p99_ms", Json::Num(v)));
        }
        if let Some(v) = self.cache_hit_rate {
            pairs.push(("cache_hit_rate", Json::Num(v)));
        }
        if let Some(v) = self.availability {
            pairs.push(("availability", Json::Num(v)));
        }
        if let Some(v) = self.sheds {
            pairs.push(("sheds", Json::Num(v as f64)));
        }
        if let Some(d) = &self.dtype {
            pairs.push(("dtype", Json::Str(d.clone())));
        }
        if let Some(v) = self.bytes_per_row {
            pairs.push(("bytes_per_row", Json::Num(v)));
        }
        for (key, value) in &self.extra {
            pairs.push((key.as_str(), Json::Num(*value)));
        }
        Json::obj(pairs)
    }
}

/// Where `BENCH_<name>.json` files land: `$GRASS_BENCH_DIR` or the CWD.
pub fn bench_json_path(name: &str) -> PathBuf {
    let dir = std::env::var("GRASS_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    PathBuf::from(dir).join(format!("BENCH_{name}.json"))
}

/// Write benchmark records to `BENCH_<name>.json` (overwriting any previous
/// run) and return the path. Every bench target calls this so the perf
/// trajectory is diffable across PRs. The top level records the SIMD ISA
/// the run dispatched to (`"avx2+fma"` / `"neon"` / `"scalar"`), so perf
/// numbers are never compared across different kernel paths by accident.
pub fn write_bench_json(name: &str, records: &[BenchRecord]) -> std::io::Result<PathBuf> {
    let path = bench_json_path(name);
    let j = Json::obj(vec![
        ("bench", Json::Str(name.to_string())),
        (
            "simd_isa",
            Json::Str(crate::linalg::simd::active_isa().to_string()),
        ),
        (
            "records",
            Json::Arr(records.iter().map(|r| r.to_json()).collect()),
        ),
    ]);
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single closure invocation.
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (R, Duration) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench_with_budget("spin", Duration::from_millis(20), || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.samples >= 3);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_secs(2)).contains("s"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000_000);
    }

    #[test]
    fn bench_record_math_and_json() {
        let r = BenchRecord::from_duration("sjlt:k=64", 10, 1000, 64, Duration::from_millis(10))
            .with("speedup_vs_per_sample", 2.5);
        assert!((r.samples_per_sec - 1000.0).abs() < 1.0);
        assert!((r.ns_per_elem - 1000.0).abs() < 1.0);
        let j = r.to_json();
        assert_eq!(j.req("method").unwrap().as_str(), Some("sjlt:k=64"));
        assert_eq!(j.req("k").unwrap().as_usize(), Some(64));
        assert!(j.req("speedup_vs_per_sample").unwrap().as_f64().is_some());
        // density/mean_nnz are omitted until recorded, then serialized.
        assert!(j.get("density").is_none());
        let r = BenchRecord::from_duration("sjlt:k=64", 10, 1000, 64, Duration::from_millis(10))
            .with_density(0.01, 10.0);
        let j = r.to_json();
        assert_eq!(j.req("density").unwrap().as_f64(), Some(0.01));
        assert_eq!(j.req("mean_nnz").unwrap().as_f64(), Some(10.0));
        // Preconditioner costs are omitted until recorded, then serialized.
        assert!(j.get("precond_fit_ms").is_none());
        let r = BenchRecord::from_duration("precond", 10, 64, 64, Duration::from_millis(10))
            .with_precond(12.5, 0.75);
        let j = r.to_json();
        assert_eq!(j.req("precond_fit_ms").unwrap().as_f64(), Some(12.5));
        assert_eq!(j.req("precond_apply_ms").unwrap().as_f64(), Some(0.75));
        // Recovery metrics are omitted until recorded, then serialized.
        assert!(j.get("resume_skipped_rows").is_none());
        assert!(j.get("retries_attempted").is_none());
        let r = BenchRecord::from_duration("resume", 10, 64, 64, Duration::from_millis(10))
            .with_recovery(96, 2);
        let j = r.to_json();
        assert_eq!(j.req("resume_skipped_rows").unwrap().as_usize(), Some(96));
        assert_eq!(j.req("retries_attempted").unwrap().as_usize(), Some(2));
        // Serving metrics are omitted until recorded, then serialized.
        assert!(j.get("qps").is_none());
        assert!(j.get("cache_hit_rate").is_none());
        let r = BenchRecord::from_duration("serve", 10, 64, 64, Duration::from_millis(10))
            .with_serving(250.0, 3.5, 9.0, 14.0)
            .with_cache_hit_rate(0.97);
        let j = r.to_json();
        assert_eq!(j.req("qps").unwrap().as_f64(), Some(250.0));
        assert_eq!(j.req("p50_ms").unwrap().as_f64(), Some(3.5));
        assert_eq!(j.req("p95_ms").unwrap().as_f64(), Some(9.0));
        assert_eq!(j.req("p99_ms").unwrap().as_f64(), Some(14.0));
        assert_eq!(j.req("cache_hit_rate").unwrap().as_f64(), Some(0.97));
        // Availability metrics are omitted until recorded, then serialized.
        assert!(j.get("availability").is_none());
        assert!(j.get("sheds").is_none());
        let r = BenchRecord::from_duration("soak", 10, 64, 64, Duration::from_millis(10))
            .with_availability(0.95, 7);
        let j = r.to_json();
        assert_eq!(j.req("availability").unwrap().as_f64(), Some(0.95));
        assert_eq!(j.req("sheds").unwrap().as_usize(), Some(7));
        // Payload dtype fields are omitted until recorded, then serialized.
        assert!(j.get("dtype").is_none());
        assert!(j.get("bytes_per_row").is_none());
        let r = BenchRecord::from_duration("stream", 10, 64, 64, Duration::from_millis(10))
            .with_dtype("f16", 128.0);
        let j = r.to_json();
        assert_eq!(j.req("dtype").unwrap().as_str(), Some("f16"));
        assert_eq!(j.req("bytes_per_row").unwrap().as_f64(), Some(128.0));
    }

    #[test]
    fn bench_json_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!("grass_benchjson_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        let recs = vec![BenchRecord::from_duration("rm:k=8", 4, 100, 8, Duration::from_micros(50))];
        let j = Json::obj(vec![
            ("bench", Json::Str("unit".into())),
            ("records", Json::Arr(recs.iter().map(|r| r.to_json()).collect())),
        ]);
        std::fs::write(&path, j.to_string_pretty()).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.req("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(back.req("records").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
