//! Micro-benchmark harness (offline stand-in for criterion): warmup,
//! adaptive iteration count, median-of-samples reporting. Used by every
//! `cargo bench` target and by the experiment wall-time columns.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Mean time per iteration.
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub samples: usize,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<48} median {:>12} mean {:>12} (min {}, max {}, n={})",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.max),
            self.samples
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`, targeting `budget` total runtime (min 3 samples).
pub fn bench_with_budget<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: run once to estimate cost.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let samples = ((budget.as_secs_f64() / once.as_secs_f64()) as usize).clamp(3, 1000);
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        median: times[times.len() / 2],
        mean: total / times.len() as u32,
        min: times[0],
        max: *times.last().unwrap(),
        samples: times.len(),
    }
}

/// Benchmark with the default 1-second budget.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let budget = std::env::var("GRASS_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(800));
    bench_with_budget(name, budget, f)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single closure invocation.
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (R, Duration) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench_with_budget("spin", Duration::from_millis(20), || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.samples >= 3);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_secs(2)).contains("s"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000_000);
    }
}
