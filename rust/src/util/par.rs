//! Data-parallel primitives on `std::thread::scope` — a minimal stand-in
//! for rayon. All helpers split work into at most `available_parallelism()`
//! contiguous chunks, which is the right grain for the crate's hot loops
//! (long, uniform, cache-streaming passes over gradient buffers).

use std::ops::Range;

/// Number of worker threads to use (respects `GRASS_NUM_THREADS`).
pub fn num_threads() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("GRASS_NUM_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Split `0..n` into at most `num_threads()` chunks of at least `min_chunk`.
pub fn chunk_ranges(n: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return vec![];
    }
    let workers = num_threads()
        .min(n.div_ceil(min_chunk.max(1)))
        .max(1);
    let base = n / workers;
    let rem = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f` over disjoint subranges of `0..n` in parallel.
pub fn par_ranges<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let ranges = chunk_ranges(n, min_chunk);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r);
        }
        return;
    }
    std::thread::scope(|s| {
        for r in ranges {
            let f = &f;
            s.spawn(move || f(r));
        }
    });
}

/// Map each chunk range to a value; results returned in chunk order.
pub fn par_map_ranges<R, F>(n: usize, min_chunk: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(Range<usize>) -> R + Sync,
{
    let ranges = chunk_ranges(n, min_chunk);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(f).collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                let f = &f;
                s.spawn(move || f(r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Parallel map-reduce over chunk-local accumulators.
pub fn par_map_reduce<R, F, G>(n: usize, min_chunk: usize, map: F, reduce: G) -> Option<R>
where
    R: Send,
    F: Fn(Range<usize>) -> R + Sync,
    G: Fn(R, R) -> R,
{
    par_map_ranges(n, min_chunk, map).into_iter().reduce(reduce)
}

/// Apply `f(chunk_index_start, chunk)` to disjoint mutable chunks of `data`
/// in parallel, splitting on row boundaries of width `row`.
pub fn par_chunks_mut<T, F>(data: &mut [T], row: usize, min_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row > 0 && data.len() % row == 0);
    let n_rows = data.len() / row;
    let ranges = chunk_ranges(n_rows, min_rows);
    if ranges.len() <= 1 {
        if !data.is_empty() {
            f(0, data);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = data;
        let mut offset = 0usize;
        for r in ranges {
            let len = (r.end - r.start) * row;
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let f = &f;
            let start_row = offset;
            s.spawn(move || f(start_row, head));
            offset += r.end - r.start;
        }
    });
}

/// Element-wise `a += b` (used to merge private accumulators). Runs
/// through the runtime-dispatched [`crate::linalg::simd::add_assign`]
/// kernel; one add per element on every ISA, so merges are
/// bit-compatible with the scalar loop.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    crate::linalg::simd::add_assign(a, b);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 100, 1023] {
            let rs = chunk_ranges(n, 1);
            let total: usize = rs.iter().map(|r| r.end - r.start).sum();
            assert_eq!(total, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn min_chunk_respected() {
        let rs = chunk_ranges(100, 64);
        assert!(rs.len() <= 2);
    }

    #[test]
    fn par_ranges_visits_all() {
        let counter = AtomicUsize::new(0);
        par_ranges(1000, 10, |r| {
            counter.fetch_add(r.end - r.start, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_map_reduce_sums() {
        let got = par_map_reduce(10_000, 100, |r| r.sum::<usize>(), |a, b| a + b).unwrap();
        assert_eq!(got, (0..10_000usize).sum());
    }

    #[test]
    fn par_chunks_mut_writes_disjoint() {
        let mut data = vec![0u32; 64 * 8];
        par_chunks_mut(&mut data, 8, 1, |start_row, chunk| {
            for (i, row) in chunk.chunks_mut(8).enumerate() {
                row.fill((start_row + i) as u32);
            }
        });
        for (i, row) in data.chunks(8).enumerate() {
            assert!(row.iter().all(|&v| v == i as u32), "row {i}");
        }
    }

    #[test]
    fn empty_input_ok() {
        par_ranges(0, 1, |_| panic!("should not run"));
        let v: Vec<usize> = par_map_ranges(0, 1, |r| r.len());
        assert!(v.is_empty());
    }
}
