//! Random Mask (`RM_k`) sparsification — paper §3.2.
//!
//! Selects `k` distinct coordinates of the `p`-dimensional gradient and
//! extracts the sub-vector: `ĝ = M g` with `M` a binary selection matrix.
//! O(k) per projection — sub-linear in `p`. Entries are scaled by `√(p/k)`
//! so that `E[⟨ĝ_a, ĝ_b⟩] = ⟨g_a, g_b⟩` (unbiased GradDot under random
//! coordinate sampling); the paper omits the constant as it cancels in
//! correlation-based metrics, but the preconditioned influence pipeline
//! benefits from scale-consistency across layers.

use super::rng::Pcg;
use super::sparse::SparseRows;
use super::{Compressor, Scratch};
use crate::linalg::simd;
use crate::util::par;

#[derive(Debug, Clone)]
pub struct RandomMask {
    p: usize,
    /// Sorted selected coordinates (len = k).
    indices: Vec<u32>,
    scale: f32,
}

impl RandomMask {
    pub fn new(p: usize, k: usize, seed: u64) -> Self {
        assert!(k > 0 && k <= p, "mask k = {k} must be in [1, p = {p}]");
        let mut rng = Pcg::new(seed ^ 0x4D41_534B); // "MASK"
        let indices = rng.sample_distinct(p, k);
        Self {
            p,
            indices,
            scale: ((p as f64 / k as f64).sqrt()) as f32,
        }
    }

    /// Build from explicit indices (used by [`super::selective`] and by the
    /// factorized compressors which share mask plumbing).
    pub fn from_indices(p: usize, mut indices: Vec<u32>, scale: Option<f32>) -> Self {
        indices.sort_unstable();
        indices.dedup();
        assert!(!indices.is_empty(), "empty mask");
        assert!(
            (*indices.last().unwrap() as usize) < p,
            "mask index out of range"
        );
        let k = indices.len();
        Self {
            p,
            indices,
            scale: scale.unwrap_or(((p as f64 / k as f64).sqrt()) as f32),
        }
    }

    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }
}

impl Compressor for RandomMask {
    fn input_dim(&self) -> usize {
        self.p
    }

    fn output_dim(&self) -> usize {
        self.indices.len()
    }

    fn compress_into(&self, g: &[f32], out: &mut [f32]) {
        assert_eq!(g.len(), self.p);
        assert_eq!(out.len(), self.indices.len());
        simd::gather_scale(g, &self.indices, self.scale, out);
    }

    /// Batch kernel: a parallel strided gather, each row one call into the
    /// SIMD-dispatched [`crate::linalg::simd::gather_scale`] kernel
    /// (`vgatherdps` on AVX2) over the sorted index list. The mask's scale
    /// is uniform, so the kernel fuses the gather and the scale multiply
    /// without materialising a per-column table — the index list itself is
    /// the gather stream, already cache-resident and construction-validated
    /// to be in range. The workspace is accepted (batch-kernel contract)
    /// but not needed.
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        let (p, k) = (self.p, self.indices.len());
        assert_eq!(gs.len(), n * p);
        assert_eq!(out.len(), n * k);
        let idx = &self.indices;
        let scale = self.scale;
        par::par_chunks_mut(out, k, 8, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let g = &gs[(row_start + off) * p..(row_start + off + 1) * p];
                simd::gather_scale(g, idx, scale, orow);
            }
        });
    }

    /// CSR batch kernel — `O(nnz + k)` per row via a two-pointer merge of
    /// the row's sorted indices with the sorted mask, parallel over rows.
    /// Never reads a zero coordinate, so cost is independent of `p`. The
    /// data-dependent merge stays scalar by design (see the `linalg::simd`
    /// dispatch table): there is no dense run of coordinates to vectorize.
    fn compress_sparse_batch_with(
        &self,
        rows: &SparseRows,
        out: &mut [f32],
        _scratch: &mut Scratch,
    ) {
        assert_eq!(rows.dim(), self.p, "sparse batch dimension mismatch");
        let k = self.indices.len();
        let n = rows.n();
        assert_eq!(out.len(), n * k);
        let scale = self.scale;
        let mask = &self.indices;
        par::par_chunks_mut(out, k, 4, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let (idx, vals) = rows.row(row_start + off);
                orow.fill(0.0);
                let mut mi = 0usize;
                for (&j, &v) in idx.iter().zip(vals) {
                    while mi < k && mask[mi] < j {
                        mi += 1;
                    }
                    if mi == k {
                        break;
                    }
                    if mask[mi] == j {
                        orow[mi] = v * scale;
                        mi += 1;
                    }
                }
            }
        });
    }

    /// O(nnz + k) via merge of two sorted index lists.
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let mut mi = 0usize;
        for (&j, &v) in idx.iter().zip(vals) {
            while mi < self.indices.len() && self.indices[mi] < j {
                mi += 1;
            }
            if mi == self.indices.len() {
                break;
            }
            if self.indices[mi] == j {
                out[mi] = v * self.scale;
            }
        }
    }

    fn name(&self) -> String {
        format!("RM_{}", self.indices.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn extracts_selected_coordinates() {
        let m = RandomMask::from_indices(8, vec![1, 4, 6], Some(1.0));
        let g = [0.0, 10.0, 0.0, 0.0, 40.0, 0.0, 60.0, 0.0];
        assert_eq!(m.compress(&g), vec![10.0, 40.0, 60.0]);
    }

    #[test]
    fn unbiased_inner_product() {
        // E over masks of <Mg_a, Mg_b> ≈ <g_a, g_b> with √(p/k) scaling.
        let p = 2048;
        let k = 256;
        let mut rng = Pcg::new(17);
        let a: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let exact: f64 = a.iter().zip(&b).map(|(x, y)| (x * y) as f64).sum();
        let trials = 200;
        let mut mean = 0.0f64;
        for t in 0..trials {
            let m = RandomMask::new(p, k, t as u64);
            let (ca, cb) = (m.compress(&a), m.compress(&b));
            mean += ca.iter().zip(&cb).map(|(x, y)| (x * y) as f64).sum::<f64>();
        }
        mean /= trials as f64;
        // exact is O(sqrt(p)) ≈ 45; estimator std ≈ p/sqrt(k·trials) ≈ 9
        assert!(
            (mean - exact).abs() < 30.0,
            "masked inner product biased: {mean} vs {exact}"
        );
    }

    #[test]
    fn dedups_and_sorts_indices() {
        let m = RandomMask::from_indices(10, vec![5, 2, 5, 9], Some(1.0));
        assert_eq!(m.indices(), &[2, 5, 9]);
    }

    #[test]
    fn full_mask_is_identity_times_scale() {
        let m = RandomMask::new(16, 16, 0);
        assert_eq!(m.indices(), (0..16u32).collect::<Vec<_>>().as_slice());
        assert!((m.scale() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sparse_path_agrees() {
        let m = RandomMask::new(100, 20, 3);
        let idx = [3u32, 17, 50, 99];
        let vals = [1.0f32, -2.0, 3.0, 4.0];
        let mut dense = vec![0.0; 100];
        for (&i, &v) in idx.iter().zip(&vals) {
            dense[i as usize] = v;
        }
        let a = m.compress(&dense);
        let mut b = vec![0.0; 20];
        m.compress_sparse_into(&idx, &vals, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        RandomMask::from_indices(4, vec![4], None);
    }

    #[test]
    fn batch_gather_table_from_scratch_matches_single() {
        // Repeated batches through the same scratch match the single-row
        // path bitwise (the gather kernel performs the identical per-element
        // multiply on every ISA, and the workspace carries no kernel state).
        let (p, k, n) = (500, 60, 9);
        let m = RandomMask::new(p, k, 11);
        let mut rng = Pcg::new(2);
        let gs: Vec<f32> = (0..n * p).map(|_| rng.next_gaussian()).collect();
        let mut scratch = Scratch::new();
        let mut batch = vec![0.0f32; n * k];
        m.compress_batch_with(&gs, n, &mut batch, &mut scratch);
        m.compress_batch_with(&gs, n, &mut batch, &mut scratch);
        for i in 0..n {
            assert_eq!(
                &batch[i * k..(i + 1) * k],
                m.compress(&gs[i * p..(i + 1) * p]).as_slice(),
                "row {i}"
            );
        }
    }

    #[test]
    fn csr_batch_matches_dense_batch() {
        let (p, k, n) = (800, 100, 6);
        let m = RandomMask::new(p, k, 5);
        let mut rng = Pcg::new(9);
        let gs: Vec<f32> = (0..n * p)
            .map(|_| {
                if rng.next_f32() < 0.95 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect();
        let rows = SparseRows::from_dense_threshold(&gs, n, p, 0.0);
        let mut scratch = Scratch::new();
        let mut dense_out = vec![0.0f32; n * k];
        m.compress_batch_with(&gs, n, &mut dense_out, &mut scratch);
        let mut sparse_out = vec![0.0f32; n * k];
        m.compress_sparse_batch_with(&rows, &mut sparse_out, &mut scratch);
        assert_eq!(dense_out, sparse_out, "mask gather is exact: bitwise equal");
    }
}
