//! GraSS (`SJLT_k ∘ MASK_k'`) — paper §3.3.1.
//!
//! Two-stage compression: (1) sparsify the p-dimensional gradient to a
//! k'-dimensional sub-vector via a (random or selective) mask, then
//! (2) sparse-project the sub-vector to the target dimension k via SJLT.
//! Overall O(k' + k') = O(k') — *sub-linear* in p. Extremes: `k' = p`
//! recovers vanilla SJLT; `k' = k` recovers pure sparsification.

use super::mask::RandomMask;
use super::selective::TrainedMask;
use super::sjlt::Sjlt;
use super::sparse::SparseRows;
use super::{Compressor, MaskKind, Scratch};
use crate::linalg::simd;
use crate::util::par;

pub struct Grass {
    mask: RandomMask,
    sjlt: Sjlt,
    /// Scratch is per-call to stay `Sync`; reuse happens at the batch level
    /// in the coordinator (see `coordinator::compress_stage`).
    k_prime: usize,
}

impl Grass {
    /// Random-mask stage 1. `k_prime` is the intermediate dimension
    /// (`k ≤ k' ≤ p`); the paper's default is `k' = 4·k_max` for TRAK
    /// models and `2k_in ⊗ 2k_out` factorized.
    pub fn new(p: usize, k_prime: usize, k: usize, kind: MaskKind, seed: u64) -> Self {
        assert!(
            k <= k_prime && k_prime <= p,
            "need k ≤ k' ≤ p (got k={k}, k'={k_prime}, p={p})"
        );
        let mask = match kind {
            MaskKind::Random => RandomMask::new(p, k_prime, seed ^ 0x6A55),
            // A selective request without trained scores routes through the
            // documented untrained fallback: magnitude-free selection on the
            // selective stream, **distinct** from the random-mask stream so
            // `rm`- and `sm`-masked GraSS never silently coincide. The real
            // graddot-score-backed stage is [`Grass::with_scores`] /
            // [`Grass::with_mask`].
            MaskKind::Selective => RandomMask::new(p, k_prime, seed ^ 0x5E1E),
        };
        Self {
            sjlt: Sjlt::new(k_prime, k, 1, seed ^ 0x9A55),
            mask,
            k_prime,
        }
    }

    /// Graddot-score-backed selective stage 1: keep the `k_prime`
    /// highest-scoring coordinates (scores from
    /// [`super::selective::train_selective_mask`] or any per-coordinate
    /// importance statistic, e.g. squared-gradient means), then SJLT to `k`.
    /// This is the trained routing for [`MaskKind::Selective`].
    pub fn with_scores(p: usize, scores: &[f32], k_prime: usize, k: usize, seed: u64) -> Self {
        assert_eq!(scores.len(), p, "need one importance score per coordinate");
        let trained = TrainedMask {
            scores: scores.to_vec(),
            corr_history: vec![],
        };
        Self::with_mask(p, trained.into_mask(p, k_prime), k, seed)
    }

    /// Build from an explicit (e.g. selective-mask-trained) stage-1 mask.
    pub fn with_mask(p: usize, mask: RandomMask, k: usize, seed: u64) -> Self {
        assert_eq!(mask.input_dim(), p);
        let k_prime = mask.output_dim();
        assert!(k <= k_prime);
        Self {
            sjlt: Sjlt::new(k_prime, k, 1, seed ^ 0x9A55),
            mask,
            k_prime,
        }
    }

    pub fn k_prime(&self) -> usize {
        self.k_prime
    }

    pub fn mask_indices(&self) -> &[u32] {
        self.mask.indices()
    }
}

impl Compressor for Grass {
    fn input_dim(&self) -> usize {
        self.mask.input_dim()
    }

    fn output_dim(&self) -> usize {
        self.sjlt.output_dim()
    }

    fn compress_into(&self, g: &[f32], out: &mut [f32]) {
        let mut mid = vec![0.0f32; self.k_prime];
        self.mask.compress_into(g, &mut mid);
        self.sjlt.compress_into(&mid, out);
    }

    /// Sparse path: O(nnz∩mask) — intersect the sparse input with the mask
    /// indices, then SJLT over the (even sparser) intermediate vector.
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        let mut mid = vec![0.0f32; self.k_prime];
        self.mask.compress_sparse_into(idx, vals, &mut mid);
        self.sjlt.compress_into(&mid, out);
    }

    /// Batch kernel: stage 1 is one batched mask gather into a workspace
    /// `n × k'` intermediate, stage 2 one batched SJLT over it — the
    /// per-sample `mid` allocation of the scalar path is hoisted into the
    /// scratch and both stages run their own tuned batch kernels.
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        assert_eq!(gs.len(), n * self.input_dim());
        assert_eq!(out.len(), n * self.output_dim());
        let mut mid = scratch.take_f32(n * self.k_prime);
        self.mask.compress_batch_with(gs, n, &mut mid, scratch);
        self.sjlt.compress_batch_with(&mid, n, out, scratch);
        scratch.put_f32(mid);
    }

    /// CSR batch kernel, entirely in index space: per row, a two-pointer
    /// merge intersects the input support with the sorted mask indices, and
    /// every surviving non-zero scatters **directly** through the SJLT's
    /// counter-based `(bucket, sign)` hash of its mask position — the
    /// `k'`-dimensional sub-vector is never materialised, densely or
    /// otherwise. `O(nnz + k')` merge + `O(s·nnz∩mask)` scatter per row,
    /// independent of `p` (§3.3.1's sub-linear claim, end to end).
    fn compress_sparse_batch_with(
        &self,
        rows: &SparseRows,
        out: &mut [f32],
        _scratch: &mut Scratch,
    ) {
        assert_eq!(rows.dim(), self.input_dim(), "sparse batch dimension mismatch");
        let n = rows.n();
        let k = self.output_dim();
        assert_eq!(out.len(), n * k);
        let mask_idx = self.mask.indices();
        let kp = mask_idx.len();
        let scale = self.mask.scale();
        let s = self.sjlt.s();
        let inv = 1.0 / (s as f32).sqrt();
        par::par_chunks_mut(out, k, 1, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let (idx, vals) = rows.row(row_start + off);
                orow.fill(0.0);
                let mut mi = 0usize;
                for (&j, &v) in idx.iter().zip(vals) {
                    while mi < kp && mask_idx[mi] < j {
                        mi += 1;
                    }
                    if mi == kp {
                        break;
                    }
                    if mask_idx[mi] == j {
                        let mv = v * scale;
                        if mv != 0.0 {
                            for r in 0..s {
                                let (b, sgn) = self.sjlt.bucket_sign(mi, r);
                                orow[b] += sgn * mv;
                            }
                        }
                        mi += 1;
                    }
                }
                if s > 1 {
                    simd::scale_inplace(orow, inv);
                }
            }
        });
    }

    fn name(&self) -> String {
        format!("GraSS[SJLT_{} ∘ M_{}]", self.output_dim(), self.k_prime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn norm(v: &[f32]) -> f64 {
        v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn equals_mask_then_sjlt_composition() {
        let (p, kp, k) = (1024, 256, 64);
        let g1 = Grass::new(p, kp, k, MaskKind::Random, 77);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        // manual composition with identical seeds
        let mask = RandomMask::new(p, kp, 77 ^ 0x6A55);
        let sjlt = Sjlt::new(kp, k, 1, 77 ^ 0x9A55);
        let want = sjlt.compress(&mask.compress(&g));
        assert_eq!(g1.compress(&g), want);
    }

    #[test]
    fn approximate_norm_preservation() {
        // Two random stages still concentrate: ratio within a generous band.
        let (p, kp, k) = (8192, 2048, 512);
        let gr = Grass::new(p, kp, k, MaskKind::Random, 3);
        let mut rng = Pcg::new(2);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let ratio = norm(&gr.compress(&g)) / norm(&g);
        assert!((0.75..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn k_prime_equals_p_recovers_sjlt_geometry() {
        let (p, k) = (512, 64);
        let gr = Grass::new(p, p, k, MaskKind::Random, 5);
        let mut rng = Pcg::new(9);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        // full mask is a (scaled-identity) permutation, so output norm ≈ SJLT norm
        let ratio = norm(&gr.compress(&g)) / norm(&g);
        assert!((0.7..1.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn with_trained_mask() {
        let p = 256;
        let mask = RandomMask::from_indices(p, (0..64u32).collect(), None);
        let gr = Grass::with_mask(p, mask, 16, 11);
        assert_eq!(gr.output_dim(), 16);
        assert_eq!(gr.k_prime(), 64);
        let mut g = vec![0.0f32; p];
        // energy outside the mask must be dropped
        for j in 64..p {
            g[j] = 1.0;
        }
        assert!(gr.compress(&g).iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "need k")]
    fn invalid_dims_panic() {
        Grass::new(100, 10, 20, MaskKind::Random, 0);
    }

    #[test]
    fn csr_batch_matches_dense_batch() {
        let (p, kp, k, n) = (2048, 512, 64, 6);
        let gr = Grass::new(p, kp, k, MaskKind::Random, 13);
        let mut rng = Pcg::new(21);
        let gs: Vec<f32> = (0..n * p)
            .map(|_| {
                if rng.next_f32() < 0.96 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect();
        let rows = SparseRows::from_dense_threshold(&gs, n, p, 0.0);
        let mut scratch = Scratch::new();
        let mut dense_out = vec![0.0f32; n * k];
        gr.compress_batch_with(&gs, n, &mut dense_out, &mut scratch);
        let mut sparse_out = vec![0.0f32; n * k];
        gr.compress_sparse_batch_with(&rows, &mut sparse_out, &mut scratch);
        for i in 0..n * k {
            assert!(
                (dense_out[i] - sparse_out[i]).abs() <= 1e-4 * (1.0 + dense_out[i].abs()),
                "at {i}: {} vs {}",
                sparse_out[i],
                dense_out[i]
            );
        }
    }

    #[test]
    fn selective_kind_distinct_from_random() {
        // Regression: `Grass::new(.., Selective, ..)` must not reuse the
        // random-mask stream — an `sm`-masked spec has to produce different
        // projections than the `rm`-masked one at the same seed.
        let (p, kp, k) = (1024, 256, 64);
        let random = Grass::new(p, kp, k, MaskKind::Random, 7);
        let selective = Grass::new(p, kp, k, MaskKind::Selective, 7);
        let mut rng = Pcg::new(8);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        assert_ne!(
            random.compress(&g),
            selective.compress(&g),
            "selective mask kind collapsed onto the random stream"
        );
    }

    #[test]
    fn with_scores_keeps_top_scoring_coordinates() {
        // Score-backed selective stage 1: plant all importance on the last
        // 64 coordinates. The selective GraSS must drop everything outside
        // them, while a random mask (with overwhelming probability at
        // p = 256, k' = 64) keeps some of the low-score support.
        let (p, kp, k) = (256usize, 64usize, 16usize);
        let mut scores = vec![0.0f32; p];
        for j in p - kp..p {
            scores[j] = 1.0 + j as f32;
        }
        let selective = Grass::with_scores(p, &scores, kp, k, 5);
        let random = Grass::new(p, kp, k, MaskKind::Random, 5);
        // Exact: the score-backed stage selects precisely the planted set.
        assert!(
            selective.mask_indices().iter().all(|&j| (j as usize) >= p - kp),
            "selective stage kept low-score coordinates"
        );
        assert_eq!(selective.mask_indices().len(), kp);
        // The random mask (deterministic at this seed, and with probability
        // ≈ 1 − 10⁻⁶⁰ over seeds) keeps some of the low-score support.
        assert!(
            random.mask_indices().iter().any(|&j| (j as usize) < p - kp),
            "random mask improbably dropped all low coordinates"
        );
        // Energy outside the selected set is provably dropped end-to-end:
        let mut low = vec![0.0f32; p];
        for j in 0..p - kp {
            low[j] = 1.0;
        }
        assert!(
            selective.compress(&low).iter().all(|&v| v == 0.0),
            "selective stage leaked low-score energy"
        );
    }
}
