//! LoGra (`GAUSS_{k_in ⊗ k_out}`) — the factorized SOTA baseline
//! (Choe et al. 2024), paper §3.3.2.
//!
//! For a linear layer `y = W x` with sequence input, the per-sample weight
//! gradient is `vec(DW) = Σ_t x_t ⊗ dy_t`. LoGra assumes a Kronecker
//! projection `P = P_in ⊗ P_out` and computes
//!
//! `P vec(DW) = Σ_t (P_in x_t) ⊗ (P_out dy_t) = vec( (X P_inᵀ)ᵀ (DY P_outᵀ) )`
//!
//! i.e. two *small* dense projections (k_in×d_in and k_out×d_out) per
//! timestep plus a k_in×k_out accumulation — O(T(k_in d_in + k_out d_out))
//! ≈ O(√(p_l k_l)) per token — and the full gradient is never materialised.
//! The factor matrices are small enough to store explicitly (the paper
//! defaults them to Gaussian).

use super::rng::Pcg;
use super::sparse::SparseRows;
use super::{FactorizedCompressor, Scratch};
use crate::linalg::matmul::{matmul, matmul_abt, matmul_at_b};
use crate::util::par;

/// Project a CSR batch through a dense `kk × d` row-major factor matrix:
/// `out[t, a] = Σ_{j ∈ nnz(t)} rows[t, j] · proj[a, j]` — `O(nnz · kk)` per
/// timestep row instead of the dense GEMM's `O(d · kk)`, parallel over
/// rows. Skipped zero terms contribute exactly `+0.0`, so the result
/// matches the dense projection to fp-reassociation tolerance.
fn project_sparse(proj: &[f32], d: usize, kk: usize, rows: &SparseRows, out: &mut [f32]) {
    debug_assert_eq!(rows.dim(), d);
    debug_assert_eq!(out.len(), rows.n() * kk);
    par::par_chunks_mut(out, kk, 16, |t_start, chunk| {
        for (off, yr) in chunk.chunks_mut(kk).enumerate() {
            let (idx, vals) = rows.row(t_start + off);
            for (a, yv) in yr.iter_mut().enumerate() {
                let pr = &proj[a * d..(a + 1) * d];
                let mut acc = 0.0f32;
                for (&j, &v) in idx.iter().zip(vals) {
                    acc += v * pr[j as usize];
                }
                *yv = acc;
            }
        }
    });
}

#[derive(Debug, Clone)]
pub struct LoGra {
    d_in: usize,
    d_out: usize,
    k_in: usize,
    k_out: usize,
    /// `k_in × d_in`, row-major, entries N(0, 1/k_in).
    p_in: Vec<f32>,
    /// `k_out × d_out`, row-major, entries N(0, 1/k_out).
    p_out: Vec<f32>,
}

impl LoGra {
    pub fn new(d_in: usize, d_out: usize, k_in: usize, k_out: usize, seed: u64) -> Self {
        assert!(k_in <= d_in && k_out <= d_out, "factor dims exceed layer dims");
        let mut rng = Pcg::new(seed ^ 0x106A);
        let gen = |rows: usize, cols: usize, rng: &mut Pcg| -> Vec<f32> {
            let scale = 1.0 / (rows as f32).sqrt();
            (0..rows * cols).map(|_| rng.next_gaussian() * scale).collect()
        };
        let p_in = gen(k_in, d_in, &mut rng);
        let p_out = gen(k_out, d_out, &mut rng);
        Self {
            d_in,
            d_out,
            k_in,
            k_out,
            p_in,
            p_out,
        }
    }

    pub fn k_in(&self) -> usize {
        self.k_in
    }

    pub fn k_out(&self) -> usize {
        self.k_out
    }

    /// Project the input factor: `Y(T×k_in) = X(T×d_in) · P_inᵀ`.
    /// Parallel over timesteps — this dense factor projection is LoGra's
    /// dominant cost and the baseline side of the Table 2 comparison.
    pub fn project_in(&self, t: usize, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), t * self.d_in);
        debug_assert_eq!(y.len(), t * self.k_in);
        let (d_in, k_in, p_in) = (self.d_in, self.k_in, &self.p_in);
        crate::util::par::par_chunks_mut(y, k_in, 16, |t_start, chunk| {
            for (off, yr) in chunk.chunks_mut(k_in).enumerate() {
                let ti = t_start + off;
                let xr = &x[ti * d_in..(ti + 1) * d_in];
                for (kk, yv) in yr.iter_mut().enumerate() {
                    let pr = &p_in[kk * d_in..(kk + 1) * d_in];
                    *yv = xr.iter().zip(pr).map(|(a, b)| a * b).sum();
                }
            }
        });
    }

    /// Project the output factor: `Z(T×k_out) = DY(T×d_out) · P_outᵀ`.
    pub fn project_out(&self, t: usize, dy: &[f32], z: &mut [f32]) {
        debug_assert_eq!(dy.len(), t * self.d_out);
        debug_assert_eq!(z.len(), t * self.k_out);
        let (d_out, k_out, p_out) = (self.d_out, self.k_out, &self.p_out);
        crate::util::par::par_chunks_mut(z, k_out, 16, |t_start, chunk| {
            for (off, zr) in chunk.chunks_mut(k_out).enumerate() {
                let ti = t_start + off;
                let dr = &dy[ti * d_out..(ti + 1) * d_out];
                for (kk, zv) in zr.iter_mut().enumerate() {
                    let pr = &p_out[kk * d_out..(kk + 1) * d_out];
                    *zv = dr.iter().zip(pr).map(|(a, b)| a * b).sum();
                }
            }
        });
    }
}

impl FactorizedCompressor for LoGra {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn output_dim(&self) -> usize {
        self.k_in * self.k_out
    }

    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), t * self.d_in);
        assert_eq!(dy.len(), t * self.d_out);
        assert_eq!(out.len(), self.k_in * self.k_out);
        let mut y = vec![0.0f32; t * self.k_in];
        let mut z = vec![0.0f32; t * self.k_out];
        self.project_in(t, x, &mut y);
        self.project_out(t, dy, &mut z);
        // out[a*k_out + b] = Σ_t y[t,a] z[t,b]  ==  Yᵀ Z
        matmul_at_b(&y, &z, out, t, self.k_in, self.k_out);
    }

    /// Batch kernel: the two dense factor projections run as **one** GEMM
    /// each over all `n·t` timesteps (`Y = X·P_inᵀ`, `Z = DY·P_outᵀ` via
    /// the register-tiled [`matmul_abt`]), amortising projector traversal
    /// across the whole batch; only the small `k_in×k_out` reconstruction
    /// stays per-sample, parallelised over samples with workspace buffers.
    #[allow(clippy::too_many_arguments)]
    fn compress_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let k = self.k_in * self.k_out;
        assert_eq!(x.len(), n * t * self.d_in);
        assert_eq!(dy.len(), n * t * self.d_out);
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        let nt = n * t;
        let mut y = scratch.take_f32(nt * self.k_in);
        let mut z = scratch.take_f32(nt * self.k_out);
        matmul_abt(x, &self.p_in, &mut y, nt, self.d_in, self.k_in);
        matmul_abt(dy, &self.p_out, &mut z, nt, self.d_out, self.k_out);
        let (k_in, k_out) = (self.k_in, self.k_out);
        {
            let (y, z) = (&y[..], &z[..]);
            par::par_chunks_mut(out, out_stride, 1, |row_start, chunk| {
                for (off, orow) in chunk.chunks_mut(out_stride).enumerate() {
                    let i = row_start + off;
                    matmul_at_b(
                        &y[i * t * k_in..(i + 1) * t * k_in],
                        &z[i * t * k_out..(i + 1) * t * k_out],
                        &mut orow[out_off..out_off + k],
                        t,
                        k_in,
                        k_out,
                    );
                }
            });
        }
        scratch.put_f32(y);
        scratch.put_f32(z);
    }

    /// CSR batch kernel: each factor side projects in `O(nnz · k)` per
    /// timestep row (see `project_sparse`) instead of the dense GEMM's
    /// `O(d · k)`; the small `k_in × k_out` per-sample reconstruction is
    /// unchanged. At 1% activation density this is the difference between
    /// `nnz·k` and `d·k` multiply-adds — the dense-baseline cost the paper
    /// contrasts sparsity-native compression against.
    #[allow(clippy::too_many_arguments)]
    fn compress_sparse_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &SparseRows,
        dy: &SparseRows,
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let k = self.k_in * self.k_out;
        assert_eq!(x.n(), n * t, "x row count mismatch");
        assert_eq!(dy.n(), n * t, "dy row count mismatch");
        assert_eq!(x.dim(), self.d_in, "x factor dimension mismatch");
        assert_eq!(dy.dim(), self.d_out, "dy factor dimension mismatch");
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        let nt = n * t;
        let mut y = scratch.take_f32(nt * self.k_in);
        let mut z = scratch.take_f32(nt * self.k_out);
        project_sparse(&self.p_in, self.d_in, self.k_in, x, &mut y);
        project_sparse(&self.p_out, self.d_out, self.k_out, dy, &mut z);
        let (k_in, k_out) = (self.k_in, self.k_out);
        {
            let (y, z) = (&y[..], &z[..]);
            par::par_chunks_mut(out, out_stride, 1, |row_start, chunk| {
                for (off, orow) in chunk.chunks_mut(out_stride).enumerate() {
                    let i = row_start + off;
                    matmul_at_b(
                        &y[i * t * k_in..(i + 1) * t * k_in],
                        &z[i * t * k_out..(i + 1) * t * k_out],
                        &mut orow[out_off..out_off + k],
                        t,
                        k_in,
                        k_out,
                    );
                }
            });
        }
        scratch.put_f32(y);
        scratch.put_f32(z);
    }

    /// The dense factor projections are `O(d·k)` GEMMs per timestep row,
    /// so CSR conversion wins below the crossover.
    fn sparse_dispatch_viable(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("LoGra[GAUSS_{}⊗{}]", self.k_in, self.k_out)
    }
}

/// Reference: materialise the full per-sample gradient `Σ_t dy_t x_tᵀ` and
/// apply the Kronecker projection densely — O(T·p_l) + O(p_l·k_l). Used by
/// tests to validate the factorized fast paths, and by the Table 2 harness
/// as the "materialise" strawman the paper rules out in §3.3.2.
pub fn project_via_materialized(
    logra: &LoGra,
    t: usize,
    x: &[f32],
    dy: &[f32],
) -> Vec<f32> {
    let (d_in, d_out) = (logra.d_in, logra.d_out);
    // G(d_in×d_out) = Xᵀ DY  (so vec index a*d_out+b == x_a * dy_b)
    let mut g = vec![0.0f32; d_in * d_out];
    matmul_at_b(x, dy, &mut g, t, d_in, d_out);
    // (P_in ⊗ P_out) vec(G): out[a,b] = Σ_{i,j} P_in[a,i] P_out[b,j] G[i,j]
    // = P_in · G · P_outᵀ
    let mut tmp = vec![0.0f32; logra.k_in * d_out];
    matmul(&logra.p_in, &g, &mut tmp, logra.k_in, d_in, d_out);
    let mut out = vec![0.0f32; logra.k_in * logra.k_out];
    for a in 0..logra.k_in {
        for b in 0..logra.k_out {
            let pr = &logra.p_out[b * d_out..(b + 1) * d_out];
            let tr = &tmp[a * d_out..(a + 1) * d_out];
            out[a * logra.k_out + b] = tr.iter().zip(pr).map(|(u, v)| u * v).sum();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    #[test]
    fn factorized_matches_materialized() {
        let (d_in, d_out, k_in, k_out, t) = (24, 16, 4, 3, 7);
        let lg = LoGra::new(d_in, d_out, k_in, k_out, 42);
        let mut rng = Pcg::new(1);
        let x: Vec<f32> = (0..t * d_in).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..t * d_out).map(|_| rng.next_gaussian()).collect();
        let fast = lg.compress(t, &x, &dy);
        let slow = project_via_materialized(&lg, t, &x, &dy);
        for i in 0..fast.len() {
            assert!(
                (fast[i] - slow[i]).abs() < 1e-3 * (1.0 + slow[i].abs()),
                "mismatch at {i}: {} vs {}",
                fast[i],
                slow[i]
            );
        }
    }

    #[test]
    fn single_timestep_is_plain_kron() {
        let (d_in, d_out, k_in, k_out) = (8, 6, 2, 2);
        let lg = LoGra::new(d_in, d_out, k_in, k_out, 7);
        let mut rng = Pcg::new(2);
        let x: Vec<f32> = (0..d_in).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..d_out).map(|_| rng.next_gaussian()).collect();
        let out = lg.compress(1, &x, &dy);
        // out[a*k_out+b] = (P_in x)_a (P_out dy)_b
        let mut px = vec![0.0f32; k_in];
        lg.project_in(1, &x, &mut px);
        let mut pdy = vec![0.0f32; k_out];
        lg.project_out(1, &dy, &mut pdy);
        for a in 0..k_in {
            for b in 0..k_out {
                let want = px[a] * pdy[b];
                assert!((out[a * k_out + b] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn norm_roughly_preserved_for_rank1() {
        // Kronecker of two JL maps preserves kron-structured norms.
        let (d_in, d_out, k_in, k_out) = (256, 256, 32, 32);
        let lg = LoGra::new(d_in, d_out, k_in, k_out, 9);
        let mut rng = Pcg::new(3);
        let x: Vec<f32> = (0..d_in).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..d_out).map(|_| rng.next_gaussian()).collect();
        let out = lg.compress(1, &x, &dy);
        let nx: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        let ndy: f64 = dy.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        let full = (nx * ndy).sqrt();
        let got = out
            .iter()
            .map(|&v| (v as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let ratio = got / full;
        assert!((0.6..1.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn csr_batch_matches_dense_batch() {
        let (d_in, d_out, k_in, k_out, n, t) = (64, 48, 8, 6, 3, 5);
        let lg = LoGra::new(d_in, d_out, k_in, k_out, 17);
        let mut rng = Pcg::new(6);
        let sparse_fill = |len: usize, rng: &mut Pcg| -> Vec<f32> {
            (0..len)
                .map(|_| {
                    if rng.next_f32() < 0.9 {
                        0.0
                    } else {
                        rng.next_gaussian()
                    }
                })
                .collect()
        };
        let x = sparse_fill(n * t * d_in, &mut rng);
        let dy = sparse_fill(n * t * d_out, &mut rng);
        let xs = SparseRows::from_dense_threshold(&x, n * t, d_in, 0.0);
        let dys = SparseRows::from_dense_threshold(&dy, n * t, d_out, 0.0);
        let k = lg.output_dim();
        let mut scratch = Scratch::new();
        let mut dense_out = vec![0.0f32; n * k];
        lg.compress_batch_with(n, t, &x, &dy, &mut dense_out, k, 0, &mut scratch);
        let mut sparse_out = vec![0.0f32; n * k];
        lg.compress_sparse_batch_with(n, t, &xs, &dys, &mut sparse_out, k, 0, &mut scratch);
        for i in 0..n * k {
            assert!(
                (dense_out[i] - sparse_out[i]).abs() <= 1e-4 * (1.0 + dense_out[i].abs()),
                "at {i}: {} vs {}",
                sparse_out[i],
                dense_out[i]
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let lg1 = LoGra::new(16, 16, 4, 4, 5);
        let lg2 = LoGra::new(16, 16, 4, 4, 5);
        let x = vec![1.0f32; 16];
        let dy = vec![0.5f32; 16];
        assert_eq!(lg1.compress(1, &x, &dy), lg2.compress(1, &x, &dy));
    }
}
