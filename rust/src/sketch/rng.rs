//! Deterministic, counter-based randomness for all compressors.
//!
//! Every projection in this crate is a *pure function of a seed* — the
//! projection "matrix" is never materialised unless an algorithm needs it
//! (LoGra's small factor matrices). Entries are derived from a splitmix64
//! hash of `(seed, coordinates...)`, which gives:
//!
//! - zero memory for SJLT / masks / Gaussian baselines at p = 10^5..10^10,
//! - bitwise reproducibility across threads and machines (the cache and
//!   attribute stages, and every LDS retrain, must agree on the projection),
//! - O(1) random access, so workers can partition work arbitrarily.

/// splitmix64 finalizer — a fast, well-mixed 64-bit hash.
#[inline(always)]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Hash a `(seed, a)` pair into a u64.
#[inline(always)]
pub fn hash2(seed: u64, a: u64) -> u64 {
    splitmix64(seed ^ splitmix64(a))
}

/// Hash a `(seed, a, b)` triple into a u64.
#[inline(always)]
pub fn hash3(seed: u64, a: u64, b: u64) -> u64 {
    splitmix64(seed ^ splitmix64(a ^ splitmix64(b)))
}

/// Map a u64 to a uniform f32 in [0, 1).
#[inline(always)]
pub fn to_unit_f32(x: u64) -> f32 {
    // Use the top 24 bits for an exactly-representable mantissa.
    ((x >> 40) as f32) * (1.0 / 16_777_216.0)
}

/// Map a u64 to a uniform f64 in [0, 1).
#[inline(always)]
pub fn to_unit_f64(x: u64) -> f64 {
    ((x >> 11) as f64) * (1.0 / 9_007_199_254_740_992.0)
}

/// Map a u64 to ±1.0 (Rademacher) using the low bit.
#[inline(always)]
pub fn to_sign(x: u64) -> f32 {
    if x & 1 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Map a pair of u64 hashes to one standard Gaussian via Box–Muller.
#[inline(always)]
pub fn to_gaussian(u: u64, v: u64) -> f32 {
    let u1 = to_unit_f64(u).max(1e-12);
    let u2 = to_unit_f64(v);
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// A small stateful PRNG (xorshift-star flavoured splitmix stream) for the
/// places where a *sequence* is more natural than counter addressing:
/// dataset synthesis, subset sampling, optimiser init.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self {
            state: splitmix64(seed ^ 0xDEAD_BEEF_CAFE_F00D),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        splitmix64(self.state)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        to_unit_f32(self.next_u64())
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        to_unit_f64(self.next_u64())
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the modulo bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard Gaussian sample.
    #[inline]
    pub fn next_gaussian(&mut self) -> f32 {
        let (u, v) = (self.next_u64(), self.next_u64());
        to_gaussian(u, v)
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm), sorted.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v as u32);
        }
        out.sort_unstable();
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_mixing() {
        assert_eq!(splitmix64(1), splitmix64(1));
        assert_ne!(splitmix64(1), splitmix64(2));
        // avalanche: flipping one input bit flips ~half the output bits
        let a = splitmix64(0x1234);
        let b = splitmix64(0x1235);
        let flipped = (a ^ b).count_ones();
        assert!((16..=48).contains(&flipped), "poor avalanche: {flipped}");
    }

    #[test]
    fn unit_f32_in_range() {
        for i in 0..10_000u64 {
            let u = to_unit_f32(splitmix64(i));
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        let n = 100_000;
        for i in 0..n as u64 {
            let g = to_gaussian(hash2(7, i), hash2(13, i)) as f64;
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut rng = Pcg::new(42);
        let idx = rng.sample_distinct(1000, 100);
        assert_eq!(idx.len(), 100);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 100);
        assert!(idx.iter().all(|&i| (i as usize) < 1000));
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut rng = Pcg::new(3);
        let idx = rng.sample_distinct(16, 16);
        assert_eq!(idx, (0..16u32).collect::<Vec<_>>());
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Pcg::new(9);
        for _ in 0..10_000 {
            assert!(rng.next_below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
