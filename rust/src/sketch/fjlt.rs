//! Fast Johnson–Lindenstrauss transform (`FJLT_k`) — the TRAK baseline
//! (Ailon–Chazelle 2009; Fandina et al. 2023). Implemented as a subsampled
//! randomized Hadamard transform (SRHT): `ĝ = √(p₂/k) · S · H · D · g`,
//! where `D` is a random sign flip, `H` the orthonormal Walsh–Hadamard
//! transform over the zero-padded power-of-two dimension `p₂`, and `S`
//! samples `k` coordinates. O((p + k) log p) per projection.
//!
//! Its algorithmic structure — a *dense* transform touching every padded
//! coordinate — is exactly why it cannot exploit input sparsity (paper
//! §3.1): nnz-scaling is impossible once H mixes all coordinates.

use super::rng::{hash2, to_sign, Pcg};
use super::{Compressor, Scratch};
use crate::linalg::fwht::{fwht_inplace, next_pow2};
use crate::util::par;

#[derive(Debug, Clone)]
pub struct Fjlt {
    p: usize,
    p2: usize,
    k: usize,
    seed: u64,
    /// Sampled output coordinates (len = k, with replacement per SRHT).
    sample: Vec<u32>,
    scale: f32,
}

impl Fjlt {
    pub fn new(p: usize, k: usize, seed: u64) -> Self {
        assert!(p > 0 && k > 0);
        let p2 = next_pow2(p);
        let mut rng = Pcg::new(seed ^ 0xF117);
        let sample: Vec<u32> = (0..k).map(|_| rng.next_below(p2) as u32).collect();
        Self {
            p,
            p2,
            k,
            seed,
            sample,
            scale: ((p2 as f64 / k as f64).sqrt()) as f32,
        }
    }

    /// The random sign for input coordinate j.
    #[inline(always)]
    fn sign(&self, j: usize) -> f32 {
        to_sign(hash2(self.seed, j as u64))
    }
}

impl Compressor for Fjlt {
    fn input_dim(&self) -> usize {
        self.p
    }

    fn output_dim(&self) -> usize {
        self.k
    }

    fn compress_into(&self, g: &[f32], out: &mut [f32]) {
        assert_eq!(g.len(), self.p);
        assert_eq!(out.len(), self.k);
        // D·g into the padded buffer
        let mut buf = vec![0.0f32; self.p2];
        for (j, &v) in g.iter().enumerate() {
            buf[j] = v * self.sign(j);
        }
        // H
        fwht_inplace(&mut buf);
        // S with scaling
        for (o, &s) in out.iter_mut().zip(&self.sample) {
            *o = buf[s as usize] * self.scale;
        }
    }

    /// Batch kernel: the sign flips `D` are hashed once per batch (not once
    /// per row), and the padded FWHT buffers for all rows live in one
    /// workspace allocation. Rows transform in parallel, then the
    /// subsampled gather writes each output row.
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        assert_eq!(gs.len(), n * self.p);
        assert_eq!(out.len(), n * self.k);
        let (p, p2, k) = (self.p, self.p2, self.k);
        // Hash the sign table once for the whole batch.
        let mut signs = scratch.take_f32(p);
        for (j, sv) in signs.iter_mut().enumerate() {
            *sv = self.sign(j);
        }
        // D·g then H, row-parallel over one shared padded buffer.
        let mut buf_all = scratch.take_f32(n * p2);
        {
            let signs = &signs[..];
            par::par_chunks_mut(&mut buf_all, p2, 1, |row_start, chunk| {
                for (off, brow) in chunk.chunks_mut(p2).enumerate() {
                    let g = &gs[(row_start + off) * p..(row_start + off + 1) * p];
                    for ((b, &v), &sv) in brow.iter_mut().zip(g).zip(signs) {
                        *b = v * sv;
                    }
                    fwht_inplace(brow);
                }
            });
        }
        // S with scaling
        let scale = self.scale;
        {
            let buf_all = &buf_all[..];
            par::par_chunks_mut(out, k, 8, |row_start, chunk| {
                for (off, orow) in chunk.chunks_mut(k).enumerate() {
                    let brow = &buf_all[(row_start + off) * p2..(row_start + off + 1) * p2];
                    for (o, &s) in orow.iter_mut().zip(&self.sample) {
                        *o = brow[s as usize] * scale;
                    }
                }
            });
        }
        scratch.put_f32(buf_all);
        scratch.put_f32(signs);
    }

    fn name(&self) -> String {
        format!("FJLT_{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn norm(v: &[f32]) -> f64 {
        v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn norm_preservation() {
        let (p, k) = (3000, 1024); // non-pow2 p exercises padding
        let t = Fjlt::new(p, k, 3);
        let mut rng = Pcg::new(4);
        for _ in 0..3 {
            let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
            let ratio = norm(&t.compress(&g)) / norm(&g);
            assert!((0.85..1.15).contains(&ratio), "ratio {ratio}");
        }
    }

    #[test]
    fn distance_preservation() {
        let (p, k) = (2048, 512);
        let t = Fjlt::new(p, k, 5);
        let mut rng = Pcg::new(6);
        let a: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let d: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x - y).collect();
        let (ca, cb) = (t.compress(&a), t.compress(&b));
        let dc: Vec<f32> = ca.iter().zip(&cb).map(|(x, y)| x - y).collect();
        let ratio = norm(&dc) / norm(&d);
        assert!((0.8..1.2).contains(&ratio), "distance ratio {ratio}");
    }

    #[test]
    fn deterministic() {
        let t = Fjlt::new(100, 16, 9);
        let g: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
        assert_eq!(t.compress(&g), t.compress(&g));
    }

    #[test]
    fn spike_input_spreads_energy() {
        // A 1-sparse input must spread across the Hadamard basis — the
        // structural reason FJLT can't exploit sparsity.
        let p = 256;
        let t = Fjlt::new(p, 64, 11);
        let mut g = vec![0.0f32; p];
        g[17] = 1.0;
        let out = t.compress(&g);
        let nnz_out = out.iter().filter(|&&x| x != 0.0).count();
        assert!(nnz_out > 32, "FJLT output unexpectedly sparse: {nnz_out}");
        let ratio = norm(&out);
        assert!((0.6..1.4).contains(&ratio), "spike norm {ratio}");
    }
}
