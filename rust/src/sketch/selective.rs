//! Selective Mask (`SM_k`) — paper §3.2, Eq. (1) and Appendix B.4.2.
//!
//! Learns a soft mask `σ(S) ∈ (0,1)^p` maximising the expected Pearson
//! correlation between full and masked GradDot attribution scores, minus an
//! ℓ1 sparsity penalty:
//!
//! `max_S  E_test[ corr( (⟨g_i, g_q⟩)_i , (⟨σ(S)⊙g_i, σ(S)⊙g_q⟩)_i ) ] − λ‖σ(S)‖₁`
//!
//! Because both sides are masked, the masked score is linear in
//! `w_j = σ(S_j)²`:  `â_i = Σ_j w_j g_i(j) g_q(j)`, so the objective
//! gradient is available in closed form — no autograd needed:
//!
//! `∂obj/∂w_j = E_q[ q(j) · (Gᵀ d_q)(j) ]`, where `d_q = ∂corr/∂â` is the
//! standard Pearson adjoint, and `∂w_j/∂S_j = 2 σ(S_j)² (1−σ(S_j))`.
//!
//! We optimise with Adam plus the paper's inverse-temperature annealing
//! (`S → S/T`, `T ↓`), then extract the top-k coordinates (App. B.4.2
//! "Ensuring Exact k"). The factorized variant for linear layers trains
//! `S_in, S_out` jointly using the Kronecker identity
//! `⟨x⊗d, x'⊗d'⟩ = ⟨x,x'⟩·⟨d,d'⟩`, never materialising layer gradients.

use super::mask::RandomMask;
use super::rng::Pcg;
use crate::util::par;

/// Hyper-parameters for the Eq. (1) optimiser.
#[derive(Debug, Clone)]
pub struct SelectiveMaskConfig {
    pub lambda: f32,
    pub lr: f32,
    pub steps: usize,
    /// Inverse-temperature annealing: T goes t_start → t_end geometrically.
    pub t_start: f32,
    pub t_end: f32,
    pub seed: u64,
}

impl Default for SelectiveMaskConfig {
    fn default() -> Self {
        Self {
            lambda: 0.5,
            lr: 0.05,
            steps: 60,
            t_start: 1.0,
            t_end: 0.25,
            seed: 0,
        }
    }
}

/// RMS-normalise a gradient so the ℓ1 weight λ is scale-free: at a uniform
/// mask the correlation gradient vanishes identically (â ∝ a), so absolute
/// magnitudes carry no meaning — only the relative per-coordinate signal
/// does. λ then acts as a threshold in RMS units.
fn rms_normalize(g: &mut [f32]) {
    let rms = (g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / g.len().max(1) as f64)
        .sqrt()
        .max(1e-12);
    let inv = (1.0 / rms) as f32;
    for v in g.iter_mut() {
        *v *= inv;
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Pearson adjoint: given fixed `a` and current `b`, returns
/// (corr, d corr / d b). Constant vectors get a zero adjoint.
fn pearson_and_adjoint(a: &[f32], b: &[f32]) -> (f64, Vec<f32>) {
    let n = a.len();
    debug_assert_eq!(n, b.len());
    let nf = n as f64;
    let am = a.iter().map(|&x| x as f64).sum::<f64>() / nf;
    let bm = b.iter().map(|&x| x as f64).sum::<f64>() / nf;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        let da = a[i] as f64 - am;
        let db = b[i] as f64 - bm;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    let (sa, sb) = ((va / nf).sqrt(), (vb / nf).sqrt());
    if sa < 1e-12 || sb < 1e-12 {
        return (0.0, vec![0.0; n]);
    }
    let r = (cov / nf) / (sa * sb);
    let adj: Vec<f32> = (0..n)
        .map(|i| {
            let da = (a[i] as f64 - am) / sa;
            let db = (b[i] as f64 - bm) / sb;
            ((da - r * db) / (nf * sb)) as f32
        })
        .collect();
    (r, adj)
}

/// Adam state over a parameter vector.
struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
    lr: f32,
}

impl Adam {
    fn new(dim: usize, lr: f32) -> Self {
        Self {
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
            lr,
        }
    }

    /// Ascent step (we maximise the objective).
    fn step(&mut self, theta: &mut [f32], grad: &[f32]) {
        self.t += 1;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        for j in 0..theta.len() {
            self.m[j] = b1 * self.m[j] + (1.0 - b1) * grad[j];
            self.v[j] = b2 * self.v[j] + (1.0 - b2) * grad[j] * grad[j];
            theta[j] += self.lr * (self.m[j] / bc1) / ((self.v[j] / bc2).sqrt() + eps);
        }
    }
}

/// Result of training a selective mask.
#[derive(Debug, Clone)]
pub struct TrainedMask {
    /// Final sigmoid scores per coordinate.
    pub scores: Vec<f32>,
    /// Objective (mean correlation) trajectory, one entry per step.
    pub corr_history: Vec<f64>,
}

impl TrainedMask {
    /// Top-k extraction (App B.4.2): adaptive threshold ensuring exactly k.
    pub fn top_k_indices(&self, k: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.scores.len() as u32).collect();
        order.sort_unstable_by(|&i, &j| {
            self.scores[j as usize]
                .partial_cmp(&self.scores[i as usize])
                .unwrap()
        });
        let mut idx: Vec<u32> = order[..k.min(order.len())].to_vec();
        idx.sort_unstable();
        idx
    }

    /// Materialise as a mask compressor over dimension `p`.
    pub fn into_mask(&self, p: usize, k: usize) -> RandomMask {
        RandomMask::from_indices(p, self.top_k_indices(k), None)
    }
}

/// Train a selective mask on dense per-sample gradients.
///
/// `train`: `n × p` row-major per-sample gradients (a subsample suffices);
/// `queries`: `m × p` row-major test gradients.
pub fn train_selective_mask(
    train: &[f32],
    queries: &[f32],
    n: usize,
    m: usize,
    p: usize,
    cfg: &SelectiveMaskConfig,
) -> TrainedMask {
    assert_eq!(train.len(), n * p);
    assert_eq!(queries.len(), m * p);
    assert!(n >= 3, "need ≥3 train samples for correlation");
    let mut rng = Pcg::new(cfg.seed ^ 0x534D);
    // Init with a real spread: at an exactly uniform mask â ∝ a and the
    // correlation gradient is identically zero, so symmetry must be broken
    // at init for the optimisation to discriminate coordinates.
    let mut s: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
    let mut adam = Adam::new(p, cfg.lr);
    let mut history = Vec::with_capacity(cfg.steps);

    // Precompute exact GradDot scores a[q][i] = <g_i, g_q>.
    let exact: Vec<Vec<f32>> = par::par_map_ranges(m, 1, |qr| {
        qr.map(|q| {
            let gq = &queries[q * p..(q + 1) * p];
            (0..n)
                .map(|i| {
                    let gi = &train[i * p..(i + 1) * p];
                    gi.iter().zip(gq).map(|(x, y)| x * y).sum()
                })
                .collect::<Vec<f32>>()
        })
        .collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect();

    for step in 0..cfg.steps {
        let frac = step as f32 / (cfg.steps.max(2) - 1) as f32;
        let temp = cfg.t_start * (cfg.t_end / cfg.t_start).powf(frac);
        let sig: Vec<f32> = s.iter().map(|&x| sigmoid(x / temp)).collect();
        let w: Vec<f32> = sig.iter().map(|&x| x * x).collect();

        // Accumulate ∂obj/∂w over queries (parallel over queries).
        let (grad_w, corr_sum) = par::par_map_reduce(
            m,
            1,
            |qr| {
                let mut gw_total = vec![0.0f32; p];
                let mut r_total = 0.0f64;
                for q in qr {
                    let gq = &queries[q * p..(q + 1) * p];
                    // â_i = <g_i, w ⊙ g_q>
                    let wq: Vec<f32> = w.iter().zip(gq).map(|(a, b)| a * b).collect();
                    let bhat: Vec<f32> = (0..n)
                        .map(|i| {
                            let gi = &train[i * p..(i + 1) * p];
                            gi.iter().zip(&wq).map(|(x, y)| x * y).sum()
                        })
                        .collect();
                    let (r, d) = pearson_and_adjoint(&exact[q], &bhat);
                    r_total += r;
                    // ∂obj_q/∂w_j = g_q(j) · Σ_i d_i g_i(j)
                    let mut gw = vec![0.0f32; p];
                    for i in 0..n {
                        let di = d[i];
                        if di == 0.0 {
                            continue;
                        }
                        let gi = &train[i * p..(i + 1) * p];
                        for j in 0..p {
                            gw[j] += di * gi[j];
                        }
                    }
                    for j in 0..p {
                        gw_total[j] += gw[j] * gq[j];
                    }
                }
                (gw_total, r_total)
            },
            |(mut ga, ra), (gb, rb)| {
                par::add_assign(&mut ga, &gb);
                (ga, ra + rb)
            },
        )
        .unwrap_or((vec![0.0f32; p], 0.0));
        history.push(corr_sum / m as f64);

        // Chain to S: ∂w/∂S = 2σ·σ'(S/T)/T ; ℓ1 term: −λσ'(S/T)/T.
        let mut gw = grad_w;
        rms_normalize(&mut gw);
        let grad_s: Vec<f32> = (0..p)
            .map(|j| {
                let sg = sig[j];
                let dsig = sg * (1.0 - sg) / temp;
                gw[j] * 2.0 * sg * dsig - cfg.lambda * dsig
            })
            .collect();
        adam.step(&mut s, &grad_s);
    }

    let frac = 1.0f32;
    let temp = cfg.t_start * (cfg.t_end / cfg.t_start).powf(frac);
    TrainedMask {
        scores: s.iter().map(|&x| sigmoid(x / temp)).collect(),
        corr_history: history,
    }
}

/// Factorized Selective Mask for linear layers (App B.4.2): learns
/// `S_in ∈ R^{d_in}` and `S_out ∈ R^{d_out}` jointly against the product
/// score `⟨x_i,x_q⟩·⟨d_i,d_q⟩`.
///
/// `xs`: `n × d_in` layer inputs (sequence-pooled); `dys`: `n × d_out`
/// pre-activation gradients; `xq`/`dq`: the same for `m` query samples.
#[allow(clippy::too_many_arguments)]
pub fn train_factorized_selective_mask(
    xs: &[f32],
    dys: &[f32],
    xq: &[f32],
    dq: &[f32],
    n: usize,
    m: usize,
    d_in: usize,
    d_out: usize,
    cfg: &SelectiveMaskConfig,
) -> (TrainedMask, TrainedMask) {
    assert_eq!(xs.len(), n * d_in);
    assert_eq!(dys.len(), n * d_out);
    assert_eq!(xq.len(), m * d_in);
    assert_eq!(dq.len(), m * d_out);
    let mut rng = Pcg::new(cfg.seed ^ 0xFAC7);
    // Non-trivial init spread — see `train_selective_mask` on why a uniform
    // mask is a stationary point of the correlation term.
    let mut s_in: Vec<f32> = (0..d_in).map(|_| rng.next_gaussian()).collect();
    let mut s_out: Vec<f32> = (0..d_out).map(|_| rng.next_gaussian()).collect();
    let mut adam_in = Adam::new(d_in, cfg.lr);
    let mut adam_out = Adam::new(d_out, cfg.lr);
    let mut history = Vec::with_capacity(cfg.steps);

    // Exact product scores a[q][i] = <x_i,x_q>·<d_i,d_q>.
    let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
    let exact: Vec<Vec<f32>> = (0..m)
        .map(|q| {
            (0..n)
                .map(|i| {
                    dot(&xs[i * d_in..(i + 1) * d_in], &xq[q * d_in..(q + 1) * d_in])
                        * dot(
                            &dys[i * d_out..(i + 1) * d_out],
                            &dq[q * d_out..(q + 1) * d_out],
                        )
                })
                .collect()
        })
        .collect();

    for step in 0..cfg.steps {
        let frac = step as f32 / (cfg.steps.max(2) - 1) as f32;
        let temp = cfg.t_start * (cfg.t_end / cfg.t_start).powf(frac);
        let sig_in: Vec<f32> = s_in.iter().map(|&x| sigmoid(x / temp)).collect();
        let sig_out: Vec<f32> = s_out.iter().map(|&x| sigmoid(x / temp)).collect();
        let w_in: Vec<f32> = sig_in.iter().map(|&x| x * x).collect();
        let w_out: Vec<f32> = sig_out.iter().map(|&x| x * x).collect();

        let mut grad_w_in = vec![0.0f32; d_in];
        let mut grad_w_out = vec![0.0f32; d_out];
        let mut corr_sum = 0.0f64;
        for q in 0..m {
            let xqv = &xq[q * d_in..(q + 1) * d_in];
            let dqv = &dq[q * d_out..(q + 1) * d_out];
            let wxq: Vec<f32> = w_in.iter().zip(xqv).map(|(a, b)| a * b).collect();
            let wdq: Vec<f32> = w_out.iter().zip(dqv).map(|(a, b)| a * b).collect();
            // Â_i, B̂_i and â_i = Â_i·B̂_i
            let ahat: Vec<f32> = (0..n)
                .map(|i| dot(&xs[i * d_in..(i + 1) * d_in], &wxq))
                .collect();
            let bhat: Vec<f32> = (0..n)
                .map(|i| dot(&dys[i * d_out..(i + 1) * d_out], &wdq))
                .collect();
            let prod: Vec<f32> = ahat.iter().zip(&bhat).map(|(a, b)| a * b).collect();
            let (r, adj) = pearson_and_adjoint(&exact[q], &prod);
            corr_sum += r;
            // ∂â_i/∂w_in_j = x_ij x_qj B̂_i  (product rule)
            for i in 0..n {
                let scale_in = adj[i] * bhat[i];
                let scale_out = adj[i] * ahat[i];
                if scale_in != 0.0 {
                    let xi = &xs[i * d_in..(i + 1) * d_in];
                    for j in 0..d_in {
                        grad_w_in[j] += scale_in * xi[j] * xqv[j];
                    }
                }
                if scale_out != 0.0 {
                    let di = &dys[i * d_out..(i + 1) * d_out];
                    for j in 0..d_out {
                        grad_w_out[j] += scale_out * di[j] * dqv[j];
                    }
                }
            }
        }
        history.push(corr_sum / m as f64);

        rms_normalize(&mut grad_w_in);
        rms_normalize(&mut grad_w_out);
        let gs_in: Vec<f32> = (0..d_in)
            .map(|j| {
                let sg = sig_in[j];
                let dsig = sg * (1.0 - sg) / temp;
                grad_w_in[j] * 2.0 * sg * dsig - cfg.lambda * dsig
            })
            .collect();
        let gs_out: Vec<f32> = (0..d_out)
            .map(|j| {
                let sg = sig_out[j];
                let dsig = sg * (1.0 - sg) / temp;
                grad_w_out[j] * 2.0 * sg * dsig - cfg.lambda * dsig
            })
            .collect();
        adam_in.step(&mut s_in, &gs_in);
        adam_out.step(&mut s_out, &gs_out);
    }

    let temp = cfg.t_end;
    (
        TrainedMask {
            scores: s_in.iter().map(|&x| sigmoid(x / temp)).collect(),
            corr_history: history.clone(),
        },
        TrainedMask {
            scores: s_out.iter().map(|&x| sigmoid(x / temp)).collect(),
            corr_history: history,
        },
    )
}

/// A trained selective mask packaged as a [`Compressor`] (alias for the
/// underlying index-extraction mask).
pub type SelectiveMask = RandomMask;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Compressor;

    /// Synthesise gradients with *effective parameter sparsity* (the paper's
    /// §3.2 premise): coordinates [0, sig) carry unit-scale values and so
    /// dominate every GradDot score, the rest are 20× smaller. A good
    /// selective mask keeps the high-scale block — the coordinates that
    /// explain the attribution scores.
    fn planted_problem(n: usize, m: usize, p: usize, sig: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg::new(101);
        let mut mk = |rows: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; rows * p];
            for r in 0..rows {
                for j in 0..p {
                    let scale = if j < sig { 1.0 } else { 0.05 };
                    out[r * p + j] = scale * rng.next_gaussian();
                }
            }
            out
        };
        (mk(n), mk(m))
    }

    #[test]
    fn pearson_adjoint_is_correct_fd() {
        // finite-difference check of the analytic adjoint
        let a = vec![1.0f32, 2.0, 0.5, -1.0, 3.0];
        let b = vec![0.9f32, 2.2, 0.1, -0.7, 2.5];
        let (r0, adj) = pearson_and_adjoint(&a, &b);
        let eps = 1e-3f32;
        for i in 0..b.len() {
            let mut bp = b.clone();
            bp[i] += eps;
            let (rp, _) = pearson_and_adjoint(&a, &bp);
            let fd = (rp - r0) / eps as f64;
            assert!(
                (fd - adj[i] as f64).abs() < 1e-2,
                "adjoint {i}: fd {fd} vs {}",
                adj[i]
            );
        }
    }

    #[test]
    fn pearson_handles_constant_vectors() {
        let a = vec![1.0f32; 5];
        let b = vec![0.0f32, 1.0, 2.0, 3.0, 4.0];
        let (r, adj) = pearson_and_adjoint(&a, &b);
        assert_eq!(r, 0.0);
        assert!(adj.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn selective_mask_finds_signal_coordinates() {
        let (n, m, p, sig) = (32, 4, 128, 16);
        let (train, queries) = planted_problem(n, m, p, sig);
        let cfg = SelectiveMaskConfig {
            steps: 40,
            lr: 0.1,
            lambda: 0.5,
            ..Default::default()
        };
        let tm = train_selective_mask(&train, &queries, n, m, p, &cfg);
        let top = tm.top_k_indices(sig);
        let hits = top.iter().filter(|&&j| (j as usize) < sig).count();
        assert!(
            hits >= sig * 2 / 3,
            "selective mask found only {hits}/{sig} signal coords: {top:?}"
        );
        // objective should improve over training
        let first = tm.corr_history[0];
        let last = *tm.corr_history.last().unwrap();
        assert!(last >= first - 0.05, "corr degraded: {first} -> {last}");
    }

    #[test]
    fn trained_mask_is_a_valid_compressor() {
        let (n, m, p, sig) = (16, 2, 64, 8);
        let (train, queries) = planted_problem(n, m, p, sig);
        let tm = train_selective_mask(&train, &queries, n, m, p, &Default::default());
        let mask = tm.into_mask(p, 8);
        assert_eq!(mask.output_dim(), 8);
        let out = mask.compress(&train[..p]);
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn factorized_selective_mask_runs_and_selects() {
        let (n, m, d_in, d_out) = (24, 3, 48, 32);
        let mut rng = Pcg::new(77);
        let sig_in = 8usize;
        let sig_out = 6usize;
        let mk = |rows: usize, d: usize, sig: usize, rng: &mut Pcg| -> Vec<f32> {
            let mut out = vec![0.0f32; rows * d];
            for r in 0..rows {
                for j in 0..d {
                    let scale = if j < sig { 1.0 } else { 0.05 };
                    out[r * d + j] = scale * rng.next_gaussian();
                }
            }
            out
        };
        let xs = mk(n, d_in, sig_in, &mut rng);
        let dys = mk(n, d_out, sig_out, &mut rng);
        let xq = mk(m, d_in, sig_in, &mut rng);
        let dq = mk(m, d_out, sig_out, &mut rng);
        let cfg = SelectiveMaskConfig {
            steps: 40,
            lr: 0.1,
            lambda: 0.5,
            ..Default::default()
        };
        let (tin, tout) =
            train_factorized_selective_mask(&xs, &dys, &xq, &dq, n, m, d_in, d_out, &cfg);
        let hits_in = tin
            .top_k_indices(sig_in)
            .iter()
            .filter(|&&j| (j as usize) < sig_in)
            .count();
        let hits_out = tout
            .top_k_indices(sig_out)
            .iter()
            .filter(|&&j| (j as usize) < sig_out)
            .count();
        assert!(hits_in >= sig_in / 2, "in-mask hits {hits_in}/{sig_in}");
        assert!(hits_out >= sig_out / 2, "out-mask hits {hits_out}/{sig_out}");
    }
}
