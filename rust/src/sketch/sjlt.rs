//! Sparse Johnson–Lindenstrauss transform (SJLT) — paper §3.1.
//!
//! Each input coordinate `j` contributes to exactly `s` output buckets
//! `h_r(j) ∈ [k]` with signs `σ_r(j) ∈ {±1}`, `r = 0..s`, scaled by
//! `1/√s` (Kane–Nelson). With `s = o(k)` this preserves JL geometry while
//! costing `O(s·nnz(g))` per projection — *independent of k* and scaling
//! with input sparsity, the two properties the paper exploits.
//!
//! ## Contention-free parallel layout (the paper's CUDA trick, for CPUs)
//!
//! The paper's CUDA kernel partitions *input* dimensions across threads to
//! avoid atomic scatter contention on the small output vector. We do the
//! same with scoped threads (`util::par`): each worker owns a private
//! `k`-length accumulator over its input chunk; accumulators are reduced
//! pairwise at the end. For the
//! problem sizes of the paper (k ≤ 8192) a private accumulator is 32 KB —
//! comfortably L1/L2-resident, so the scatter is cache-friendly.
//!
//! Bucket/sign streams are counter-based hashes of `(seed, j, r)` — no
//! projection matrix is ever materialised (see [`super::rng`]).

use super::rng::{hash3, to_sign};
use super::sparse::SparseRows;
use super::{Compressor, Scratch};
use crate::linalg::simd;
use crate::util::par;

/// Below this many input elements, parallel fan-out costs more than it saves.
const PAR_THRESHOLD: usize = 1 << 15;

/// Input coordinates per batch-kernel chunk. The (bucket, sign) table for a
/// chunk is `s · CHUNK` entries of 8 bytes — 32 KB at `s = 1` — so it stays
/// L1/L2-resident while every row in the batch scatters through it, instead
/// of materialising all `p·s` entries (which is O(p·s·8) bytes and explodes
/// at billion-scale `p`).
const BATCH_CHUNK: usize = 4096;

#[derive(Debug, Clone)]
pub struct Sjlt {
    p: usize,
    k: usize,
    s: usize,
    seed: u64,
    inv_sqrt_s: f32,
}

impl Sjlt {
    pub fn new(p: usize, k: usize, s: usize, seed: u64) -> Self {
        assert!(k > 0 && p > 0 && s > 0, "SJLT dims must be positive");
        assert!(s <= k, "s = {s} must be ≤ k = {k}");
        Self {
            p,
            k,
            s,
            seed,
            inv_sqrt_s: 1.0 / (s as f32).sqrt(),
        }
    }

    /// Number of output replicas per input coordinate.
    pub fn s(&self) -> usize {
        self.s
    }

    /// The bucket and sign for replica `r` of input coordinate `j`.
    #[inline(always)]
    pub fn bucket_sign(&self, j: usize, r: usize) -> (usize, f32) {
        let h = hash3(self.seed, j as u64, r as u64);
        // High bits choose the bucket (multiply-shift), low bit the sign —
        // independent enough for JL purposes and branch-free. The
        // multiply-shift maps a 63-bit value through `· k >> 63`, so the
        // result is strictly below `k` by construction — no clamp needed in
        // the hot loop.
        let bucket = ((h >> 1) as u128 * self.k as u128 >> 63) as usize;
        debug_assert!(bucket < self.k);
        (bucket, to_sign(h))
    }

    /// Scatter an index range of a dense vector into `acc` (+= semantics).
    #[inline]
    fn scatter_range(&self, g: &[f32], start: usize, acc: &mut [f32]) {
        for (off, &v) in g.iter().enumerate() {
            if v == 0.0 {
                continue; // nnz-scaling: zero entries cost one branch
            }
            let j = start + off;
            for r in 0..self.s {
                let (b, sgn) = self.bucket_sign(j, r);
                acc[b] += sgn * v;
            }
        }
    }
}

impl Compressor for Sjlt {
    fn input_dim(&self) -> usize {
        self.p
    }

    fn output_dim(&self) -> usize {
        self.k
    }

    fn compress_into(&self, g: &[f32], out: &mut [f32]) {
        assert_eq!(g.len(), self.p);
        assert_eq!(out.len(), self.k);
        if self.p < PAR_THRESHOLD {
            out.fill(0.0);
            self.scatter_range(g, 0, out);
        } else {
            // Input-partitioned, private-accumulator reduction (see module doc).
            let acc = par::par_map_reduce(
                self.p,
                PAR_THRESHOLD / 4,
                |r| {
                    let mut local = vec![0.0f32; self.k];
                    self.scatter_range(&g[r.clone()], r.start, &mut local);
                    local
                },
                |mut a, b| {
                    par::add_assign(&mut a, &b);
                    a
                },
            )
            .unwrap_or_else(|| vec![0.0f32; self.k]);
            out.copy_from_slice(&acc);
        }
        if self.s > 1 {
            simd::scale_inplace(out, self.inv_sqrt_s);
        }
    }

    /// Batch path: the (bucket, sign) stream depends only on (seed, j, r),
    /// so it is hashed **once per batch** instead of once per row —
    /// removing two splitmix rounds per element per row — and materialised
    /// in cache-resident chunks of [`BATCH_CHUNK`] coordinates (never the
    /// full `p·s` table). For each chunk, every row scatters that column
    /// range through the shared read-only table into its own output slice:
    /// the paper's contention-free layout, with rows partitioned across
    /// threads. Chunks are visited in ascending order, so per-bucket
    /// addition order matches the serial path exactly.
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        assert_eq!(gs.len(), n * self.p);
        assert_eq!(out.len(), n * self.k);
        let (p, k, s) = (self.p, self.k, self.s);
        let inv = self.inv_sqrt_s;
        out.fill(0.0);
        let chunk_cols = BATCH_CHUNK.min(p);
        let mut table = scratch.take_table(chunk_cols * s);
        let mut j0 = 0;
        while j0 < p {
            let cl = chunk_cols.min(p - j0);
            // Hash this chunk's (bucket, sign) entries once for all rows.
            for (off, ent) in table[..cl * s].chunks_mut(s).enumerate() {
                let j = j0 + off;
                for (r, e) in ent.iter_mut().enumerate() {
                    let (b, sgn) = self.bucket_sign(j, r);
                    *e = (b as u32, sgn);
                }
            }
            let table = &table[..cl * s];
            // Scatter the chunk for every row; each row owns its output
            // slice, so the parallel fan-out is contention-free. The
            // scatter itself is the SIMD-dispatched kernel: an 8-wide
            // zero-skip sweep that preserves ascending-j addition order.
            par::par_chunks_mut(out, k, 1, |row_start, rows| {
                for (off, orow) in rows.chunks_mut(k).enumerate() {
                    let i = row_start + off;
                    let g = &gs[i * p + j0..i * p + j0 + cl];
                    simd::sjlt_scatter(g, table, s, orow);
                }
            });
            j0 += cl;
        }
        if s > 1 {
            simd::scale_inplace(out, inv);
        }
        scratch.put_table(table);
    }

    /// CSR batch kernel — `O(s·nnz)` per row, the headline complexity of
    /// §3.1, with rows partitioned across threads (each row owns its output
    /// slice, so the scatter is contention-free).
    ///
    /// Unlike the dense batch kernel there is **no** shared bucket/sign
    /// table: supports differ per row, so a `p·s`-entry table would cost
    /// `O(p)` and defeat nnz-proportionality. Each non-zero instead pays
    /// one splitmix round per replica — hashing in bucket order matches the
    /// dense path's ascending-`j` accumulation order exactly, so sparse and
    /// dense outputs agree to fp-identical sums over the stored non-zeros.
    /// The per-nonzero hash+scatter stays scalar (no dense run of
    /// coordinates to sweep — see the `linalg::simd` dispatch table); only
    /// the final `1/√s` scale dispatches to SIMD.
    fn compress_sparse_batch_with(
        &self,
        rows: &SparseRows,
        out: &mut [f32],
        _scratch: &mut Scratch,
    ) {
        assert_eq!(rows.dim(), self.p, "sparse batch dimension mismatch");
        let (k, s) = (self.k, self.s);
        let n = rows.n();
        assert_eq!(out.len(), n * k);
        let inv = self.inv_sqrt_s;
        par::par_chunks_mut(out, k, 1, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let (idx, vals) = rows.row(row_start + off);
                orow.fill(0.0);
                for (&j, &v) in idx.iter().zip(vals) {
                    if v == 0.0 {
                        continue;
                    }
                    for r in 0..s {
                        let (b, sgn) = self.bucket_sign(j as usize, r);
                        orow[b] += sgn * v;
                    }
                }
                if s > 1 {
                    simd::scale_inplace(orow, inv);
                }
            }
        });
    }

    /// O(s·nnz) sparse path — the headline complexity of §3.1.
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        assert_eq!(out.len(), self.k);
        out.fill(0.0);
        for (&j, &v) in idx.iter().zip(vals) {
            if v == 0.0 {
                continue;
            }
            for r in 0..self.s {
                let (b, sgn) = self.bucket_sign(j as usize, r);
                out[b] += sgn * v;
            }
        }
        if self.s > 1 {
            simd::scale_inplace(out, self.inv_sqrt_s);
        }
    }

    /// The dense batch kernel scans all `p` coordinates per row, so CSR
    /// conversion wins below the crossover.
    fn sparse_dispatch_viable(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("SJLT_{}(s={})", self.k, self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn norm(v: &[f32]) -> f64 {
        v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn buckets_uniform_signs_balanced() {
        let t = Sjlt::new(1 << 16, 64, 1, 42);
        let mut counts = vec![0usize; 64];
        let mut signsum = 0i64;
        for j in 0..(1 << 16) {
            let (b, s) = t.bucket_sign(j, 0);
            counts[b] += 1;
            signsum += s as i64;
        }
        let expect = (1 << 16) / 64;
        for (b, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < 0.2 * expect as f64,
                "bucket {b} count {c} vs {expect}"
            );
        }
        assert!(signsum.unsigned_abs() < 2_000, "sign imbalance {signsum}");
    }

    #[test]
    fn norm_preservation_jl() {
        // E[|SJLT g|^2] = |g|^2; with k = 1024 the deviation is small.
        let p = 8192;
        let k = 1024;
        let t = Sjlt::new(p, k, 1, 7);
        let mut rng = Pcg::new(3);
        for _ in 0..5 {
            let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
            let out = t.compress(&g);
            let ratio = norm(&out) / norm(&g);
            assert!(
                (0.85..1.15).contains(&ratio),
                "norm ratio {ratio} out of JL band"
            );
        }
    }

    #[test]
    fn distance_preservation_pairwise() {
        let p = 4096;
        let k = 512;
        let t = Sjlt::new(p, k, 1, 11);
        let mut rng = Pcg::new(4);
        let xs: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..p).map(|_| rng.next_gaussian()).collect())
            .collect();
        let cs: Vec<Vec<f32>> = xs.iter().map(|x| t.compress(x)).collect();
        for i in 0..xs.len() {
            for j in (i + 1)..xs.len() {
                let d: Vec<f32> = xs[i].iter().zip(&xs[j]).map(|(a, b)| a - b).collect();
                let dc: Vec<f32> = cs[i].iter().zip(&cs[j]).map(|(a, b)| a - b).collect();
                let ratio = norm(&dc) / norm(&d);
                assert!(
                    (0.8..1.2).contains(&ratio),
                    "pairwise distance ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        // Above PAR_THRESHOLD the parallel path must agree bit-for-bit in sum
        // structure with the serial scatter (same buckets, fp-addition order
        // differs only across disjoint chunks merged once).
        let p = PAR_THRESHOLD * 2 + 123;
        let k = 256;
        let t = Sjlt::new(p, k, 1, 21);
        let mut rng = Pcg::new(8);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let par = t.compress(&g);
        let mut serial = vec![0.0f32; k];
        t.scatter_range(&g, 0, &mut serial);
        for i in 0..k {
            assert!((par[i] - serial[i]).abs() < 1e-3, "mismatch at {i}");
        }
    }

    #[test]
    fn s_greater_one_scaling() {
        // With s replicas the 1/sqrt(s) scaling keeps norms unbiased.
        let p = 4096;
        let k = 512;
        let mut rng = Pcg::new(5);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        for s in [2, 4, 8] {
            let t = Sjlt::new(p, k, s, 13);
            let ratio = norm(&t.compress(&g)) / norm(&g);
            assert!((0.85..1.15).contains(&ratio), "s={s} ratio {ratio}");
        }
    }

    #[test]
    fn batch_matches_single() {
        let (p, k, n) = (2000, 64, 5);
        for s in [1usize, 3] {
            let t = Sjlt::new(p, k, s, 17);
            let mut rng = Pcg::new(6);
            let gs: Vec<f32> = (0..n * p).map(|_| rng.next_gaussian()).collect();
            let mut batch = vec![0.0f32; n * k];
            t.compress_batch(&gs, n, &mut batch);
            for i in 0..n {
                let single = t.compress(&gs[i * p..(i + 1) * p]);
                for j in 0..k {
                    assert!(
                        (batch[i * k + j] - single[j]).abs() < 1e-4,
                        "s={s} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn csr_batch_matches_dense_batch() {
        let (p, k, n) = (3000, 64, 7);
        for s in [1usize, 3] {
            let t = Sjlt::new(p, k, s, 29);
            let mut rng = Pcg::new(12);
            let gs: Vec<f32> = (0..n * p)
                .map(|_| {
                    if rng.next_f32() < 0.97 {
                        0.0
                    } else {
                        rng.next_gaussian()
                    }
                })
                .collect();
            let rows = SparseRows::from_dense_threshold(&gs, n, p, 0.0);
            let mut scratch = Scratch::new();
            let mut dense_out = vec![0.0f32; n * k];
            t.compress_batch_with(&gs, n, &mut dense_out, &mut scratch);
            let mut sparse_out = vec![0.0f32; n * k];
            t.compress_sparse_batch_with(&rows, &mut sparse_out, &mut scratch);
            for i in 0..n * k {
                assert!(
                    (dense_out[i] - sparse_out[i]).abs() < 1e-4,
                    "s={s} at {i}: {} vs {}",
                    sparse_out[i],
                    dense_out[i]
                );
            }
        }
    }

    #[test]
    fn zero_input_zero_output() {
        let t = Sjlt::new(100, 10, 1, 0);
        assert!(t.compress(&vec![0.0; 100]).iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "must be")]
    fn s_larger_than_k_panics() {
        Sjlt::new(10, 4, 8, 0);
    }
}
