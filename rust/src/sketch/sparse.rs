//! CSR batches of per-sample gradients — the sparsity-native fast path.
//!
//! The paper's headline complexity (`O(s·nnz(g))` for SJLT, §3.1) only
//! materialises if the kernels never *touch* the zero coordinates. A
//! [`SparseRows`] batch stores `n` gradient rows in compressed sparse row
//! form — one shared `indices`/`values` arena plus `n + 1` row offsets —
//! so a 99%-sparse batch occupies (and streams) 1% of the dense bytes and
//! every sparse kernel walks exactly `nnz` entries per row.
//!
//! Rows keep their indices **sorted strictly increasing**, which the tuned
//! kernels rely on: [`super::mask::RandomMask`] merges two sorted index
//! lists in `O(nnz + k)`, and [`super::grass::Grass`] intersects the input
//! support with the mask support entirely in index space.
//!
//! For banks whose dense kernels cost `O(p)`-per-row or worse (see
//! [`super::Compressor::sparse_dispatch_viable`]), the pipeline's grad
//! workers density-[`probe`] each batch and convert it to CSR only below
//! [`SPARSE_DISPATCH_MAX_DENSITY`] (see [`should_dispatch_sparse`]), so
//! the compress workers run the sparse kernels on it — above the
//! crossover, the dense batch kernels win because they amortise projector
//! setup (e.g. SJLT's chunked bucket/sign tables) across rows, which
//! per-row sparse supports cannot.

/// Density at (or below) which the auto-dispatcher routes a gradient batch
/// through the CSR kernels — for compressors that opt in via
/// [`super::Compressor::sparse_dispatch_viable`].
///
/// Calibration, for the opted-in kernels (those whose dense batch cost
/// scales with the input width): SJLT's dense batch kernel costs one
/// table build of `p·s` hashes per batch plus one load+branch per element
/// per row, while the CSR kernel costs ~2 splitmix rounds per stored
/// non-zero. A hash is ≈3× a predicted load+branch, and the CSR
/// conversion itself scans the batch once, so the sparse path wins once
/// fewer than ~1 in 8 elements are non-zero and loses (by the same
/// argument, run backwards) above it. The LoGra/FactSjlt dense kernels
/// break even far higher (`nnz·k` vs `d·k` multiply-adds per row), so one
/// conservative constant serves every *viable* kernel. Compressors whose
/// dense path is already sub-linear in `p` (mask gathers, GraSS) never
/// opt in: no density makes conversion pay there, and the pipeline skips
/// the probe for them entirely.
pub const SPARSE_DISPATCH_MAX_DENSITY: f64 = 0.125;

/// Whether a batch with `nnz` non-zeros out of `elems` total elements
/// should take the sparse kernels — the pipeline's dispatch predicate,
/// split out so the crossover is unit-testable without a runtime.
#[inline]
pub fn should_dispatch_sparse(nnz: usize, elems: usize) -> bool {
    elems > 0 && (nnz as f64) <= SPARSE_DISPATCH_MAX_DENSITY * elems as f64
}

/// Count the non-zero entries of a dense buffer.
#[inline]
pub fn count_nnz(xs: &[f32]) -> usize {
    xs.iter().filter(|&&v| v != 0.0).count()
}

/// Early-exit density probe: decide [`should_dispatch_sparse`] for a
/// dense buffer while scanning as little of it as possible. Returns
/// `(go_sparse, nnz_seen, elems_scanned)` — the scan stops the moment the
/// running non-zero count exceeds the dispatch budget, so a fully dense
/// batch pays ~`SPARSE_DISPATCH_MAX_DENSITY` of a full pass rather than
/// all of it (a sparse verdict scans everything, but that batch is about
/// to be converted anyway). `go_sparse` always equals
/// `should_dispatch_sparse(count_nnz(xs), xs.len())`; the seen/scanned
/// counts feed the pipeline's input-density gauge.
pub fn probe(xs: &[f32]) -> (bool, usize, usize) {
    let budget = (SPARSE_DISPATCH_MAX_DENSITY * xs.len() as f64) as usize;
    let mut nnz = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v != 0.0 {
            nnz += 1;
            if nnz > budget {
                return (false, nnz, i + 1);
            }
        }
    }
    (!xs.is_empty(), nnz, xs.len())
}

/// A batch of `n` sparse rows over a `dim`-dimensional space, CSR layout.
///
/// Row `i` owns `indices[row_offsets[i]..row_offsets[i+1]]` (sorted
/// strictly increasing, each `< dim`) and the matching `values` slice.
/// Rows may be ragged (any per-row nnz, including empty rows).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseRows {
    dim: usize,
    /// `n + 1` offsets into `indices`/`values`; `row_offsets[0] == 0`.
    row_offsets: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl SparseRows {
    /// An empty batch (zero rows) over a `dim`-dimensional space.
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "need a positive row dimension");
        Self {
            dim,
            row_offsets: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Convert `n` dense rows (`n × dim`, row-major), keeping entries with
    /// `|v| > threshold`. `threshold = 0.0` keeps exactly the non-zeros.
    /// NaN entries are always kept: dropping them would let the sparse
    /// path cache clean-looking rows where the dense kernels would
    /// propagate (and surface) the corruption.
    pub fn from_dense_threshold(gs: &[f32], n: usize, dim: usize, threshold: f32) -> Self {
        assert_eq!(gs.len(), n * dim, "dense batch shape mismatch");
        let mut out = Self::new(dim);
        for row in gs.chunks(dim) {
            for (j, &v) in row.iter().enumerate() {
                if v.abs() > threshold || v.is_nan() {
                    out.indices.push(j as u32);
                    out.values.push(v);
                }
            }
            out.row_offsets.push(out.indices.len());
        }
        out
    }

    /// Append one row. `idx` must be sorted strictly increasing with every
    /// entry `< dim`; `idx` and `vals` must have equal length.
    pub fn push_row(&mut self, idx: &[u32], vals: &[f32]) {
        assert_eq!(idx.len(), vals.len(), "row index/value length mismatch");
        // Hard assert: the merge kernels (RandomMask, GraSS) rely on
        // sortedness for correctness and would silently drop entries of an
        // unsorted row — the O(nnz) check costs no more than the push.
        assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "row indices must be sorted strictly increasing"
        );
        if let Some(&last) = idx.last() {
            assert!((last as usize) < self.dim, "row index out of range");
        }
        self.indices.extend_from_slice(idx);
        self.values.extend_from_slice(vals);
        self.row_offsets.push(self.indices.len());
    }

    /// Row dimension (the dense width each row sparsifies).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of rows.
    pub fn n(&self) -> usize {
        self.row_offsets.len() - 1
    }

    /// Total stored non-zeros across all rows.
    pub fn nnz_total(&self) -> usize {
        self.indices.len()
    }

    /// Stored non-zeros in row `i`.
    pub fn nnz(&self, i: usize) -> usize {
        self.row_offsets[i + 1] - self.row_offsets[i]
    }

    /// Row `i` as `(sorted indices, values)`.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.row_offsets[i], self.row_offsets[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Fraction of stored entries over the dense `n × dim` size (0 for an
    /// empty batch).
    pub fn density(&self) -> f64 {
        let elems = self.n() * self.dim;
        if elems == 0 {
            0.0
        } else {
            self.nnz_total() as f64 / elems as f64
        }
    }

    /// Mean stored non-zeros per row (0 for an empty batch).
    pub fn mean_nnz(&self) -> f64 {
        if self.n() == 0 {
            0.0
        } else {
            self.nnz_total() as f64 / self.n() as f64
        }
    }

    /// Scatter into a dense `n × dim` buffer (fully overwritten).
    pub fn densify_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.n() * self.dim, "dense output shape mismatch");
        out.fill(0.0);
        for (i, orow) in out.chunks_mut(self.dim).enumerate() {
            let (idx, vals) = self.row(i);
            for (&j, &v) in idx.iter().zip(vals) {
                orow[j as usize] = v;
            }
        }
    }

    /// Allocating form of [`SparseRows::densify_into`].
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n() * self.dim];
        self.densify_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_exact() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0, 0.0, 3.0, 0.0];
        let sp = SparseRows::from_dense_threshold(&dense, 2, 4, 0.0);
        assert_eq!(sp.n(), 2);
        assert_eq!(sp.dim(), 4);
        assert_eq!(sp.nnz_total(), 3);
        assert_eq!(sp.nnz(0), 2);
        assert_eq!(sp.nnz(1), 1);
        assert_eq!(sp.row(0), (&[1u32, 3][..], &[1.5f32, -2.0][..]));
        assert_eq!(sp.to_dense(), dense);
    }

    #[test]
    fn threshold_drops_small_entries() {
        let dense = vec![0.05, 1.0, -0.05, 2.0];
        let sp = SparseRows::from_dense_threshold(&dense, 1, 4, 0.1);
        assert_eq!(sp.row(0), (&[1u32, 3][..], &[1.0f32, 2.0][..]));
        assert_eq!(sp.density(), 0.5);
        assert_eq!(sp.mean_nnz(), 2.0);
    }

    #[test]
    fn nan_entries_survive_conversion() {
        // A diverged gradient's NaNs must flow through the CSR path just
        // as the dense kernels would propagate them.
        let dense = vec![0.0, f32::NAN, 0.0, 1.0];
        let sp = SparseRows::from_dense_threshold(&dense, 1, 4, 0.0);
        assert_eq!(sp.nnz(0), 2);
        let (idx, vals) = sp.row(0);
        assert_eq!(idx, &[1u32, 3]);
        assert!(vals[0].is_nan());
        assert_eq!(vals[1], 1.0);
    }

    #[test]
    fn push_row_and_empty_rows() {
        let mut sp = SparseRows::new(10);
        sp.push_row(&[2, 7], &[1.0, 2.0]);
        sp.push_row(&[], &[]);
        sp.push_row(&[9], &[-3.0]);
        assert_eq!(sp.n(), 3);
        assert_eq!(sp.nnz(1), 0);
        let dense = sp.to_dense();
        assert_eq!(dense.len(), 30);
        assert_eq!(dense[2], 1.0);
        assert_eq!(dense[10..20], [0.0; 10]);
        assert_eq!(dense[29], -3.0);
    }

    #[test]
    fn empty_batch_density_zero() {
        let sp = SparseRows::new(8);
        assert_eq!(sp.n(), 0);
        assert_eq!(sp.density(), 0.0);
        assert_eq!(sp.mean_nnz(), 0.0);
        assert!(sp.to_dense().is_empty());
    }

    #[test]
    fn dispatch_crossover() {
        // exactly at the threshold dispatches sparse; one non-zero above
        // it dispatches dense.
        let elems = 8000;
        let at = (SPARSE_DISPATCH_MAX_DENSITY * elems as f64) as usize;
        assert!(should_dispatch_sparse(at, elems));
        assert!(!should_dispatch_sparse(at + 1, elems));
        assert!(!should_dispatch_sparse(0, 0), "empty batch stays dense");
        let mut dense = vec![0.0f32; 100];
        dense[3] = 1.0;
        dense[77] = -1.0;
        assert_eq!(count_nnz(&dense), 2);
        assert!(should_dispatch_sparse(count_nnz(&dense), dense.len()));
    }

    #[test]
    fn probe_matches_full_predicate_and_exits_early() {
        // Property: probe's verdict equals the full-scan predicate, at
        // every density around the crossover (incl. exactly at it).
        let n = 4096;
        for planted in [0usize, 1, 500, 512, 513, 1000, n] {
            let mut xs = vec![0.0f32; n];
            for v in xs.iter_mut().take(planted) {
                *v = 1.0;
            }
            let (go, nnz_seen, scanned) = probe(&xs);
            assert_eq!(
                go,
                should_dispatch_sparse(count_nnz(&xs), xs.len()),
                "planted {planted}"
            );
            assert!(scanned <= n);
            assert!(nnz_seen <= planted);
            if go {
                assert_eq!((nnz_seen, scanned), (planted, n), "sparse verdict scans fully");
            }
        }
        // Dense verdict exits early: non-zeros up front stop the scan at
        // budget + 1 elements.
        let mut xs = vec![1.0f32; n];
        xs[0] = 1.0;
        let budget = (SPARSE_DISPATCH_MAX_DENSITY * n as f64) as usize;
        let (go, nnz_seen, scanned) = probe(&xs);
        assert!(!go);
        assert_eq!(nnz_seen, budget + 1);
        assert_eq!(scanned, budget + 1);
        // Empty buffer: dense (nothing to win).
        assert_eq!(probe(&[]), (false, 0, 0));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn push_row_rejects_out_of_range() {
        let mut sp = SparseRows::new(4);
        sp.push_row(&[4], &[1.0]);
    }

    #[test]
    #[should_panic(expected = "sorted strictly increasing")]
    fn push_row_rejects_unsorted() {
        // The merge kernels would silently drop entries of an unsorted
        // row, so the invariant is a hard assert even in release builds.
        let mut sp = SparseRows::new(10);
        sp.push_row(&[7, 2], &[1.0, 2.0]);
    }
}
