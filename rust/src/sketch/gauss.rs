//! Dense Gaussian random projection (`GAUSS_k`) — the classical JL baseline
//! (Wojnowicz et al. 2016; TRAK's `RANDOM`). O(pk) time; the projection
//! matrix `P_ij ~ N(0, 1/k)` is *never stored* — entries are counter-based
//! hashes of `(seed, i, j)`, so memory stays O(1) even at p = 10^9 where the
//! paper notes the matrix "is too large to fit in GPU memory".
//!
//! Also provides the dense Rademacher variant (`±1/√k`, Fig. 1 of the
//! paper), which is ~3× faster to generate and JL-equivalent.

use super::rng::{hash3, to_gaussian, to_sign};
use super::{Compressor, Scratch};
use crate::util::par;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseEntry {
    Gaussian,
    Rademacher,
}

#[derive(Debug, Clone)]
pub struct GaussianProjection {
    p: usize,
    k: usize,
    seed: u64,
    entry: DenseEntry,
    inv_sqrt_k: f32,
}

impl GaussianProjection {
    pub fn new(p: usize, k: usize, seed: u64) -> Self {
        Self::with_entry(p, k, seed, DenseEntry::Gaussian)
    }

    pub fn rademacher(p: usize, k: usize, seed: u64) -> Self {
        Self::with_entry(p, k, seed, DenseEntry::Rademacher)
    }

    pub fn with_entry(p: usize, k: usize, seed: u64, entry: DenseEntry) -> Self {
        assert!(p > 0 && k > 0);
        Self {
            p,
            k,
            seed,
            entry,
            inv_sqrt_k: 1.0 / (k as f32).sqrt(),
        }
    }

    /// P[i][j] (unnormalised; the 1/√k factor is applied at the end).
    #[inline(always)]
    fn entry(&self, i: usize, j: usize) -> f32 {
        let h = hash3(self.seed, i as u64, j as u64);
        match self.entry {
            DenseEntry::Gaussian => to_gaussian(h, h ^ 0x9E37_79B9_7F4A_7C15),
            DenseEntry::Rademacher => to_sign(h),
        }
    }

    fn row_dot(&self, i: usize, g: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (j, &v) in g.iter().enumerate() {
            acc += self.entry(i, j) * v;
        }
        acc * self.inv_sqrt_k
    }
}

impl Compressor for GaussianProjection {
    fn input_dim(&self) -> usize {
        self.p
    }

    fn output_dim(&self) -> usize {
        self.k
    }

    fn compress_into(&self, g: &[f32], out: &mut [f32]) {
        assert_eq!(g.len(), self.p);
        assert_eq!(out.len(), self.k);
        if self.k * self.p < (1 << 18) {
            for (i, o) in out.iter_mut().enumerate() {
                *o = self.row_dot(i, g);
            }
        } else {
            par::par_chunks_mut(out, 1, 1, |start, chunk| {
                for (off, o) in chunk.iter_mut().enumerate() {
                    *o = self.row_dot(start + off, g);
                }
            });
        }
    }

    /// O(k·nnz): dense rows evaluated only at non-zero input coordinates
    /// (paper §3.1: "for a dense matrix projection, the complexity becomes
    /// O(k·nnz(g))").
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        assert_eq!(out.len(), self.k);
        par::par_chunks_mut(out, 1, 16, |start, chunk| {
            for (off, o) in chunk.iter_mut().enumerate() {
                let i = start + off;
                let mut acc = 0.0f32;
                for (&j, &v) in idx.iter().zip(vals) {
                    acc += self.entry(i, j as usize) * v;
                }
                *o = acc * self.inv_sqrt_k;
            }
        });
    }

    /// Blocked-matmul batch path: generate `P` in row blocks (so memory
    /// stays bounded at `block·p` floats, drawn from the workspace) and
    /// multiply all inputs against each block — the cache/BLAS-friendly
    /// formulation of the dense baseline, analogous to the paper's
    /// torch.matmul reference.
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], scratch: &mut Scratch) {
        assert_eq!(gs.len(), n * self.p);
        assert_eq!(out.len(), n * self.k);
        const BLOCK: usize = 64;
        let kb_max = BLOCK.min(self.k);
        let mut bt = scratch.take_f32(self.p * kb_max);
        let mut tmp = scratch.take_f32(n * kb_max);
        let mut i0 = 0;
        while i0 < self.k {
            let kb = BLOCK.min(self.k - i0);
            // bt: p × kb block of Pᵀ, generated counter-based in parallel.
            par::par_chunks_mut(&mut bt[..self.p * kb], kb, 256, |j_start, chunk| {
                for (off, row) in chunk.chunks_mut(kb).enumerate() {
                    let j = j_start + off;
                    for (c, v) in row.iter_mut().enumerate() {
                        *v = self.entry(i0 + c, j);
                    }
                }
            });
            crate::linalg::matmul::matmul(gs, &bt[..self.p * kb], &mut tmp[..n * kb], n, self.p, kb);
            for r in 0..n {
                for c in 0..kb {
                    out[r * self.k + i0 + c] = tmp[r * kb + c] * self.inv_sqrt_k;
                }
            }
            i0 += kb;
        }
        scratch.put_f32(bt);
        scratch.put_f32(tmp);
    }

    fn name(&self) -> String {
        match self.entry {
            DenseEntry::Gaussian => format!("GAUSS_{}", self.k),
            DenseEntry::Rademacher => format!("RADEM_{}", self.k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;

    fn norm(v: &[f32]) -> f64 {
        v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn norm_preservation() {
        let (p, k) = (2048, 512);
        let mut rng = Pcg::new(1);
        let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        for entry in [DenseEntry::Gaussian, DenseEntry::Rademacher] {
            let proj = GaussianProjection::with_entry(p, k, 5, entry);
            let ratio = norm(&proj.compress(&g)) / norm(&g);
            assert!((0.85..1.15).contains(&ratio), "{entry:?} ratio {ratio}");
        }
    }

    #[test]
    fn inner_product_preservation() {
        let (p, k) = (2048, 1024);
        let mut rng = Pcg::new(2);
        let a: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let proj = GaussianProjection::new(p, k, 9);
        let (ca, cb) = (proj.compress(&a), proj.compress(&b));
        let exact: f64 = a.iter().zip(&b).map(|(x, y)| (x * y) as f64).sum();
        let approx: f64 = ca.iter().zip(&cb).map(|(x, y)| (x * y) as f64).sum();
        // |error| = O(|a||b|/sqrt(k)) ≈ 2048/32 = 64
        assert!(
            (exact - approx).abs() < 200.0,
            "inner product: {exact} vs {approx}"
        );
    }

    #[test]
    fn deterministic_across_calls_and_seeds_differ() {
        let proj = GaussianProjection::new(256, 32, 7);
        let g: Vec<f32> = (0..256).map(|i| (i as f32).sin()).collect();
        assert_eq!(proj.compress(&g), proj.compress(&g));
        let proj2 = GaussianProjection::new(256, 32, 8);
        assert_ne!(proj.compress(&g), proj2.compress(&g));
    }

    #[test]
    fn batch_matches_single() {
        let (p, k, n) = (300, 70, 5); // k not a multiple of the block
        let proj = GaussianProjection::new(p, k, 11);
        let mut rng = Pcg::new(4);
        let gs: Vec<f32> = (0..n * p).map(|_| rng.next_gaussian()).collect();
        let mut batch = vec![0.0f32; n * k];
        proj.compress_batch(&gs, n, &mut batch);
        for i in 0..n {
            let single = proj.compress(&gs[i * p..(i + 1) * p]);
            for j in 0..k {
                assert!(
                    (batch[i * k + j] - single[j]).abs() < 1e-3,
                    "({i},{j}): {} vs {}",
                    batch[i * k + j],
                    single[j]
                );
            }
        }
    }

    #[test]
    fn entries_have_unit_variance() {
        let proj = GaussianProjection::new(10_000, 4, 3);
        let mut sq = 0.0f64;
        for j in 0..10_000 {
            let e = proj.entry(0, j) as f64;
            sq += e * e;
        }
        let var = sq / 10_000.0;
        assert!((var - 1.0).abs() < 0.08, "entry var {var}");
    }
}
