//! FactGraSS (`SJLT_{k_l} ∘ MASK_{k_in' ⊗ k_out'}`) — paper §3.3.2.
//!
//! The factorized GraSS for linear layers, in three stages per sample:
//!
//! 1. **Sparsification** — mask the layer input `x_t ∈ R^{d_in}` to
//!    `k_in'` coordinates and the pre-activation gradient `dy_t ∈ R^{d_out}`
//!    to `k_out'` coordinates (O(k_in') + O(k_out') per timestep);
//! 2. **Reconstruction** — form the *sparsified* gradient
//!    `g' = Σ_t x'_t ⊗ dy'_t = vec(X'ᵀ DY')` of dimension
//!    `k' = k_in'·k_out'` (O(T·k') — never the full `d_in·d_out` gradient);
//! 3. **Sparse projection** — SJLT `g'` down to the target `k_l` (O(k')).
//!
//! Overall O(k'_l) time and space per sample — sub-linear in `p_l`, and
//! faster than LoGra whenever the blow-up factor `c = k'/k` satisfies
//! `c ≤ √(p_l/k_l)` (trivially true at e.g. `p_l = 4096²`, `k_l = 64²`,
//! `c ≤ 64`).

use super::mask::RandomMask;
use super::rng::Pcg;
use super::sjlt::Sjlt;
use super::sparse::SparseRows;
use super::{Compressor, FactorizedCompressor, MaskKind, Scratch};
use crate::linalg::matmul::matmul_at_b;
use crate::util::par;

pub struct FactGrass {
    d_in: usize,
    d_out: usize,
    /// Stage-1 masks over the two factors.
    mask_in: RandomMask,
    mask_out: RandomMask,
    /// Stage-3 SJLT over the k_in'·k_out' reconstructed vector.
    sjlt: Sjlt,
    k: usize,
}

impl FactGrass {
    /// `k_in_p`/`k_out_p` are the intermediate (post-mask) factor dims; `k`
    /// is the final compressed dim. Paper default: `k_in' = 2·k_in`,
    /// `k_out' = 2·k_out` with `k = k_in·k_out`.
    pub fn new(
        d_in: usize,
        d_out: usize,
        k_in_p: usize,
        k_out_p: usize,
        k: usize,
        kind: MaskKind,
        seed: u64,
    ) -> Self {
        assert!(k_in_p <= d_in && k_out_p <= d_out, "mask dims exceed layer dims");
        assert!(k <= k_in_p * k_out_p, "target k exceeds reconstructed dim");
        let salt = match kind {
            MaskKind::Random => 0x4653u64,
            MaskKind::Selective => 0x5346u64,
        };
        let mut rng = Pcg::new(seed ^ salt);
        let mask_in = RandomMask::from_indices(
            d_in,
            rng.sample_distinct(d_in, k_in_p),
            Some(((d_in as f64 / k_in_p as f64).sqrt()) as f32),
        );
        let mask_out = RandomMask::from_indices(
            d_out,
            rng.sample_distinct(d_out, k_out_p),
            Some(((d_out as f64 / k_out_p as f64).sqrt()) as f32),
        );
        Self {
            d_in,
            d_out,
            mask_in,
            mask_out,
            sjlt: Sjlt::new(k_in_p * k_out_p, k, 1, seed ^ 0xFA57),
            k,
        }
    }

    /// Build with explicit (e.g. selective-trained) factor masks.
    pub fn with_masks(
        d_in: usize,
        d_out: usize,
        mask_in: RandomMask,
        mask_out: RandomMask,
        k: usize,
        seed: u64,
    ) -> Self {
        assert_eq!(mask_in.input_dim(), d_in);
        assert_eq!(mask_out.input_dim(), d_out);
        let kp = mask_in.output_dim() * mask_out.output_dim();
        assert!(k <= kp);
        Self {
            d_in,
            d_out,
            sjlt: Sjlt::new(kp, k, 1, seed ^ 0xFA57),
            mask_in,
            mask_out,
            k,
        }
    }

    pub fn k_in_p(&self) -> usize {
        self.mask_in.output_dim()
    }

    pub fn k_out_p(&self) -> usize {
        self.mask_out.output_dim()
    }

    /// Stage 1+2: reconstruct the sparsified gradient `vec(X'ᵀ DY')`
    /// (exposed for tests and the L1 Pallas kernel cross-check).
    pub fn reconstruct(&self, t: usize, x: &[f32], dy: &[f32]) -> Vec<f32> {
        let (ki, ko) = (self.k_in_p(), self.k_out_p());
        let mut xp = vec![0.0f32; t * ki];
        let mut dp = vec![0.0f32; t * ko];
        for ti in 0..t {
            self.mask_in.compress_into(
                &x[ti * self.d_in..(ti + 1) * self.d_in],
                &mut xp[ti * ki..(ti + 1) * ki],
            );
            self.mask_out.compress_into(
                &dy[ti * self.d_out..(ti + 1) * self.d_out],
                &mut dp[ti * ko..(ti + 1) * ko],
            );
        }
        let mut g = vec![0.0f32; ki * ko];
        matmul_at_b(&xp, &dp, &mut g, t, ki, ko);
        g
    }

    /// Batched stages 1+2: factor-mask all `n·t` timesteps with two
    /// parallel gathers, then run the per-sample `X'ᵀ DY'` reconstruction
    /// across samples. Returns the workspace-owned `n × (k_in'·k_out')`
    /// matrix of reconstructed gradients — the caller must hand it back via
    /// `scratch.put_f32`. This hoists the scalar path's per-sample
    /// `xp`/`dp`/`g` allocations into the shared workspace.
    fn reconstruct_batch(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        let (ki, ko) = (self.k_in_p(), self.k_out_p());
        let nt = n * t;
        let mut xp = scratch.take_f32(nt * ki);
        let mut dp = scratch.take_f32(nt * ko);
        self.mask_in.compress_batch_with(x, nt, &mut xp, scratch);
        self.mask_out.compress_batch_with(dy, nt, &mut dp, scratch);
        let g = self.outer_products(n, t, &xp, &dp, scratch);
        scratch.put_f32(xp);
        scratch.put_f32(dp);
        g
    }

    /// CSR variant of [`FactGrass::reconstruct_batch`]: both factor sides
    /// arrive as sparse timestep rows and are masked by the `O(nnz + k')`
    /// merge-gather kernel, so stage 1 never reads a zero activation. The
    /// masked factors are tiny and dense, so stages 2+3 are shared with the
    /// dense path unchanged.
    fn reconstruct_batch_sparse(
        &self,
        n: usize,
        t: usize,
        x: &SparseRows,
        dy: &SparseRows,
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        let (ki, ko) = (self.k_in_p(), self.k_out_p());
        let nt = n * t;
        let mut xp = scratch.take_f32(nt * ki);
        let mut dp = scratch.take_f32(nt * ko);
        self.mask_in.compress_sparse_batch_with(x, &mut xp, scratch);
        self.mask_out.compress_sparse_batch_with(dy, &mut dp, scratch);
        let g = self.outer_products(n, t, &xp, &dp, scratch);
        scratch.put_f32(xp);
        scratch.put_f32(dp);
        g
    }

    /// Stage 2 shared by the dense and CSR batch paths: the per-sample
    /// `X'ᵀ DY'` accumulation over the masked factors, parallel over
    /// samples into a workspace-owned `n × (k_in'·k_out')` matrix (the
    /// caller hands it back via `scratch.put_f32`).
    fn outer_products(
        &self,
        n: usize,
        t: usize,
        xp: &[f32],
        dp: &[f32],
        scratch: &mut Scratch,
    ) -> Vec<f32> {
        let (ki, ko) = (self.k_in_p(), self.k_out_p());
        let mut g = scratch.take_f32(n * ki * ko);
        par::par_chunks_mut(&mut g, ki * ko, 1, |row_start, chunk| {
            for (off, grow) in chunk.chunks_mut(ki * ko).enumerate() {
                let i = row_start + off;
                matmul_at_b(
                    &xp[i * t * ki..(i + 1) * t * ki],
                    &dp[i * t * ko..(i + 1) * t * ko],
                    grow,
                    t,
                    ki,
                    ko,
                );
            }
        });
        g
    }

    /// Stage 3 shared by the dense and CSR batch paths: SJLT each sample's
    /// reconstructed vector into its strided output band, parallel over
    /// samples.
    fn sjlt_rows(&self, g: &[f32], out: &mut [f32], out_stride: usize, out_off: usize) {
        let kp = self.k_in_p() * self.k_out_p();
        let k = self.k;
        par::par_chunks_mut(out, out_stride, 1, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(out_stride).enumerate() {
                let i = row_start + off;
                self.sjlt
                    .compress_into(&g[i * kp..(i + 1) * kp], &mut orow[out_off..out_off + k]);
            }
        });
    }
}

impl FactorizedCompressor for FactGrass {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn output_dim(&self) -> usize {
        self.k
    }

    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), t * self.d_in);
        assert_eq!(dy.len(), t * self.d_out);
        assert_eq!(out.len(), self.k);
        let g = self.reconstruct(t, x, dy);
        self.sjlt.compress_into(&g, out);
    }

    /// Batch kernel: batched factor masking + reconstruction (see
    /// `FactGrass::reconstruct_batch`) followed by a per-sample SJLT of
    /// the small reconstructed vectors, parallel over samples. Zero
    /// steady-state allocation.
    #[allow(clippy::too_many_arguments)]
    fn compress_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        assert_eq!(x.len(), n * t * self.d_in);
        assert_eq!(dy.len(), n * t * self.d_out);
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + self.k <= out_stride);
        let g = self.reconstruct_batch(n, t, x, dy, scratch);
        self.sjlt_rows(&g, out, out_stride, out_off);
        scratch.put_f32(g);
    }

    /// CSR batch kernel: sparse factor masking (stage 1 cost `O(nnz + k')`
    /// per timestep row, never `O(d)`), then the shared dense
    /// reconstruction and SJLT over the small masked factors. The
    /// pipeline never *converts* dense batches for this kernel
    /// (`sparse_dispatch_viable` is false — the dense gather is already
    /// `O(k')`); it serves callers that natively hold CSR factor
    /// activations, where densifying would cost the `O(d)` this kernel
    /// avoids.
    #[allow(clippy::too_many_arguments)]
    fn compress_sparse_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &SparseRows,
        dy: &SparseRows,
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        assert_eq!(x.n(), n * t, "x row count mismatch");
        assert_eq!(dy.n(), n * t, "dy row count mismatch");
        assert_eq!(x.dim(), self.d_in, "x factor dimension mismatch");
        assert_eq!(dy.dim(), self.d_out, "dy factor dimension mismatch");
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + self.k <= out_stride);
        let g = self.reconstruct_batch_sparse(n, t, x, dy, scratch);
        self.sjlt_rows(&g, out, out_stride, out_off);
        scratch.put_f32(g);
    }

    fn name(&self) -> String {
        format!(
            "FactGraSS[SJLT_{} ∘ M_{}⊗{}]",
            self.k,
            self.k_in_p(),
            self.k_out_p()
        )
    }
}

/// Pure factorized mask baseline (`MASK_{k_in ⊗ k_out}` in Table 1d):
/// stages 1+2 only, no SJLT — output dim is `k_in'·k_out'`.
pub struct FactMask(FactGrass);

impl FactMask {
    pub fn new(d_in: usize, d_out: usize, k_in: usize, k_out: usize, seed: u64) -> Self {
        // k == reconstructed dim makes stage 3 the identity in spirit; we
        // keep the struct but bypass SJLT in compress_into.
        Self(FactGrass::new(
            d_in,
            d_out,
            k_in,
            k_out,
            k_in * k_out,
            MaskKind::Random,
            seed,
        ))
    }

    /// Selective-mask variant (`SM_{k_in ⊗ k_out}`): explicit trained masks.
    pub fn with_masks(d_in: usize, d_out: usize, mask_in: RandomMask, mask_out: RandomMask) -> Self {
        let k = mask_in.output_dim() * mask_out.output_dim();
        Self(FactGrass::with_masks(d_in, d_out, mask_in, mask_out, k, 0))
    }
}

impl FactorizedCompressor for FactMask {
    fn d_in(&self) -> usize {
        self.0.d_in
    }

    fn d_out(&self) -> usize {
        self.0.d_out
    }

    fn output_dim(&self) -> usize {
        self.0.k_in_p() * self.0.k_out_p()
    }

    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]) {
        let g = self.0.reconstruct(t, x, dy);
        out.copy_from_slice(&g);
    }

    /// Batch kernel: batched reconstruction, then a parallel copy of each
    /// sample's row into its output band.
    #[allow(clippy::too_many_arguments)]
    fn compress_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let k = self.output_dim();
        assert_eq!(x.len(), n * t * self.0.d_in);
        assert_eq!(dy.len(), n * t * self.0.d_out);
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        let g = self.0.reconstruct_batch(n, t, x, dy, scratch);
        copy_bands(&g, k, out, out_stride, out_off);
        scratch.put_f32(g);
    }

    /// CSR batch kernel: sparse factor masking (`O(nnz + k')` per timestep
    /// row), shared reconstruction, parallel band copy.
    #[allow(clippy::too_many_arguments)]
    fn compress_sparse_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &SparseRows,
        dy: &SparseRows,
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let k = self.output_dim();
        assert_eq!(x.n(), n * t, "x row count mismatch");
        assert_eq!(dy.n(), n * t, "dy row count mismatch");
        assert_eq!(x.dim(), self.0.d_in, "x factor dimension mismatch");
        assert_eq!(dy.dim(), self.0.d_out, "dy factor dimension mismatch");
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        let g = self.0.reconstruct_batch_sparse(n, t, x, dy, scratch);
        copy_bands(&g, k, out, out_stride, out_off);
        scratch.put_f32(g);
    }

    fn name(&self) -> String {
        format!("RM_{}⊗{}", self.0.k_in_p(), self.0.k_out_p())
    }
}

/// Copy each sample's `k`-wide row of `g` into its strided output band,
/// parallel over samples (shared by the dense and CSR FactMask kernels).
fn copy_bands(g: &[f32], k: usize, out: &mut [f32], out_stride: usize, out_off: usize) {
    par::par_chunks_mut(out, out_stride, 8, |row_start, chunk| {
        for (off, orow) in chunk.chunks_mut(out_stride).enumerate() {
            let i = row_start + off;
            orow[out_off..out_off + k].copy_from_slice(&g[i * k..(i + 1) * k]);
        }
    });
}

/// Factorized SJLT baseline (`SJLT_{k_in ⊗ k_out}` in Table 1d): SJLT on
/// each factor separately, then Kronecker — the "small problem size" regime
/// the paper shows is slow on GPU but included for LDS comparison.
pub struct FactSjlt {
    d_in: usize,
    d_out: usize,
    sjlt_in: Sjlt,
    sjlt_out: Sjlt,
}

impl FactSjlt {
    pub fn new(d_in: usize, d_out: usize, k_in: usize, k_out: usize, seed: u64) -> Self {
        Self {
            d_in,
            d_out,
            sjlt_in: Sjlt::new(d_in, k_in, 1, seed ^ 0x51),
            sjlt_out: Sjlt::new(d_out, k_out, 1, seed ^ 0x52),
        }
    }
}

impl FactorizedCompressor for FactSjlt {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn output_dim(&self) -> usize {
        self.sjlt_in.output_dim() * self.sjlt_out.output_dim()
    }

    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]) {
        let (ki, ko) = (self.sjlt_in.output_dim(), self.sjlt_out.output_dim());
        let mut xp = vec![0.0f32; t * ki];
        let mut dp = vec![0.0f32; t * ko];
        for ti in 0..t {
            self.sjlt_in.compress_into(
                &x[ti * self.d_in..(ti + 1) * self.d_in],
                &mut xp[ti * ki..(ti + 1) * ki],
            );
            self.sjlt_out.compress_into(
                &dy[ti * self.d_out..(ti + 1) * self.d_out],
                &mut dp[ti * ko..(ti + 1) * ko],
            );
        }
        matmul_at_b(&xp, &dp, out, t, ki, ko);
    }

    /// Batch kernel: both factor SJLTs run their chunked batch scatter over
    /// all `n·t` timestep rows at once (the bucket/sign stream is hashed
    /// once per batch), then the Kronecker accumulation runs per sample in
    /// parallel from workspace buffers.
    #[allow(clippy::too_many_arguments)]
    fn compress_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let (ki, ko) = (self.sjlt_in.output_dim(), self.sjlt_out.output_dim());
        let k = ki * ko;
        assert_eq!(x.len(), n * t * self.d_in);
        assert_eq!(dy.len(), n * t * self.d_out);
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        let nt = n * t;
        let mut xp = scratch.take_f32(nt * ki);
        let mut dp = scratch.take_f32(nt * ko);
        self.sjlt_in.compress_batch_with(x, nt, &mut xp, scratch);
        self.sjlt_out.compress_batch_with(dy, nt, &mut dp, scratch);
        {
            let (xp, dp) = (&xp[..], &dp[..]);
            par::par_chunks_mut(out, out_stride, 1, |row_start, chunk| {
                for (off, orow) in chunk.chunks_mut(out_stride).enumerate() {
                    let i = row_start + off;
                    matmul_at_b(
                        &xp[i * t * ki..(i + 1) * t * ki],
                        &dp[i * t * ko..(i + 1) * t * ko],
                        &mut orow[out_off..out_off + k],
                        t,
                        ki,
                        ko,
                    );
                }
            });
        }
        scratch.put_f32(xp);
        scratch.put_f32(dp);
    }

    /// CSR batch kernel: both factor SJLTs take their `O(s·nnz)` sparse
    /// scatter over the CSR timestep rows (no chunked table — supports
    /// differ per row), then the shared per-sample Kronecker accumulation.
    #[allow(clippy::too_many_arguments)]
    fn compress_sparse_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &SparseRows,
        dy: &SparseRows,
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let (ki, ko) = (self.sjlt_in.output_dim(), self.sjlt_out.output_dim());
        let k = ki * ko;
        assert_eq!(x.n(), n * t, "x row count mismatch");
        assert_eq!(dy.n(), n * t, "dy row count mismatch");
        assert_eq!(x.dim(), self.d_in, "x factor dimension mismatch");
        assert_eq!(dy.dim(), self.d_out, "dy factor dimension mismatch");
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        let nt = n * t;
        let mut xp = scratch.take_f32(nt * ki);
        let mut dp = scratch.take_f32(nt * ko);
        self.sjlt_in.compress_sparse_batch_with(x, &mut xp, scratch);
        self.sjlt_out.compress_sparse_batch_with(dy, &mut dp, scratch);
        {
            let (xp, dp) = (&xp[..], &dp[..]);
            par::par_chunks_mut(out, out_stride, 1, |row_start, chunk| {
                for (off, orow) in chunk.chunks_mut(out_stride).enumerate() {
                    let i = row_start + off;
                    matmul_at_b(
                        &xp[i * t * ki..(i + 1) * t * ki],
                        &dp[i * t * ko..(i + 1) * t * ko],
                        &mut orow[out_off..out_off + k],
                        t,
                        ki,
                        ko,
                    );
                }
            });
        }
        scratch.put_f32(xp);
        scratch.put_f32(dp);
    }

    /// Both factor SJLTs scan all `d` coordinates per timestep row on the
    /// dense path, so CSR conversion wins below the crossover.
    fn sparse_dispatch_viable(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!(
            "SJLT_{}⊗{}",
            self.sjlt_in.output_dim(),
            self.sjlt_out.output_dim()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::rng::Pcg;
    use crate::sketch::Compressor;

    #[test]
    fn matches_materialize_then_grass_semantics() {
        // FactGraSS(x, dy) == SJLT(mask-kron of materialised gradient):
        // build the full gradient, gather the (i,j) pairs selected by the two
        // factor masks (with scales), and SJLT the result.
        let (d_in, d_out, ki, ko, k, t) = (12, 10, 4, 3, 6, 5);
        let fg = FactGrass::new(d_in, d_out, ki, ko, k, MaskKind::Random, 33);
        let mut rng = Pcg::new(4);
        let x: Vec<f32> = (0..t * d_in).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..t * d_out).map(|_| rng.next_gaussian()).collect();

        // full gradient G[i][j] = Σ_t x[t,i] dy[t,j]
        let mut gfull = vec![0.0f32; d_in * d_out];
        for ti in 0..t {
            for i in 0..d_in {
                for j in 0..d_out {
                    gfull[i * d_out + j] += x[ti * d_in + i] * dy[ti * d_out + j];
                }
            }
        }
        // manual mask-kron gather
        let si = fg.mask_in.scale();
        let so = fg.mask_out.scale();
        let mut gp = vec![0.0f32; ki * ko];
        for (a, &i) in fg.mask_in.indices().iter().enumerate() {
            for (b, &j) in fg.mask_out.indices().iter().enumerate() {
                gp[a * ko + b] = gfull[i as usize * d_out + j as usize] * si * so;
            }
        }
        let want = Sjlt::new(ki * ko, k, 1, 33 ^ 0xFA57).compress(&gp);
        let got = fg.compress(t, &x, &dy);
        for i in 0..k {
            assert!(
                (got[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
                "mismatch at {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn reconstruct_is_kron_of_sums() {
        let (d_in, d_out, ki, ko, t) = (8, 8, 3, 3, 4);
        let fg = FactGrass::new(d_in, d_out, ki, ko, 4, MaskKind::Random, 1);
        let mut rng = Pcg::new(5);
        let x: Vec<f32> = (0..t * d_in).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..t * d_out).map(|_| rng.next_gaussian()).collect();
        let g = fg.reconstruct(t, &x, &dy);
        assert_eq!(g.len(), ki * ko);
        // g[a,b] = Σ_t x'[t,a] dy'[t,b]
        let mut want = vec![0.0f32; ki * ko];
        for ti in 0..t {
            let mut xp = vec![0.0f32; ki];
            fg.mask_in
                .compress_into(&x[ti * d_in..(ti + 1) * d_in], &mut xp);
            let mut dp = vec![0.0f32; ko];
            fg.mask_out
                .compress_into(&dy[ti * d_out..(ti + 1) * d_out], &mut dp);
            for a in 0..ki {
                for b in 0..ko {
                    want[a * ko + b] += xp[a] * dp[b];
                }
            }
        }
        for i in 0..ki * ko {
            assert!((g[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn fact_mask_output_is_reconstruction() {
        let fm = FactMask::new(16, 16, 4, 4, 2);
        assert_eq!(fm.output_dim(), 16);
        let mut rng = Pcg::new(6);
        let x: Vec<f32> = (0..2 * 16).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..2 * 16).map(|_| rng.next_gaussian()).collect();
        let out = fm.compress(2, &x, &dy);
        assert_eq!(out.len(), 16);
    }

    #[test]
    fn fact_sjlt_linear_in_inputs() {
        let fs = FactSjlt::new(32, 32, 8, 8, 3);
        let mut rng = Pcg::new(7);
        let x: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
        let dy: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
        let out1 = fs.compress(1, &x, &dy);
        let x2: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
        let out2 = fs.compress(1, &x2, &dy);
        for i in 0..out1.len() {
            assert!((out2[i] - 2.0 * out1[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn zero_activations_give_zero() {
        let fg = FactGrass::new(16, 16, 8, 8, 16, MaskKind::Random, 9);
        let out = fg.compress(3, &vec![0.0; 48], &vec![0.0; 48]);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "exceed")]
    fn invalid_mask_dims_panic() {
        FactGrass::new(4, 4, 8, 2, 4, MaskKind::Random, 0);
    }
}
